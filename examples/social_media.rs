//! Social Media pipeline under a real-derived diurnal workload with a
//! spike (the paper's Fig 6(a) scenario).
//!
//! Plans on the first 25% of the trace, then serves the remaining 75%
//! with the InferLine Tuner reacting to the spike, and compares against
//! the coarse-grained baseline (CG plan + AutoScale tuning). Runs on the
//! virtual plane so the full hour-long, 300 QPS workload finishes in
//! seconds.
//!
//! Run: `cargo run --release --example social_media`

use inferline::baselines::coarse::CoarseTarget;
use inferline::config::pipelines;
use inferline::experiments::common::{print_summary, run_coarse, run_inferline};
use inferline::profiler::analytic::paper_profiles;
use inferline::util::par::default_workers;
use inferline::workload::autoscale;

fn main() {
    let spec = pipelines::social_media();
    let profiles = paper_profiles();
    let slo = 0.15;

    let full = autoscale::big_spike_trace(61);
    let (sample, live) = full.split_at_fraction(0.25);
    println!(
        "workload: {} queries over {:.0}s (mean {:.0} qps, spike to ~300 qps)",
        full.len(),
        full.duration(),
        full.mean_rate()
    );
    println!("planning on the first 25% ({} queries), serving the rest\n", sample.len());

    match run_inferline(&spec, &profiles, &sample, &live, slo, default_workers()) {
        Ok((plan, summary)) => {
            println!("InferLine plan: {}", plan.config.summary(&spec));
            println!("  initial cost ${:.2}/hr\n", plan.cost_per_hour);
            print_summary("", &summary);
            // Show the Tuner's reaction: replica count over time.
            println!("\nreplica timeline (Tuner scaling through the spike):");
            let tl = &summary.result.replica_timeline;
            for window in tl.chunks(1 + tl.len() / 12) {
                let (t, n) = window[0];
                println!("  t={t:>6.0}s  replicas={n:<3} {}", "#".repeat(n));
            }
        }
        Err(e) => println!("InferLine: {e}"),
    }

    println!();
    let cg = run_coarse(&spec, &profiles, &sample, &live, slo, CoarseTarget::Peak, true);
    print_summary("", &cg);
}
