//! Video Monitoring: conditional control flow and the planner's
//! hardware/batch/replica trade-offs across SLOs.
//!
//! The detector feeds two conditional branches (vehicle identification
//! s=0.4, license plates s=0.25). The example plans the pipeline across a
//! range of SLOs, showing the cost cliff as the deadline loosens and the
//! planner downgrades hardware (paper Fig 9's phenomenon), then serves
//! one configuration on the physical threaded plane with calibrated
//! backends to verify the plan end to end.
//!
//! Run: `cargo run --release --example video_monitoring`

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::serving::{Backend, ServingEngine};
use inferline::util::stats;
use inferline::workload::gamma_trace;

fn main() {
    let spec = pipelines::video_monitoring();
    let profiles = paper_profiles();
    let lambda = 120.0;
    let sample = gamma_trace(lambda, 1.0, 45.0, 42);

    println!("== planner sweep across SLOs (λ={lambda} qps, CV=1) ==");
    let mut chosen = None;
    for slo in [0.1, 0.15, 0.2, 0.3, 0.5] {
        match Planner::new(&spec, &profiles).plan(&sample, slo) {
            Ok(plan) => {
                println!(
                    "  SLO {:>4.0} ms: ${:>6.2}/hr  {}",
                    slo * 1e3,
                    plan.cost_per_hour,
                    plan.config.summary(&spec)
                );
                if slo == 0.3 {
                    chosen = Some(plan);
                }
            }
            Err(e) => println!("  SLO {:>4.0} ms: {e}", slo * 1e3),
        }
    }

    let Some(plan) = chosen else { return };
    println!("\n== serving the 300 ms plan on the physical plane ==");
    let live = gamma_trace(lambda, 1.0, 10.0, 7);
    let backends: Vec<Backend> = spec
        .stages
        .iter()
        .zip(&plan.config.stages)
        .map(|(s, c)| Backend::Calibrated {
            profile: profiles.get(&s.model).get(c.hw).unwrap().clone(),
        })
        .collect();
    let engine = ServingEngine::start(&spec, &plan.config, backends).unwrap();
    let n = live.len();
    let result = engine.serve_trace(&live, 1.0, 9);
    println!(
        "  served {}/{} queries: p50 {:.1} ms  p99 {:.1} ms  attainment(300ms) {:.2}%",
        result.latencies.len(),
        n,
        stats::quantile(&result.latencies, 0.5) * 1e3,
        stats::p99(&result.latencies) * 1e3,
        stats::attainment(&result.latencies, 0.3) * 100.0
    );
}
