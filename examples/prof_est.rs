use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::{self, SimParams};
use inferline::workload::gamma_trace;
fn main() {
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let params = SimParams::default();
    let hour = gamma_trace(150.0, 1.0, 3600.0, 1);
    let plan = Planner::new(&spec, &profiles).plan(&gamma_trace(150.0, 1.0, 30.0, 2), 0.3).unwrap();
    let mut total = 0usize;
    for _ in 0..8 {
        total += simulator::simulate(&spec, &profiles, &plan.config, &hour, &params).latencies.len();
    }
    println!("{total}");
}
