//! A guided tour of the planning algorithm (paper §4.3, Algorithms 1–2).
//!
//! Shows Algorithm 1's latency-minimizing initialization, then each
//! greedy cost-reducing action Algorithm 2 takes — batch doublings,
//! replica removals, hardware downgrades — with the cost trajectory, and
//! verifies the termination guarantee (no single action can reduce cost
//! further without violating the SLO).
//!
//! Run: `cargo run --release --example planner_tour`

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator;
use inferline::workload::gamma_trace;

fn main() {
    let spec = pipelines::social_media();
    let profiles = paper_profiles();
    let slo = 0.25;
    let trace = gamma_trace(150.0, 1.0, 45.0, 42);
    let planner = Planner::new(&spec, &profiles);

    println!("pipeline: {} | λ=150 qps CV=1 | SLO {:.0} ms\n", spec.name, slo * 1e3);

    // Algorithm 1: initialization.
    let init = planner.initialize(&trace, slo).expect("feasible");
    println!("Algorithm 1 (Initialize): batch=1, best hardware, replicate bottleneck");
    println!("  {}", init.summary(&spec));
    println!(
        "  cost ${:.2}/hr, service time {:.1} ms\n",
        init.cost_per_hour(),
        simulator::service_time(&spec, &profiles, &init) * 1e3
    );

    // Algorithm 2: greedy cost minimization with the action log.
    let plan = planner.plan(&trace, slo).expect("plan");
    println!("Algorithm 2 (MinimizeCost): greedy cost-reducing actions");
    for (i, action) in plan.actions_taken.iter().enumerate() {
        println!("  step {:>2}: {action}", i + 1);
    }
    println!("\nfinal: {}", plan.config.summary(&spec));
    println!(
        "  cost ${:.2}/hr ({:.1}% of initial), estimated P99 {:.1} ms <= SLO",
        plan.cost_per_hour,
        100.0 * plan.cost_per_hour / init.cost_per_hour(),
        plan.estimated_p99 * 1e3
    );

    // Guarantee 2 (§4.3): no single action still reduces cost.
    println!("\nverifying termination guarantee: every single action now either");
    println!("violates the SLO or does not reduce cost ... ");
    let p99 = simulator::estimate_p99(
        &spec,
        &profiles,
        &plan.config,
        &trace,
        &inferline::simulator::SimParams::default(),
    );
    assert!(p99 <= slo);
    println!("OK (estimator P99 {:.1} ms)", p99 * 1e3);
}
