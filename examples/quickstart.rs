//! Quickstart: the full InferLine stack end to end on real models.
//!
//! 1. Load the AOT-compiled HLO artifacts (`make artifacts`).
//! 2. **Profile** each model of the TF-Cascade pipeline through PJRT on
//!    this machine's CPU (the paper's Profiler, §4.1).
//! 3. **Plan** a configuration for a 40 QPS workload with a 250 ms P99
//!    SLO using the measured profiles (Planner + Estimator, §4.2–4.3).
//! 4. **Serve** a live trace on the physical plane — replica worker
//!    threads executing the real HLO through their own PJRT clients
//!    behind centralized batched queues — and report latency/throughput
//!    against the Estimator's prediction.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::Path;
use std::sync::Arc;

use inferline::config::pipelines;
use inferline::hardware::Hardware;
use inferline::planner::Planner;
use inferline::profiler::ProfileSet;
use inferline::runtime::Manifest;
use inferline::serving::{profile as phys, Backend, ServingEngine};
use inferline::simulator::{self, SimParams};
use inferline::util::stats;
use inferline::workload::gamma_trace;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Arc::new(Manifest::load(&artifacts)?);
    let spec = pipelines::tf_cascade();
    let slo = 0.25;
    let lambda = 40.0;

    // -- 2. Profile (real PJRT measurements, CPU tier) ------------------
    println!("== profiling {} models through PJRT ==", spec.n_stages());
    let mut profiles = ProfileSet::default();
    let opts = phys::ProfileOptions { warmup_runs: 2, measure_runs: 7, max_batch: Some(16) };
    for stage in &spec.stages {
        let p = phys::profile_model(&manifest, &stage.model, &opts)?;
        let pts: Vec<String> =
            p.points.iter().map(|&(b, l)| format!("b{b}={:.2}ms", l * 1e3)).collect();
        println!("  {:<12} {}", stage.model, pts.join("  "));
        profiles.insert(&stage.model, Hardware::Cpu, p);
    }

    // -- 3. Plan ---------------------------------------------------------
    println!("\n== planning (λ={lambda} qps, SLO {:.0} ms) ==", slo * 1e3);
    let sample = gamma_trace(lambda, 1.0, 30.0, 42);
    let plan = Planner::new(&spec, &profiles)
        .plan(&sample, slo)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("  config:   {}", plan.config.summary(&spec));
    println!("  cost:     ${:.2}/hr", plan.cost_per_hour);
    println!("  est. P99: {:.1} ms", plan.estimated_p99 * 1e3);

    // -- 4. Serve on the physical plane (real compute) --------------------
    println!("\n== serving 20 s of live traffic through PJRT ==");
    let live = gamma_trace(lambda, 1.0, 20.0, 77);
    let est = simulator::estimate_p99(&spec, &profiles, &plan.config, &live, &SimParams::default());
    let backends: Vec<Backend> =
        spec.stages.iter().map(|_| Backend::Pjrt { manifest: manifest.clone() }).collect();
    let engine = ServingEngine::start(&spec, &plan.config, backends)?;
    let n = live.len();
    let result = engine.serve_trace(&live, 1.0, 7);

    println!("  served:       {}/{} queries", result.latencies.len(), n);
    println!("  throughput:   {:.1} qps", result.achieved_qps);
    println!(
        "  latency:      p50 {:.1} ms | p99 {:.1} ms (estimator predicted {:.1} ms)",
        stats::quantile(&result.latencies, 0.5) * 1e3,
        stats::p99(&result.latencies) * 1e3,
        est * 1e3
    );
    println!(
        "  SLO ({:.0} ms): {:.2}% attainment",
        slo * 1e3,
        stats::attainment(&result.latencies, slo) * 100.0
    );
    anyhow::ensure!(result.latencies.len() == n, "lost queries");
    println!("\nquickstart OK");
    Ok(())
}
