//! Controlled-mode conformance for the event core.
//!
//! The open-loop conformance suites (`feasibility_conformance.rs`,
//! `estimator_fast_path.rs`) pin the Estimator path; this suite pins the
//! code paths only controlled (tuner-in-the-loop) runs exercise: control
//! ticks interleaved with query events, `SetReplicas` with activation
//! delays, scale-down cancellation of in-flight activations (and their
//! revival on a rate flap), and the DS2 `Halt`/`Resume` path. Every
//! assertion is a semantic invariant of the engine — not a golden file —
//! so an event-core rewrite that changes *any* simulated outcome on these
//! paths trips the suite:
//!
//! * a `NullController` run is bit-identical to the open-loop simulation
//!   (ticks observe, never perturb);
//! * a scale-down/scale-up flap inside the activation window is
//!   bit-identical to never flapping at all (cancelled activations revive
//!   at their original activation time, paying no second delay);
//! * halts defer dispatch — never drop work — and controlled runs
//!   conserve queries and are deterministic per seed.

use inferline::baselines::ds2::Ds2Controller;
use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::control::{
    simulate_controlled, simulate_controlled_with_faults, ControlAction, ControlState, Controller,
    CountingController, NullController,
};
use inferline::simulator::faults::{FaultNode, FaultPlan, FaultSpec};
use inferline::simulator::{self, SimParams, SimResult};
use inferline::tuner::{Tuner, TunerInputs};
use inferline::workload::{gamma_trace, scenarios, Trace};

/// Assert two results agree bit-for-bit on everything a query observes.
/// (`replica_timeline` is excluded: controlled runs record a t=0 snapshot
/// and per-action entries that open-loop runs do not.)
fn assert_query_outcomes_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.latencies.len(), b.latencies.len(), "{ctx}: completion count");
    for (i, (x, y)) in a.latencies.iter().zip(&b.latencies).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: latency #{i}");
    }
    assert_eq!(a.completions.len(), b.completions.len(), "{ctx}: completions");
    for ((t1, l1), (t2, l2)) in a.completions.iter().zip(&b.completions) {
        assert_eq!(t1.to_bits(), t2.to_bits(), "{ctx}: completion time");
        assert_eq!(l1.to_bits(), l2.to_bits(), "{ctx}: completion latency");
    }
    assert_eq!(a.horizon.to_bits(), b.horizon.to_bits(), "{ctx}: horizon");
    assert_eq!(a.stage_stats.len(), b.stage_stats.len(), "{ctx}: stage count");
    for (i, (s1, s2)) in a.stage_stats.iter().zip(&b.stage_stats).enumerate() {
        assert_eq!(s1.max_queue, s2.max_queue, "{ctx}: stage {i} max_queue");
        assert_eq!(s1.batches, s2.batches, "{ctx}: stage {i} batches");
        assert_eq!(s1.queries, s2.queries, "{ctx}: stage {i} queries");
        assert_eq!(s1.busy_time.to_bits(), s2.busy_time.to_bits(), "{ctx}: stage {i} busy");
        assert_eq!(s1.mean_batch.to_bits(), s2.mean_batch.to_bits(), "{ctx}: stage {i} batch");
    }
}

/// A do-nothing controller in the loop changes *nothing*: control ticks
/// interleave with arrivals, batch completions and dispatches, yet every
/// query-visible outcome — and the accrued cost — must match the
/// open-loop run bit for bit, on every pipeline shape (chains, branching
/// DAGs, conditional routing).
#[test]
fn null_controller_is_bit_identical_to_open_loop() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in pipelines::all() {
        // A flash crowd drives real queueing so ticks land between
        // dispatch and completion events, not in quiet gaps.
        let live = scenarios::flash_crowd_trace(90.0, 280.0, 10.0, 2.0, 8.0, 4.0, 1.0, 45.0, 31);
        let config = Planner::new(&spec, &profiles).initialize(&live, 0.3).unwrap();
        let open = simulator::simulate(&spec, &profiles, &config, &live, &params);
        let mut null = NullController;
        let controlled = simulate_controlled(&spec, &profiles, &config, &live, &params, &mut null);
        assert_query_outcomes_identical(&open, &controlled, &spec.name);
        assert_eq!(
            open.cost_dollars.to_bits(),
            controlled.cost_dollars.to_bits(),
            "{}: idle-controller cost diverged from static cost",
            spec.name
        );
        assert_eq!(open.latencies.len(), live.len(), "{}: lost queries", spec.name);
    }
}

/// Replays a fixed (tick time, stage, replica target) script.
struct ScriptController {
    script: Vec<(f64, usize, usize)>,
    next: usize,
}

impl Controller for ScriptController {
    fn on_arrival(&mut self, _t: f64) {}
    fn on_tick(&mut self, t: f64, _state: &ControlState) -> Vec<ControlAction> {
        let mut actions = Vec::new();
        while self.next < self.script.len() && self.script[self.next].0 <= t {
            let (_, stage, replicas) = self.script[self.next];
            actions.push(ControlAction::SetReplicas { stage, replicas });
            self.next += 1;
        }
        actions
    }
}

fn run_script(script: Vec<(f64, usize, usize)>) -> SimResult {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    // Starve stage 0 so the exact moment extra capacity comes online is
    // visible in every queued query's latency.
    let live = gamma_trace(60.0, 1.0, 20.0, 77);
    let mut config = Planner::new(&spec, &profiles).initialize(&live, 0.3).unwrap();
    config.stages[0].replicas = 1;
    let mut ctl = ScriptController { script, next: 0 };
    simulate_controlled(
        &spec, &profiles, &config, &live, &SimParams::default(), &mut ctl,
    )
}

/// A scale-down followed by a scale-up inside the activation window must
/// be indistinguishable from never scaling down: the cancelled
/// activations are still scheduled, so reviving them brings the replicas
/// online at their *original* activation time without a second delay.
/// The third run proves the assertion has power — paying the delay again
/// (a fresh scale-up with no earlier request) visibly shifts latencies.
#[test]
fn activation_flap_revives_cancelled_replicas_at_original_time() {
    let up = 4usize;
    let base = 1usize;
    // Scale up at t=2 (online at 7), cancel at t=4, revive at t=6.
    let flap_script = vec![(2.0, 0, base + up), (4.0, 0, base), (6.0, 0, base + up)];
    let flap = run_script(flap_script);
    // Reference: scale up at t=2 and never waver.
    let steady = run_script(vec![(2.0, 0, base + up)]);
    // Power check: first request at t=6 pays the delay (online at 11).
    let late = run_script(vec![(6.0, 0, base + up)]);

    assert_query_outcomes_identical(&steady, &flap, "flap vs steady");
    assert!(
        flap.latencies
            .iter()
            .zip(&late.latencies)
            .any(|(a, b)| a.to_bits() != b.to_bits()),
        "late scale-up matches the flap run — the revival assertion is vacuous"
    );
    // The flap is visible where it should be: in the provisioning
    // timeline (down then back up), not in any query outcome.
    assert!(flap.replica_timeline.len() > steady.replica_timeline.len());
    let total_at = |r: &SimResult, t: f64| {
        r.replica_timeline.iter().rfind(|&&(at, _)| at <= t).map(|&(_, n)| n)
    };
    assert_eq!(total_at(&flap, 2.0), total_at(&steady, 2.0));
    assert!(total_at(&flap, 4.5) < total_at(&flap, 2.0), "scale-down never landed");
    assert_eq!(total_at(&flap, 6.0), total_at(&steady, 6.0));
}

/// Issues one pipeline-wide halt at a fixed tick.
struct HaltOnce {
    at: f64,
    duration: f64,
    fired: bool,
}

impl Controller for HaltOnce {
    fn on_arrival(&mut self, _t: f64) {}
    fn on_tick(&mut self, t: f64, _state: &ControlState) -> Vec<ControlAction> {
        if !self.fired && t >= self.at {
            self.fired = true;
            vec![ControlAction::Halt { duration: self.duration }]
        } else {
            Vec::new()
        }
    }
}

/// A halt defers dispatch without dropping work: in-flight batches drain
/// shortly after the halt begins, no new completions appear until the
/// resume, the backlog completes promptly afterwards, and every query
/// still completes.
#[test]
fn halt_defers_dispatch_until_resume_and_conserves_queries() {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    let live = gamma_trace(50.0, 1.0, 30.0, 19);
    let config = Planner::new(&spec, &profiles).initialize(&live, 0.3).unwrap();
    let halt_at = 10.0;
    let halt_for = 8.0;
    let run = || {
        let mut ctl = HaltOnce { at: halt_at, duration: halt_for, fired: false };
        simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut ctl,
        )
    };
    let a = run();
    assert_eq!(a.latencies.len(), live.len(), "halt dropped queries");
    let resume = halt_at + halt_for;
    assert!(a.completions.iter().any(|&(t, _)| t < halt_at), "no completions before the halt");
    // In-flight batches finish within one service path of the halt; after
    // that the pipeline must be silent until the resume.
    assert!(
        !a.completions.iter().any(|&(t, _)| t > halt_at + 1.0 && t < resume),
        "completions appeared mid-halt"
    );
    assert!(
        a.completions.iter().any(|&(t, _)| t >= resume && t < resume + 1.0),
        "backlog did not drain promptly after the resume"
    );
    // Halted runs are deterministic like any other.
    let b = run();
    assert_query_outcomes_identical(&a, &b, "halt determinism");
}

/// DS2's halt-and-restart reconfiguration path: halts actually fire under
/// a bursty trace, every query completes, and the whole closed loop —
/// halts, scale actions, cost integral — is deterministic per seed.
#[test]
fn ds2_halt_resume_is_deterministic_and_conserves_queries() {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    let service_times: Vec<f64> = spec
        .stages
        .iter()
        .map(|s| {
            let mp = profiles.get(&s.model);
            mp.get(mp.best_hardware()).unwrap().latency(1)
        })
        .collect();
    let config = inferline::config::PipelineConfig {
        stages: spec
            .stages
            .iter()
            .zip(&service_times)
            .map(|(s, &st)| inferline::config::StageConfig {
                hw: profiles.get(&s.model).best_hardware(),
                batch: 1,
                replicas: ((50.0 * s.scale_factor * st) / 0.9).ceil().max(1.0) as usize,
            })
            .collect(),
    };
    let live = gamma_trace(50.0, 4.0, 120.0, 43);
    let run = || {
        let mut ds2 = Ds2Controller::new(&spec, &service_times);
        let mut counting = CountingController::new(&mut ds2);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut counting,
        );
        (result, counting.halts)
    };
    let (a, halts_a) = run();
    assert!(halts_a > 0, "bursty trace never triggered a DS2 reconfiguration halt");
    assert_eq!(a.latencies.len(), live.len(), "DS2 halts dropped queries");
    let (b, halts_b) = run();
    assert_eq!(halts_a, halts_b, "halt count diverged across identical runs");
    assert_query_outcomes_identical(&a, &b, "ds2 determinism");
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits(), "ds2 cost diverged");
    assert_eq!(a.replica_timeline, b.replica_timeline, "ds2 timeline diverged");
}

/// The tuner closed loop on a branching DAG with conditional routing
/// (social-media: 4 stages, two conditional branches, a nested child):
/// deterministic per seed and query-conserving, extending the
/// chain-pipeline determinism check in `tuner_scenarios.rs` to the DAG
/// code paths (coalesced multi-child delivery, partial visit sets).
#[test]
fn tuner_on_conditional_dag_is_deterministic_and_conserves_queries() {
    let spec = pipelines::social_media();
    let profiles = paper_profiles();
    let sample = gamma_trace(100.0, 1.0, 30.0, 21);
    let plan = Planner::new(&spec, &profiles).plan(&sample, 0.3).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
    let live = scenarios::flash_crowd_trace(100.0, 320.0, 30.0, 2.0, 25.0, 10.0, 1.0, 120.0, 57);
    let run = |inputs: TunerInputs| {
        let mut tuner = Tuner::new(inputs);
        simulate_controlled(
            &spec, &profiles, &plan.config, &live, &SimParams::default(), &mut tuner,
        )
    };
    let a = run(inputs.clone());
    assert_eq!(a.latencies.len(), live.len(), "tuned DAG run lost queries");
    let b = run(inputs);
    assert_query_outcomes_identical(&a, &b, "tuner DAG determinism");
    assert_eq!(a.replica_timeline, b.replica_timeline);
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits());
}

/// The fault-injection hook with an empty plan is the no-fault engine,
/// bit for bit: on every pipeline shape, `simulate_controlled_with_faults`
/// with an empty `FaultPlan` must reproduce `simulate_controlled` exactly
/// — query outcomes, cost integral, provisioning timeline — and report
/// zero crashes, retries and sheds. This is the PR-7 invariant that lets
/// the fault machinery ride the hot path for free.
#[test]
fn empty_fault_plan_is_bit_identical_to_faultless_engine() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let empty = FaultSpec { nodes: Vec::new(), max_retries: 2, shed_after: None }.compile(8, 1);
    assert!(empty.is_empty());
    for spec in pipelines::all() {
        let live = scenarios::flash_crowd_trace(90.0, 280.0, 10.0, 2.0, 8.0, 4.0, 1.0, 45.0, 31);
        let config = Planner::new(&spec, &profiles).initialize(&live, 0.3).unwrap();
        let run_plain = || {
            let mut null = NullController;
            simulate_controlled(&spec, &profiles, &config, &live, &params, &mut null)
        };
        let run_hooked = || {
            let mut null = NullController;
            simulate_controlled_with_faults(
                &spec, &profiles, &config, &live, &params, &mut null, &empty,
            )
        };
        let plain = run_plain();
        let hooked = run_hooked();
        assert_query_outcomes_identical(&plain, &hooked, &spec.name);
        assert_eq!(
            plain.cost_dollars.to_bits(),
            hooked.cost_dollars.to_bits(),
            "{}: empty-plan cost diverged",
            spec.name
        );
        assert_eq!(plain.replica_timeline, hooked.replica_timeline, "{}: timeline", spec.name);
        assert_eq!((hooked.crashes, hooked.retries, hooked.shed), (0, 0, 0), "{}", spec.name);
    }
}

/// Same invariant on the tuner closed loop: the restore-to-floor pass
/// added for crash recovery must never fire in a fault-free run, so a
/// tuned run through the fault entry point with an empty plan stays
/// bit-identical — actions, timeline, cost and all.
#[test]
fn empty_fault_plan_is_bit_identical_under_tuner() {
    let spec = pipelines::social_media();
    let profiles = paper_profiles();
    let params = SimParams::default();
    let sample = gamma_trace(100.0, 1.0, 30.0, 21);
    let plan = Planner::new(&spec, &profiles).plan(&sample, 0.3).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
    let live = scenarios::flash_crowd_trace(100.0, 320.0, 30.0, 2.0, 25.0, 10.0, 1.0, 120.0, 57);
    let empty = FaultSpec { nodes: Vec::new(), max_retries: 0, shed_after: None }.compile(4, 9);
    let mut tuner = Tuner::new(inputs.clone());
    let plain = simulate_controlled(&spec, &profiles, &plan.config, &live, &params, &mut tuner);
    let mut tuner = Tuner::new(inputs);
    let hooked = simulate_controlled_with_faults(
        &spec, &profiles, &plan.config, &live, &params, &mut tuner, &empty,
    );
    assert_query_outcomes_identical(&plain, &hooked, "tuner empty-plan");
    assert_eq!(plain.replica_timeline, hooked.replica_timeline, "tuner timeline");
    assert_eq!(plain.cost_dollars.to_bits(), hooked.cost_dollars.to_bits());
    assert_eq!((hooked.crashes, hooked.retries, hooked.shed), (0, 0, 0));
}

/// A crash-storm run under the tuner: deterministic bit-for-bit per
/// seed, and query-conserving in the degraded-mode sense — every arrival
/// either completes or is counted shed, never silently dropped.
#[test]
fn crash_storm_run_is_deterministic_and_conserves_queries() {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    let params = SimParams::default();
    let sample = gamma_trace(100.0, 1.0, 30.0, 11);
    let plan = Planner::new(&spec, &profiles).plan(&sample, 0.3).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
    let live = gamma_trace(100.0, 1.0, 60.0, 23);
    let storm = FaultSpec {
        nodes: vec![FaultNode::CrashStorm { stage: None, start: 5.0, end: 50.0, rate: 0.2 }],
        max_retries: 2,
        shed_after: Some(2.0),
    };
    let faults: FaultPlan = storm.compile(spec.stages.len(), 77);
    assert!(!faults.is_empty(), "storm compiled to an empty plan");
    let run = || {
        let mut tuner = Tuner::new(inputs.clone());
        simulate_controlled_with_faults(
            &spec, &profiles, &plan.config, &live, &params, &mut tuner, &faults,
        )
    };
    let a = run();
    assert_eq!(
        a.latencies.len() as u64 + a.shed,
        live.len() as u64,
        "queries neither completed nor shed"
    );
    if a.crashes == 0 {
        assert_eq!(a.retries, 0, "retries without any crash");
    }
    let b = run();
    assert_query_outcomes_identical(&a, &b, "crash-storm determinism");
    assert_eq!((a.crashes, a.retries, a.shed), (b.crashes, b.retries, b.shed));
    assert_eq!(a.replica_timeline, b.replica_timeline, "crash-storm timeline diverged");
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits());
}

/// Degenerate-input liveness: a controlled run over an empty trace
/// processes its single armed control tick and terminates — no queries,
/// no further ticks, horizon at the tick.
#[test]
fn controlled_run_with_empty_trace_terminates_with_tick_horizon() {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    let params = SimParams::default();
    let config = inferline::config::PipelineConfig::uniform(
        spec.stages.len(),
        inferline::hardware::Hardware::Cpu,
        1,
        1,
    );
    let trace = Trace::new(Vec::new());
    let mut null = NullController;
    let result = simulate_controlled(&spec, &profiles, &config, &trace, &params, &mut null);
    assert!(result.latencies.is_empty());
    assert_eq!(result.horizon, params.control_interval);
}
