//! Differential conformance suite for the budgeted feasibility path.
//!
//! The early-abort and fast-accept optimizations are only safe to carry
//! on the planner hot path because their verdicts are *bit-identical* to
//! the reference semantics. This suite locks that down over a seeded grid
//! of (pipeline, scenario-family, SLO, configuration) cells spanning
//! clearly-feasible, clearly-infeasible, and near-boundary candidates:
//!
//! * `check_feasible(...).feasible` must equal the full simulation's
//!   `p99 <= slo` comparison, bit for bit, on every cell;
//! * the fast-accept must never fire on a configuration the full
//!   simulation rejects, and the early-abort must never fire on one it
//!   accepts;
//! * when the budgeted run completes (neither proof fired), its exact P99
//!   must equal the full simulation's P99 bit for bit;
//! * the pruned planner predicates [`simulator::feasible`] and
//!   [`simulator::feasible_unbudgeted`] must agree on every cell.

use inferline::config::{PipelineConfig, PipelineSpec};
use inferline::profiler::analytic::paper_profiles;
use inferline::profiler::ProfileSet;
use inferline::simulator::{self, SimParams};
use inferline::workload::scenarios::Scenario;
use inferline::workload::Trace;

/// The scenario families the grid draws traces from: steady Gamma, a
/// regime-switching MMPP burst and a flash crowd (each seed-deterministic
/// via `Scenario::build`).
fn family_trace(family: &str, seed: u64) -> Trace {
    let dur = 15.0;
    let scenario = match family {
        "steady" => Scenario::Gamma { lambda: 90.0, cv: 1.0, duration: dur },
        "bursty-mmpp" => Scenario::Mmpp {
            rates: vec![50.0, 220.0],
            dwell: vec![6.0, 3.0],
            duration: dur,
        },
        "flash-crowd" => Scenario::FlashCrowd {
            base: 80.0,
            peak: 260.0,
            start: 4.0,
            ramp: 1.0,
            hold: 3.0,
            decay: 2.0,
            cv: 1.0,
            duration: dur,
        },
        other => panic!("unknown conformance family {other:?}"),
    };
    scenario.build(seed).expect("scenario builds")
}

const FAMILIES: &[&str] = &["steady", "bursty-mmpp", "flash-crowd"];

/// Candidate configurations on both sides of the feasibility boundary:
/// the Algorithm-1 starting point at a loose SLO (feasible-ish), a
/// deliberately starved single-replica variant (infeasible under load),
/// and a generously over-replicated variant (clearly feasible).
fn candidate_configs(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    trace: &Trace,
) -> Vec<PipelineConfig> {
    let planner = inferline::planner::Planner::new(spec, profiles);
    let base = planner.initialize(trace, 1.0).expect("loose-SLO init");
    let mut starved = base.clone();
    for s in &mut starved.stages {
        s.replicas = 1;
    }
    let mut generous = base.clone();
    for s in &mut generous.stages {
        s.replicas += 2;
    }
    vec![base, starved, generous]
}

/// One conformance cell: budgeted check vs the unbudgeted reference, plus
/// the agreement obligations between the two proof paths.
fn assert_cell_conforms(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
    ctx: &str,
) -> (bool, bool) {
    let check = simulator::check_feasible(spec, profiles, config, trace, slo, params, None);
    let full_p99 = simulator::estimate_p99(spec, profiles, config, trace, params);
    let reference = full_p99 <= slo;
    assert_eq!(check.feasible, reference, "{ctx}: verdict diverged (full p99 {full_p99})");
    assert!(
        !(check.accepted && check.aborted),
        "{ctx}: contradictory accept + abort proofs"
    );
    if check.accepted {
        assert!(
            reference,
            "{ctx}: fast-accept fired but the full simulation rejects (p99 {full_p99} > {slo})"
        );
        assert!(check.p99.is_none(), "{ctx}: accepted runs know only the sign of P99 - SLO");
    }
    if check.aborted {
        assert!(
            !reference,
            "{ctx}: early-abort fired but the full simulation accepts (p99 {full_p99} <= {slo})"
        );
        assert!(check.p99.is_none(), "{ctx}: aborted runs know only the sign of P99 - SLO");
    }
    if let Some(p99) = check.p99 {
        assert_eq!(
            p99.to_bits(),
            full_p99.to_bits(),
            "{ctx}: completed budgeted run must reproduce the exact P99"
        );
    }
    // The planner-facing predicates (throughput prune applied on both
    // sides) must agree as well.
    assert_eq!(
        simulator::feasible(spec, profiles, config, trace, slo, params),
        simulator::feasible_unbudgeted(spec, profiles, config, trace, slo, params),
        "{ctx}: pruned predicates diverged"
    );
    (check.accepted, check.aborted)
}

/// The full conformance grid. SLOs span clearly-infeasible (50 ms is
/// under most batch-1 service paths), mid, and clearly-feasible (1 s)
/// targets; per-cell near-boundary SLOs are exercised by the dedicated
/// test below.
#[test]
fn budgeted_verdicts_conform_across_grid() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let mut accepts = 0usize;
    let mut aborts = 0usize;
    let mut cells = 0usize;
    for spec in inferline::config::pipelines::all() {
        for (f_idx, family) in FAMILIES.iter().enumerate() {
            let trace = family_trace(family, 4200 + f_idx as u64);
            for config in candidate_configs(&spec, &profiles, &trace) {
                for &slo in &[0.05, 0.2, 0.35, 1.0] {
                    let ctx = format!("{} / {family} / slo={slo}", spec.name);
                    let (accepted, aborted) = assert_cell_conforms(
                        &spec, &profiles, &config, &trace, slo, &params, &ctx,
                    );
                    accepts += accepted as usize;
                    aborts += aborted as usize;
                    cells += 1;
                }
            }
        }
    }
    // The grid must actually exercise both proof paths, or the suite
    // silently degenerates into testing only the completed-run path.
    assert!(accepts > 0, "no cell fast-accepted across {cells} cells");
    assert!(aborts > 0, "no cell early-aborted across {cells} cells");
}

/// Near-boundary conformance: SLOs placed *exactly* at a configuration's
/// full-simulation P99 and one ULP / one part-per-thousand around it —
/// the adversarial band where an unsound bound or a missing quantile
/// clamp would flip a verdict.
#[test]
fn near_boundary_slos_conform() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in [
        inferline::config::pipelines::image_processing(),
        inferline::config::pipelines::social_media(),
    ] {
        let trace = family_trace("bursty-mmpp", 77);
        for config in candidate_configs(&spec, &profiles, &trace) {
            let p99 = simulator::estimate_p99(&spec, &profiles, &config, &trace, &params);
            let ulp_up = f64::from_bits(p99.to_bits() + 1);
            let ulp_down = f64::from_bits(p99.to_bits() - 1);
            for slo in [p99, ulp_up, ulp_down, p99 * 0.999, p99 * 1.001] {
                let ctx = format!("{} near-boundary slo={slo:e}", spec.name);
                assert_cell_conforms(&spec, &profiles, &config, &trace, slo, &params, &ctx);
            }
        }
    }
}

/// A deeper, wider conditional tree than any of the four paper
/// pipelines: three conditional branches off the root, one of them two
/// levels deep, every edge probabilistic. This is the adversarial shape
/// for delivery coalescing — one finished batch fans out to up to three
/// children with *per-query* visit sets — so the budgeted proofs must
/// conform here exactly as on the paper topologies.
fn branchy_tree_spec() -> PipelineSpec {
    let stage = |name: &str, model: &str, s: f64, children: Vec<usize>| {
        inferline::config::StageSpec {
            name: name.to_string(),
            model: model.to_string(),
            scale_factor: s,
            children,
        }
    };
    PipelineSpec {
        name: "branchy-tree".to_string(),
        stages: vec![
            stage("ingest", "preprocess", 1.0, vec![1, 2, 3]),
            stage("detect", "yolo_lite", 0.7, vec![4]),
            stage("translate", "nmt_lite", 0.5, vec![5]),
            stage("fast", "tf_fast", 0.3, vec![]),
            stage("identify", "idmodel_lite", 0.35, vec![6]),
            stage("classify", "resnet_lite", 0.25, vec![]),
            stage("alpr", "alpr_lite", 0.2, vec![]),
        ],
        roots: vec![0],
        framework: inferline::config::Framework::Clipper,
    }
}

/// The conformance grid on the branchy conditional tree: budgeted
/// verdicts, proof soundness, and exact-P99 reproduction must all hold on
/// multi-child conditional fan-out, not just the paper pipelines.
#[test]
fn branchy_conditional_tree_conforms() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = branchy_tree_spec();
    let mut accepts = 0usize;
    let mut aborts = 0usize;
    for (f_idx, family) in FAMILIES.iter().enumerate() {
        let trace = family_trace(family, 8600 + f_idx as u64);
        for config in candidate_configs(&spec, &profiles, &trace) {
            for &slo in &[0.05, 0.2, 0.35, 1.0] {
                let ctx = format!("branchy-tree / {family} / slo={slo}");
                let (accepted, aborted) = assert_cell_conforms(
                    &spec, &profiles, &config, &trace, slo, &params, &ctx,
                );
                accepts += accepted as usize;
                aborts += aborted as usize;
            }
        }
    }
    assert!(accepts > 0, "no branchy-tree cell fast-accepted");
    assert!(aborts > 0, "no branchy-tree cell early-aborted");
}

/// The fault-injection entry point with an empty plan must reproduce the
/// plain budgeted check exactly — same verdict, same proof path (accept /
/// abort flags), and when the run completes, the identical P99 bits. A
/// sub-grid of the main conformance grid suffices: any divergence here is
/// a no-fault perturbation, which the PR-7 invariant forbids outright.
#[test]
fn empty_fault_plan_feasibility_matches_plain_check() {
    use inferline::simulator::faults::FaultSpec;
    let profiles = paper_profiles();
    let params = SimParams::default();
    let empty = FaultSpec { nodes: Vec::new(), max_retries: 2, shed_after: None }.compile(8, 5);
    assert!(empty.is_empty());
    for spec in inferline::config::pipelines::all() {
        let trace = family_trace("bursty-mmpp", 9300);
        for config in candidate_configs(&spec, &profiles, &trace) {
            for &slo in &[0.05, 0.35, 1.0] {
                let plain = simulator::check_feasible(
                    &spec, &profiles, &config, &trace, slo, &params, None,
                );
                let hooked = simulator::check_feasible_with_faults(
                    &spec, &profiles, &config, &trace, slo, &params, None, &empty,
                );
                let ctx = format!("{} / slo={slo}", spec.name);
                assert_eq!(plain.feasible, hooked.feasible, "{ctx}: verdict");
                assert_eq!(plain.accepted, hooked.accepted, "{ctx}: fast-accept path");
                assert_eq!(plain.aborted, hooked.aborted, "{ctx}: early-abort path");
                assert_eq!(
                    plain.p99.map(f64::to_bits),
                    hooked.p99.map(f64::to_bits),
                    "{ctx}: completed-run P99 bits"
                );
            }
        }
    }
}

/// Straggler regression (the late-arrival bug class): both proof
/// thresholds derive from the *full* trace length, so queries that only
/// arrive after the decision point — here a burst followed by a long
/// silent gap and a final straggler cohort — must never let a proof fire
/// that the full simulation contradicts.
#[test]
fn stragglers_after_decision_point_conform() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = inferline::config::pipelines::image_processing();
    // 300-query burst at 100 QPS, then 20 stragglers arriving one per
    // second starting 30 s later.
    let mut arrivals: Vec<f64> = (0..300).map(|i| i as f64 * 0.01).collect();
    arrivals.extend((0..20).map(|i| 33.0 + i as f64));
    let trace = Trace::new(arrivals);
    for config in candidate_configs(&spec, &profiles, &trace) {
        for &slo in &[0.02, 0.1, 0.3, 1.0] {
            let ctx = format!("stragglers slo={slo}");
            assert_cell_conforms(&spec, &profiles, &config, &trace, slo, &params, &ctx);
        }
    }
}
