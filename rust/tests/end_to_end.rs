//! End-to-end integration: plan → estimate → serve (virtual + physical
//! planes) → tune across the four paper pipelines, plus baseline
//! cross-checks. These are the "does the whole system compose" tests.

use inferline::baselines::coarse::{self, CoarseTarget};
use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::serving::{Backend, ServingEngine};
use inferline::simulator::{self, control::simulate_controlled, SimParams};
use inferline::tuner::{Tuner, TunerInputs};
use inferline::util::stats;
use inferline::workload::{autoscale, gamma_trace, varying_trace, Phase};

#[test]
fn all_four_pipelines_plan_and_meet_slo() {
    let profiles = paper_profiles();
    for spec in pipelines::all() {
        let slo = 0.3;
        let sample = gamma_trace(80.0, 1.0, 30.0, 1);
        let live = gamma_trace(80.0, 1.0, 60.0, 2);
        let plan = Planner::new(&spec, &profiles)
            .plan(&sample, slo)
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let result =
            simulator::simulate(&spec, &profiles, &plan.config, &live, &SimParams::default());
        assert_eq!(result.latencies.len(), live.len(), "{}", spec.name);
        assert!(
            result.miss_rate(slo) < 0.02,
            "{}: miss rate {}",
            spec.name,
            result.miss_rate(slo)
        );
    }
}

#[test]
fn estimator_matches_physical_plane_within_tolerance() {
    // The Fig 8 property: the Estimator's P99 must predict the physical
    // threaded serving plane. Calibrated backends isolate queueing
    // dynamics from machine noise.
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let slo = 0.3;
    let sample = gamma_trace(60.0, 1.0, 30.0, 5);
    let plan = Planner::new(&spec, &profiles).plan(&sample, slo).unwrap();
    let live = gamma_trace(60.0, 1.0, 15.0, 7);

    let est = simulator::estimate_p99(&spec, &profiles, &plan.config, &live, &SimParams::default());
    let backends: Vec<Backend> = spec
        .stages
        .iter()
        .zip(&plan.config.stages)
        .map(|(s, c)| Backend::Calibrated {
            profile: profiles.get(&s.model).get(c.hw).unwrap().clone(),
        })
        .collect();
    let engine = ServingEngine::start(&spec, &plan.config, backends).unwrap();
    let measured = engine.serve_trace(&live, 1.0, SimParams::default().routing_seed);
    assert_eq!(measured.latencies.len(), live.len());
    let measured_p99 = stats::p99(&measured.latencies);
    // Physical threads add scheduling jitter; require agreement within
    // 2.5x and both sides comfortably ordered vs the SLO.
    let ratio = measured_p99 / est;
    assert!(
        (0.4..2.5).contains(&ratio),
        "estimator {est} vs measured {measured_p99} (ratio {ratio})"
    );
}

#[test]
fn tuner_handles_real_derived_trace_end_to_end() {
    let profiles = paper_profiles();
    let spec = pipelines::tf_cascade();
    let slo = 0.15;
    let full = autoscale::synthesize(&autoscale::instant_spike_minutes()[..20], 150.0, 9);
    let (sample, live) = full.split_at_fraction(0.25);
    let plan = Planner::new(&spec, &profiles).plan(&sample, slo).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
    let mut tuner = Tuner::new(inputs);
    let result =
        simulate_controlled(&spec, &profiles, &plan.config, &live, &SimParams::default(), &mut tuner);
    assert_eq!(result.latencies.len(), live.len());
    assert!(
        result.miss_rate(slo) < 0.10,
        "tuned miss rate {} on instant-spike trace",
        result.miss_rate(slo)
    );
}

#[test]
fn inferline_beats_cg_on_cost_and_attainment_under_ramp() {
    // The Fig 7 / Fig 12 composite claim.
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let slo = 0.15;
    let sample = gamma_trace(100.0, 1.0, 30.0, 11);
    let live = varying_trace(
        &[
            Phase { lambda: 100.0, cv: 1.0, duration: 40.0, ramp: false },
            Phase { lambda: 200.0, cv: 1.0, duration: 30.0, ramp: true },
            Phase { lambda: 200.0, cv: 1.0, duration: 60.0, ramp: false },
        ],
        13,
    );
    // InferLine side.
    let plan = Planner::new(&spec, &profiles).plan(&sample, slo).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
    let mut tuner = Tuner::new(inputs);
    let il =
        simulate_controlled(&spec, &profiles, &plan.config, &live, &SimParams::default(), &mut tuner);
    // CG-Peak + AutoScale side.
    let cg = coarse::plan(&spec, &profiles, &sample, slo, CoarseTarget::Peak);
    let mut cg_tuner =
        inferline::baselines::autoscale::AutoScaleTuner::new(cg.unit_throughput, cg.units);
    let cgr =
        simulate_controlled(&spec, &profiles, &cg.config, &live, &SimParams::default(), &mut cg_tuner);
    assert!(
        il.cost_dollars < cgr.cost_dollars,
        "InferLine ${} !< CG ${}",
        il.cost_dollars,
        cgr.cost_dollars
    );
    assert!(
        il.miss_rate(slo) <= cgr.miss_rate(slo) + 0.02,
        "InferLine miss {} vs CG {}",
        il.miss_rate(slo),
        cgr.miss_rate(slo)
    );
}

#[test]
fn frameworks_differ_only_in_overhead() {
    // Fig 13: same planner, two serving frameworks; TFS costs >= Clipper
    // because of higher RPC overhead.
    let profiles = paper_profiles();
    let slo = 0.15;
    let sample = gamma_trace(120.0, 1.0, 30.0, 17);
    let mut costs = Vec::new();
    for fw in [
        inferline::config::Framework::Clipper,
        inferline::config::Framework::TfServing,
    ] {
        let mut spec = pipelines::tf_cascade();
        spec.framework = fw;
        let plan = Planner::new(&spec, &profiles).plan(&sample, slo).unwrap();
        let live = gamma_trace(120.0, 1.0, 60.0, 19);
        let result =
            simulator::simulate(&spec, &profiles, &plan.config, &live, &SimParams::default());
        assert!(result.miss_rate(slo) < 0.011, "{:?} missed", fw);
        costs.push(plan.cost_per_hour);
    }
    assert!(costs[1] >= costs[0] - 1e-9, "TFS {} < Clipper {}", costs[1], costs[0]);
}

#[test]
fn quick_experiment_registry_is_complete() {
    for name in inferline::experiments::ALL_FIGURES {
        assert!(
            ["fig3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
             "fig13", "fig14", "headline", "sweep"]
            .contains(name),
            "unexpected experiment {name}"
        );
    }
    assert!(!inferline::experiments::run_by_name("nonexistent", true));
}

#[test]
fn physical_plane_scales_while_serving() {
    // Runtime replica scaling (paper §3 requirement 1) under live load.
    let profiles = paper_profiles();
    let spec = pipelines::tf_cascade();
    let config = inferline::config::PipelineConfig::uniform(
        spec.n_stages(),
        inferline::hardware::Hardware::Cpu,
        2,
        1,
    );
    let backends: Vec<Backend> = spec
        .stages
        .iter()
        .map(|s| Backend::Calibrated {
            profile: profiles.get(&s.model).get(inferline::hardware::Hardware::Cpu).unwrap().clone(),
        })
        .collect();
    let mut engine = ServingEngine::start(&spec, &config, backends).unwrap();
    assert!(engine.wait_ready(std::time::Duration::from_secs(10)));
    engine.spawn_worker(0).unwrap();
    engine.spawn_worker(1).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert_eq!(engine.worker_counts(), vec![2, 2]);
    let live = gamma_trace(50.0, 1.0, 3.0, 23);
    let n = live.len();
    let result = engine.serve_trace(&live, 1.0, 25);
    assert_eq!(result.latencies.len(), n);
}
