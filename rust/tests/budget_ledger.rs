//! SLO-budget-ledger integration: the checked-in `scenarios/` matrix,
//! the checked-in `BUDGETS.json`, and the `budget check` gate against a
//! real robustness grid report.
//!
//! Three invariants ride here:
//!
//! 1. every `scenarios/*.json` file on disk parses, names itself after
//!    its file stem, builds a nonempty trace in both modes, and the
//!    directory matches `robustness::FAMILIES` exactly (the embedded
//!    copies are `include_str!` of these same files, so disk and binary
//!    cannot drift — but a file missing from the registration tables
//!    can);
//! 2. `BUDGETS.json` parses and both mode sections cover the matrix
//!    exactly, at the seed and SLO CI actually runs;
//! 3. a grid report round-trips the ledger machinery end to end:
//!    re-baseline → check passes; a tightened budget fails naming the
//!    offending scenario.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use inferline::config::pipelines;
use inferline::experiments::budgets::{self, BudgetFile};
use inferline::experiments::robustness::{self, FAMILIES};
use inferline::workload::scenarios::ScenarioSpec;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

#[test]
fn every_checked_in_scenario_parses_and_builds() {
    let dir = repo_root().join("scenarios");
    let mut found = BTreeSet::new();
    for entry in std::fs::read_dir(&dir).expect("scenarios/ directory at the repo root") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let stem = path.file_stem().unwrap().to_str().unwrap().to_string();
        let spec =
            ScenarioSpec::load(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert_eq!(spec.name, stem, "{}: spec name must match the file stem", path.display());
        for quick in [false, true] {
            let trace = spec
                .scenario_for(quick)
                .build(7)
                .unwrap_or_else(|e| panic!("{stem} (quick={quick}): {e}"));
            assert!(!trace.is_empty(), "{stem} (quick={quick}): empty trace");
            assert!(
                trace.duration() > 30.0,
                "{stem} (quick={quick}): only {:.1}s of arrivals",
                trace.duration()
            );
            assert!(trace.mean_rate() > 10.0, "{stem} (quick={quick}): near-idle trace");
        }
        found.insert(stem);
    }
    let families: BTreeSet<String> = FAMILIES.iter().map(|f| f.to_string()).collect();
    assert_eq!(found, families, "scenarios/*.json and robustness::FAMILIES must match 1:1");
    assert!(families.len() >= 12, "matrix shrank to {}", families.len());
}

#[test]
fn checked_in_budgets_cover_the_matrix() {
    let path = repo_root().join("BUDGETS.json");
    let file = BudgetFile::load(&path).expect("BUDGETS.json must parse");
    for (mode, section) in [("quick", &file.quick), ("full", &file.full)] {
        let mb = section.as_ref().unwrap_or_else(|| panic!("missing {mode} section"));
        // CI runs the harness at the default seed and SLO; a ledger
        // pinned to anything else could never gate.
        assert_eq!(mb.seed, 42, "{mode}: seed must match the harness default");
        assert!(
            (mb.slo - robustness::DEFAULT_SLO).abs() < 1e-12,
            "{mode}: slo {} vs harness {}",
            mb.slo,
            robustness::DEFAULT_SLO
        );
        assert!(mb.miss_slack > 0.0 && mb.miss_slack < 0.5, "{mode}: miss_slack");
        assert!(mb.cost_slack >= 1.0, "{mode}: cost_slack");
        assert!(mb.ratio_slack > 0.0 && mb.ratio_slack <= 1.0, "{mode}: ratio_slack");
        let budgeted: BTreeSet<&str> = mb.scenarios.keys().map(String::as_str).collect();
        let families: BTreeSet<&str> = FAMILIES.iter().copied().collect();
        assert_eq!(budgeted, families, "{mode}: the ledger must cover the matrix exactly");
        for (name, b) in &mb.scenarios {
            assert!(
                b.max_miss_rate >= 0.0 && b.max_miss_rate <= 1.0,
                "{mode}/{name}: max_miss_rate {}",
                b.max_miss_rate
            );
            assert!(b.max_cost_overhead >= 1.0, "{mode}/{name}: max_cost_overhead");
            assert!(b.min_peak_cost_ratio >= 0.0, "{mode}/{name}: min_peak_cost_ratio");
            if let Some(c) = b.max_cost_per_hour {
                assert!(c > 0.0, "{mode}/{name}: max_cost_per_hour {c}");
            }
        }
    }
}

#[test]
fn grid_report_gates_through_the_ledger() {
    let specs = [pipelines::image_processing()];
    let families = ["steady", "flash-crowd"];
    let cells =
        robustness::run_grid(&families, &specs, 42, robustness::DEFAULT_SLO, true);
    let report = robustness::report_json(42, robustness::DEFAULT_SLO, true, &cells);
    // Re-baseline a fresh ledger from the run, then check: must pass.
    let mut ledger = BudgetFile::default();
    assert_eq!(budgets::update(&report, &mut ledger).unwrap(), "quick");
    let outcome = budgets::check(&report, &ledger).unwrap();
    assert_eq!(outcome.mode, "quick");
    assert!(outcome.violations.is_empty(), "{:?}", outcome.violations);
    assert_eq!(outcome.lines.len(), families.len());
    // Round-trip through disk exactly like the CLI does.
    let dir = std::env::temp_dir().join("inferline-budget-ledger-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BUDGETS.json");
    ledger.save(&path).unwrap();
    assert_eq!(BudgetFile::load(&path).unwrap(), ledger);
    // Tightening a budget past the observation fails, naming the
    // scenario (the CI gate's one job).
    let mut tight = ledger.clone();
    tight.quick.as_mut().unwrap().scenarios.get_mut("flash-crowd").unwrap().max_miss_rate =
        -1.0;
    let outcome = budgets::check(&report, &tight).unwrap();
    assert!(
        outcome
            .violations
            .iter()
            .any(|v| v.scenario == "flash-crowd" && v.what.contains("miss rate")),
        "{:?}",
        outcome.violations
    );
    assert!(
        !outcome.violations.iter().any(|v| v.scenario == "steady"),
        "steady was within budget: {:?}",
        outcome.violations
    );
    // The baselines genuinely met the matrix: both systems ran in every
    // cell with live comparative ratios.
    for c in &cells {
        let m = c.outcome.as_ref().unwrap();
        let peak = m
            .baselines
            .iter()
            .find(|b| b.system == budgets::PEAK_BASELINE)
            .expect("CG-Peak baseline in every cell");
        assert!(peak.cost_ratio.is_finite() && peak.cost_ratio > 0.0, "{}", c.scenario);
    }
}
