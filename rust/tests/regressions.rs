//! Regression tests for the bug fixes shipped with the build-restoration
//! PR: retire-debt reclamation on scale-up flaps, the window-ladder T_s
//! rung for slow pipelines, the peak-rate divisor clamp on short traces,
//! and serial/parallel planner determinism.

use inferline::config::{Framework, PipelineConfig, PipelineSpec, StageConfig, StageSpec};
use inferline::hardware::Hardware;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::profiler::{BatchProfile, ProfileSet};
use inferline::simulator::control::{
    simulate_controlled, ControlAction, ControlState, Controller,
};
use inferline::simulator::SimParams;
use inferline::tuner::envelope::window_ladder;
use inferline::workload::{gamma_trace, Trace};

/// One-stage pipeline with a fixed 10 s batch-1 service time, 4 replicas,
/// and one arrival every 2.5 s: exactly critical utilization on a time
/// grid where every arrival coincides with a completion, so in steady
/// state *every* query's latency is exactly the 10 s service time. Any
/// capacity gap shows up as a clean latency step, which makes the flap
/// behavior fully deterministic to assert on.
fn slow_stage_setup() -> (PipelineSpec, ProfileSet, PipelineConfig, Trace) {
    let spec = PipelineSpec {
        name: "one-slow-stage".into(),
        stages: vec![StageSpec {
            name: "only".into(),
            model: "m".into(),
            scale_factor: 1.0,
            children: vec![],
        }],
        roots: vec![0],
        framework: Framework::Clipper,
    };
    spec.validate().unwrap();
    let mut profiles = ProfileSet::default();
    // Batch cap 1 => a single (1, 10.0s) profile point.
    profiles.insert("m", Hardware::Cpu, BatchProfile::affine(10.0, 0.0, 1));
    let config = PipelineConfig {
        stages: vec![StageConfig { hw: Hardware::Cpu, batch: 1, replicas: 4 }],
    };
    // 24 arrivals at t = 2.5, 5.0, …, 60.0.
    let trace = Trace::new((1..=24).map(|i| i as f64 * 2.5).collect());
    (spec, profiles, config, trace)
}

/// Scripted controller: fires each (time, replica-target) action on the
/// first tick at or after its time, in order.
struct ScriptController {
    /// (fire at or after, replica target) — strictly increasing times.
    schedule: Vec<(f64, usize)>,
    next: usize,
}

impl ScriptController {
    fn new(schedule: Vec<(f64, usize)>) -> Self {
        ScriptController { schedule, next: 0 }
    }
}

impl Controller for ScriptController {
    fn on_arrival(&mut self, _t: f64) {}

    fn on_tick(&mut self, now: f64, _state: &ControlState) -> Vec<ControlAction> {
        match self.schedule.get(self.next) {
            Some(&(at, replicas)) if now >= at => {
                self.next += 1;
                vec![ControlAction::SetReplicas { stage: 0, replicas }]
            }
            _ => Vec::new(),
        }
    }
}

/// A scale-down followed one control tick later by a scale-up must
/// reclaim the still-online retiring replicas instead of paying the 5 s
/// activation delay for capacity that was never actually released.
///
/// At t = 20 all four replicas are busy (batches run 10 s), so the
/// scale-down to 1 marks three of them as retire-on-completion; none
/// completes before the scale-up at t = 21. With reclamation the flap is
/// a no-op — every query's latency stays exactly the 10 s service time.
/// Without it, the three retiring replicas exit at t = 22.5/25/27.5 while
/// their three replacements sit out the activation delay until t = 26,
/// and the starved queue pushes latencies past 12.5 s.
#[test]
fn scale_flap_restores_capacity_without_activation_spike() {
    let (spec, profiles, config, trace) = slow_stage_setup();
    let params = SimParams::default(); // 1 s control ticks, 5 s activation
    let mut flap = ScriptController::new(vec![(20.0, 1), (21.0, 4)]);
    let result = simulate_controlled(&spec, &profiles, &config, &trace, &params, &mut flap);
    assert_eq!(result.latencies.len(), trace.len(), "queries lost during flap");
    let max_latency = result.latencies.iter().copied().fold(0.0, f64::max);
    assert!(
        max_latency < 10.5,
        "flap paid an activation/queueing penalty: max latency {max_latency:.2}s \
         (service time is 10s; reclaimed capacity must restore instantly)"
    );
    // The replica timeline must show the dip and the instant restore.
    assert!(result
        .replica_timeline
        .iter()
        .any(|&(t, n)| (t - 20.0).abs() < 1e-9 && n == 1));
    assert!(result
        .replica_timeline
        .iter()
        .any(|&(t, n)| (t - 21.0).abs() < 1e-9 && n == 4));
}

/// Control case: with a *long* gap the retiring replicas really do exit,
/// so the later scale-up must pay the activation delay — guarding against
/// reclamation accidentally granting free capacity for genuinely
/// released replicas.
#[test]
fn slow_flap_still_pays_activation_delay() {
    let (spec, profiles, config, trace) = slow_stage_setup();
    let params = SimParams::default();
    // The three retiring replicas complete (and exit) at t = 22.5, 25.0
    // and 27.5; scaling up at t = 35 finds nothing to reclaim.
    let mut flap = ScriptController::new(vec![(20.0, 1), (35.0, 4)]);
    let result = simulate_controlled(&spec, &profiles, &config, &trace, &params, &mut flap);
    assert_eq!(result.latencies.len(), trace.len());
    let max_latency = result.latencies.iter().copied().fold(0.0, f64::max);
    assert!(
        max_latency > 11.0,
        "genuinely released capacity must not restore for free: max {max_latency:.2}s"
    );
}

/// The same flap class one lifecycle state earlier: a scale-up must also
/// reclaim cancelled-but-inflight activations, which come online at
/// their *original* activation time instead of paying a fresh 5 s delay.
#[test]
fn scale_flap_reclaims_cancelled_pending_activations() {
    let spec = PipelineSpec {
        name: "one-slow-stage".into(),
        stages: vec![StageSpec {
            name: "only".into(),
            model: "m".into(),
            scale_factor: 1.0,
            children: vec![],
        }],
        roots: vec![0],
        framework: Framework::Clipper,
    };
    spec.validate().unwrap();
    let mut profiles = ProfileSet::default();
    profiles.insert("m", Hardware::Cpu, BatchProfile::affine(10.0, 0.0, 1));
    let config = PipelineConfig {
        stages: vec![StageConfig { hw: Hardware::Cpu, batch: 1, replicas: 1 }],
    };
    // q1 occupies the only replica from t = 0.2 to 10.2. The script asks
    // for a second replica at t = 1 (online at t = 6), cancels it at
    // t = 2 while it is still in flight, and re-requests it at t = 3.
    // Un-cancelling keeps the original t = 6 activation, so q2 (t = 6.5)
    // is served immediately: latency exactly 10 s. Without reclamation a
    // fresh activation lands at t = 8 and q2 waits 1.5 s.
    let trace = Trace::new(vec![0.2, 6.5]);
    let params = SimParams::default();
    let mut flap = ScriptController::new(vec![(1.0, 2), (2.0, 1), (3.0, 2)]);
    let result = simulate_controlled(&spec, &profiles, &config, &trace, &params, &mut flap);
    assert_eq!(result.latencies.len(), 2);
    let q2 = result.latencies[1];
    assert!(
        (q2 - 10.0).abs() < 0.5,
        "cancelled in-flight activation not reclaimed: q2 latency {q2:.2}s (want ~10.0s)"
    );
}

#[test]
fn window_ladder_always_includes_service_time_rung() {
    // Slow pipelines (T_s >= 60 s) keep their T_s rung instead of
    // degenerating to the single window [60.0].
    assert_eq!(window_ladder(75.0), vec![75.0]);
    assert_eq!(window_ladder(60.0), vec![60.0]);
    assert_eq!(window_ladder(120.0), vec![120.0]);
    // Just below the cap: T_s rung plus the 60 s cap.
    assert_eq!(window_ladder(40.0), vec![40.0, 60.0]);
    // Fast pipelines: unchanged doubling ladder from T_s to 60 s.
    let fast = window_ladder(0.25);
    assert!((fast[0] - 0.25).abs() < 1e-12);
    assert!((fast.last().unwrap() - 60.0).abs() < 1e-9);
    for pair in fast.windows(2) {
        assert!(pair[1] > pair[0]);
    }
}

#[test]
fn peak_rate_clamps_window_to_trace_duration() {
    // 10 QPS uniform trace lasting ~10 s: a 60 s peak window must divide
    // by the trace duration, not the full window.
    let trace = Trace::new((1..=100).map(|i| i as f64 / 10.0).collect());
    let mean = trace.mean_rate();
    let peak60 = trace.peak_rate(60.0);
    assert!(
        (peak60 - mean).abs() < 1.0,
        "peak over an over-long window should ~equal the mean rate: peak {peak60:.2} mean {mean:.2}"
    );
    // Regression guard against the old behavior (100 queries / 60 s ≈ 1.7).
    assert!(peak60 > mean * 0.9, "underestimated: {peak60:.2} vs mean {mean:.2}");
    // Windows shorter than the trace are unaffected.
    let bursty = gamma_trace(100.0, 4.0, 60.0, 3);
    assert!(bursty.peak_rate(0.15) > bursty.mean_rate() * 1.5);
    // CG-Peak's statistic on a short planning trace no longer undershoots
    // the sustained rate.
    let short = gamma_trace(100.0, 1.0, 10.0, 5);
    assert!(short.peak_rate(30.0) >= short.mean_rate() * 0.95);
}

#[test]
fn parallel_and_serial_planner_agree_end_to_end() {
    let profiles = paper_profiles();
    let spec = inferline::config::pipelines::social_media();
    let trace = gamma_trace(150.0, 1.0, 30.0, 7);
    let slo = 0.25;
    let serial = Planner::serial(&spec, &profiles).plan(&trace, slo).unwrap();
    let parallel = Planner::new(&spec, &profiles).with_threads(8).plan(&trace, slo).unwrap();
    assert_eq!(serial.config, parallel.config);
    assert_eq!(serial.actions_taken, parallel.actions_taken);
    assert_eq!(serial.iterations, parallel.iterations);
    assert_eq!(serial.cost_per_hour.to_bits(), parallel.cost_per_hour.to_bits());
    // Telemetry must report real cache activity.
    assert!(parallel.telemetry.cache_misses > 0);
    assert!(parallel.telemetry.cache_hits + parallel.telemetry.cache_misses > 0);
}
