//! Property tests on the discrete-event Estimator's invariants, driven by
//! randomized pipelines, profiles and workloads (util::prop — the in-repo
//! proptest replacement, DESIGN.md §8).

use inferline::config::{Framework, PipelineConfig, PipelineSpec, StageConfig, StageSpec};
use inferline::hardware::Hardware;
use inferline::profiler::{BatchProfile, ProfileSet};
use inferline::simulator::faults::{FaultNode, FaultSpec};
use inferline::simulator::{self, SimParams};
use inferline::util::prop;
use inferline::util::rng::Rng;
use inferline::workload::gamma_trace;

/// Random linear-or-branching pipeline with 2-5 stages and valid scale
/// factors, plus matching profiles and a random (feasible-ish) config.
fn random_setup(rng: &mut Rng) -> (PipelineSpec, ProfileSet, PipelineConfig) {
    let n = 2 + rng.usize(4);
    let mut stages = Vec::new();
    let mut profiles = ProfileSet::default();
    for i in 0..n {
        // Parent: previous stage (chain) or an earlier fork point.
        let scale = if i == 0 { 1.0 } else { (0.2 + 0.8 * rng.f64()).min(1.0) };
        stages.push(StageSpec {
            name: format!("s{i}"),
            model: format!("m{i}"),
            scale_factor: scale,
            children: Vec::new(),
        });
        let alpha = 0.001 + rng.f64() * 0.01;
        let beta = 0.0002 + rng.f64() * 0.004;
        profiles.insert(&format!("m{i}"), Hardware::Cpu, BatchProfile::affine(alpha, beta, 32));
        profiles.insert(
            &format!("m{i}"),
            Hardware::GpuK80,
            BatchProfile::affine(alpha * 0.5, beta * 0.2, 64),
        );
    }
    // Tree shape: each stage i>0 hangs off a random earlier stage whose
    // scale factor is >= its own.
    for i in 1..n {
        let mut parent = rng.usize(i);
        let mut guard = 0;
        while stages[parent].scale_factor < stages[i].scale_factor && guard < 10 {
            stages[i].scale_factor = stages[parent].scale_factor * (0.3 + 0.7 * rng.f64());
            guard += 1;
            parent = rng.usize(i);
        }
        stages[i].scale_factor = stages[i].scale_factor.min(stages[parent].scale_factor);
        let child = i;
        stages[parent].children.push(child);
    }
    stages[0].scale_factor = 1.0;
    let spec = PipelineSpec {
        name: "random".into(),
        stages,
        roots: vec![0],
        framework: if rng.bool(0.5) { Framework::Clipper } else { Framework::TfServing },
    };
    spec.validate().expect("generated spec must validate");
    let config = PipelineConfig {
        stages: (0..n)
            .map(|_| StageConfig {
                hw: if rng.bool(0.5) { Hardware::Cpu } else { Hardware::GpuK80 },
                batch: [1, 2, 4, 8][rng.usize(4)],
                replicas: 1 + rng.usize(4),
            })
            .collect(),
    };
    (spec, profiles, config)
}

#[test]
fn every_query_completes_exactly_once() {
    prop::check("completion conservation", 40, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let lambda = 10.0 + rng.f64() * 100.0;
        let trace = gamma_trace(lambda, 0.5 + rng.f64() * 3.0, 10.0, rng.next_u64());
        let result = simulator::simulate(&spec, &profiles, &config, &trace, &SimParams::default());
        assert_eq!(result.latencies.len(), trace.len(), "query loss or duplication");
    });
}

#[test]
fn latency_at_least_best_case_service_time() {
    prop::check("latency lower bound", 30, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(20.0, 1.0, 10.0, rng.next_u64());
        let result = simulator::simulate(&spec, &profiles, &config, &trace, &SimParams::default());
        // Lower bound: cheapest single-stage batch-1 latency of the root.
        let root = 0usize;
        let c = &config.stages[root];
        let min_service = profiles.get(&spec.stages[root].model).get(c.hw).unwrap().latency(1);
        for &l in &result.latencies {
            assert!(l >= min_service * 0.999, "latency {l} below service {min_service}");
        }
    });
}

#[test]
fn simulation_is_deterministic() {
    prop::check("determinism", 20, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(50.0, 2.0, 8.0, rng.next_u64());
        let params = SimParams::default();
        let a = simulator::simulate(&spec, &profiles, &config, &trace, &params);
        let b = simulator::simulate(&spec, &profiles, &config, &trace, &params);
        assert_eq!(a.latencies, b.latencies);
        assert_eq!(a.stage_stats.len(), b.stage_stats.len());
    });
}

#[test]
fn batch_sizes_never_exceed_configured_max() {
    prop::check("batch bound", 30, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(80.0, 2.0, 8.0, rng.next_u64());
        let result = simulator::simulate(&spec, &profiles, &config, &trace, &SimParams::default());
        for (i, st) in result.stage_stats.iter().enumerate() {
            if st.batches > 0 {
                assert!(
                    st.mean_batch <= config.stages[i].batch as f64 + 1e-9,
                    "stage {i} mean batch {} > max {}",
                    st.mean_batch,
                    config.stages[i].batch
                );
            }
        }
    });
}

#[test]
fn stage_visit_counts_respect_scale_factors() {
    prop::check("scale factor routing", 20, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(100.0, 1.0, 30.0, rng.next_u64());
        let result = simulator::simulate(&spec, &profiles, &config, &trace, &SimParams::default());
        let n = trace.len() as f64;
        for (i, st) in result.stage_stats.iter().enumerate() {
            let expected = spec.stages[i].scale_factor * n;
            let got = st.queries as f64;
            // 5-sigma binomial tolerance.
            let sigma = (n * spec.stages[i].scale_factor
                * (1.0 - spec.stages[i].scale_factor))
                .sqrt()
                .max(1.0);
            assert!(
                (got - expected).abs() <= 5.0 * sigma + 1.0,
                "stage {i}: {got} visits vs expected {expected} (sigma {sigma})"
            );
        }
    });
}

#[test]
fn routing_is_identical_across_configs() {
    // Paper §6: the same trace is reused across comparison points; our
    // routing RNG keys on query index so per-stage visit sets must be
    // identical regardless of the configuration under test.
    prop::check("routing invariance", 15, |rng| {
        let (spec, profiles, config_a) = random_setup(rng);
        let mut config_b = config_a.clone();
        for s in &mut config_b.stages {
            s.replicas += 1 + rng.usize(3);
            s.batch = 1;
        }
        let trace = gamma_trace(60.0, 1.0, 10.0, rng.next_u64());
        let params = SimParams::default();
        let a = simulator::simulate(&spec, &profiles, &config_a, &trace, &params);
        let b = simulator::simulate(&spec, &profiles, &config_b, &trace, &params);
        for (sa, sb) in a.stage_stats.iter().zip(&b.stage_stats) {
            assert_eq!(sa.queries, sb.queries, "visit sets changed with config");
        }
    });
}

#[test]
fn more_replicas_never_hurt_p99() {
    prop::check("replica monotonicity", 15, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(100.0, 2.0, 15.0, rng.next_u64());
        let params = SimParams::default();
        let p99_before = simulator::estimate_p99(&spec, &profiles, &config, &trace, &params);
        let mut bigger = config.clone();
        for s in &mut bigger.stages {
            s.replicas *= 2;
        }
        let p99_after = simulator::estimate_p99(&spec, &profiles, &bigger, &trace, &params);
        assert!(
            p99_after <= p99_before * 1.001 + 1e-6,
            "doubling replicas raised p99: {p99_before} -> {p99_after}"
        );
    });
}

/// Fisher–Yates shuffle on top of the in-repo RNG (the accept bound must
/// hold for *any* arrangement of the latency vector, not sorted input).
fn shuffle(v: &mut [f64], rng: &mut Rng) {
    for i in (1..v.len()).rev() {
        v.swap(i, rng.usize(i + 1));
    }
}

/// Quantile-clamp monotonicity, accept side: whenever at least
/// `ceil(0.99 (n-1)) + 1` samples are at or under the SLO — exactly the
/// guaranteed-hit count at which the fast-accept fires — the interpolated
/// P99 of the *full* vector is at or under the SLO, no matter what the
/// remaining samples are. This is the bit-level contract the engine's
/// accept threshold leans on (the clamp pins P99 <= sorted[ceil(pos)]).
#[test]
fn accept_hit_threshold_bounds_full_quantile() {
    use inferline::util::stats;
    prop::check("accept bound", 200, |rng| {
        let n = 2 + rng.usize(400);
        let slo = 0.05 + rng.f64();
        let hi = (0.99 * (n - 1) as f64).ceil() as usize;
        let need = hi + 1;
        assert!(need <= n, "threshold must be reachable");
        let hits = need + rng.usize(n - need + 1);
        let mut v: Vec<f64> = (0..n)
            .map(|i| {
                if i < hits {
                    // At or under the SLO, including exact ties.
                    if rng.bool(0.2) { slo } else { slo * rng.f64() }
                } else {
                    // Strictly above, from barely to wildly.
                    f64::from_bits(slo.to_bits() + 1) + rng.f64() * 10.0
                }
            })
            .collect();
        shuffle(&mut v, rng);
        let p99 = stats::quantile(&v, 0.99);
        assert!(p99 <= slo, "n={n} hits={hits} p99={p99} > slo={slo}");
    });
}

/// Quantile-clamp monotonicity, abort side (the mirror bound): whenever
/// at least `n - floor(0.99 (n-1))` samples are strictly above the SLO,
/// the interpolated P99 is strictly above it.
#[test]
fn abort_miss_threshold_bounds_full_quantile() {
    use inferline::util::stats;
    prop::check("abort bound", 200, |rng| {
        let n = 2 + rng.usize(400);
        let slo = 0.05 + rng.f64();
        let lo = (0.99 * (n - 1) as f64).floor() as usize;
        let need = (n - lo).max(1);
        let misses = need + rng.usize(n - need + 1);
        let mut v: Vec<f64> = (0..n)
            .map(|i| {
                if i < misses {
                    f64::from_bits(slo.to_bits() + 1) + rng.f64() * 10.0
                } else {
                    slo * rng.f64()
                }
            })
            .collect();
        shuffle(&mut v, rng);
        let p99 = stats::quantile(&v, 0.99);
        assert!(p99 > slo, "n={n} misses={misses} p99={p99} <= slo={slo}");
    });
}

/// The adversarial straddle: every sample within a few ULPs of the SLO,
/// so the interpolation bracket `[sorted[floor(pos)], sorted[ceil(pos)]]`
/// straddles the decision boundary and an unclamped lerp could land an
/// ULP outside it. With the hit threshold met, P99 must still be <= SLO.
#[test]
fn accept_bound_survives_ulp_straddle() {
    use inferline::util::stats;
    prop::check("ulp straddle", 200, |rng| {
        let n = 2 + rng.usize(300);
        let slo = 0.05 + rng.f64();
        let hi = (0.99 * (n - 1) as f64).ceil() as usize;
        let need = hi + 1;
        let hits = need + rng.usize(n - need + 1);
        let mut v: Vec<f64> = (0..n)
            .map(|i| {
                let ulps = rng.usize(4) as u64;
                if i < hits {
                    f64::from_bits(slo.to_bits() - ulps)
                } else {
                    f64::from_bits(slo.to_bits() + 1 + ulps)
                }
            })
            .collect();
        shuffle(&mut v, rng);
        let p99 = stats::quantile(&v, 0.99);
        assert!(p99 <= slo, "n={n} hits={hits} p99={p99:e} > slo={slo:e}");
        // And the mirror: drop below the hit threshold by flooding the
        // tail with misses; the quantile must then sit strictly above.
        let mut w: Vec<f64> = (0..n)
            .map(|_| f64::from_bits(slo.to_bits() + 1 + rng.usize(4) as u64))
            .collect();
        shuffle(&mut w, rng);
        assert!(stats::quantile(&w, 0.99) > slo);
    });
}

/// Simulation-level accept/abort soundness on randomized pipelines: if a
/// budgeted run proves a verdict, the full-trace P99 computed with
/// `util::stats::quantile` agrees — and completed runs reproduce it bit
/// for bit.
#[test]
fn budgeted_verdicts_agree_with_full_quantile_on_random_pipelines() {
    use inferline::util::stats;
    prop::check("budget verdict soundness", 30, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let lambda = 40.0 + rng.f64() * 120.0;
        let trace = gamma_trace(lambda, 0.5 + rng.f64() * 3.0, 8.0, rng.next_u64());
        let params = SimParams::default();
        let slo = 0.002 + rng.f64() * 0.5;
        let check =
            simulator::check_feasible(&spec, &profiles, &config, &trace, slo, &params, None);
        let full = simulator::simulate(&spec, &profiles, &config, &trace, &params);
        let p99 = stats::quantile(&full.latencies, 0.99);
        assert_eq!(check.feasible, p99 <= slo, "verdict diverged (p99 {p99}, slo {slo})");
        if check.accepted {
            assert!(p99 <= slo, "accept fired at slo {slo} but full p99 is {p99}");
        }
        if check.aborted {
            assert!(p99 > slo, "abort fired at slo {slo} but full p99 is {p99}");
        }
        if let Some(budgeted_p99) = check.p99 {
            assert_eq!(budgeted_p99.to_bits(), p99.to_bits());
        }
    });
}

/// Random fault spec mixing all four node kinds over a short horizon.
fn random_fault_spec(rng: &mut Rng, n_stages: usize) -> FaultSpec {
    let n = 1 + rng.usize(3);
    let nodes = (0..n)
        .map(|_| match rng.usize(4) {
            0 => FaultNode::Crash { stage: rng.usize(n_stages), time: rng.f64() * 8.0 },
            1 => FaultNode::CrashStorm {
                stage: if rng.bool(0.5) { Some(rng.usize(n_stages)) } else { None },
                start: rng.f64() * 2.0,
                end: 3.0 + rng.f64() * 5.0,
                rate: 0.1 + rng.f64() * 2.0,
            },
            2 => FaultNode::Slowdown {
                stage: rng.usize(n_stages),
                start: rng.f64() * 2.0,
                end: 3.0 + rng.f64() * 5.0,
                factor: 1.1 + rng.f64() * 2.0,
            },
            _ => FaultNode::Outage {
                stage: rng.usize(n_stages),
                start: rng.f64() * 2.0,
                end: 2.5 + rng.f64() * 2.0,
            },
        })
        .collect();
    FaultSpec {
        nodes,
        max_retries: rng.usize(4) as u32,
        shed_after: if rng.bool(0.5) { Some(0.5 + rng.f64() * 2.0) } else { None },
    }
}

/// Fault-plan compilation is bit-deterministic in (spec, stage count,
/// seed) — the same inputs yield byte-identical plans, with entries
/// time-sorted — so a chaos cell re-run reproduces exactly.
#[test]
fn fault_plan_compilation_is_bit_deterministic() {
    prop::check("fault plan determinism", 40, |rng| {
        let n_stages = 1 + rng.usize(5);
        let spec = random_fault_spec(rng, n_stages);
        let seed = rng.next_u64();
        let a = spec.compile(n_stages, seed);
        let b = spec.compile(n_stages, seed);
        assert_eq!(a.entries.len(), b.entries.len(), "entry count diverged");
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.time.to_bits(), y.time.to_bits(), "entry time bits diverged");
            assert_eq!(x.action, y.action, "entry action diverged");
        }
        assert_eq!(a.max_retries, b.max_retries);
        assert_eq!(a.shed_after.map(f64::to_bits), b.shed_after.map(f64::to_bits));
        for w in a.entries.windows(2) {
            assert!(w[0].time <= w[1].time, "plan not time-sorted");
        }
    });
}

/// Degraded-mode conservation on random pipelines under random chaos:
/// every arrival either completes (exactly once — a retried batch must
/// never double-count its queries) or is counted shed; retries imply
/// crashes; and the whole faulted run is bit-deterministic.
#[test]
fn faulted_runs_conserve_queries_and_are_deterministic() {
    prop::check("faulted conservation", 25, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let fault_spec = random_fault_spec(rng, spec.stages.len());
        let faults = fault_spec.compile(spec.stages.len(), rng.next_u64());
        let lambda = 20.0 + rng.f64() * 60.0;
        let trace = gamma_trace(lambda, 0.5 + rng.f64() * 2.0, 8.0, rng.next_u64());
        let params = SimParams::default();
        let a =
            simulator::simulate_with_faults(&spec, &profiles, &config, &trace, &params, &faults);
        assert_eq!(
            a.latencies.len() as u64 + a.shed,
            trace.len() as u64,
            "query neither completed nor shed (crashes={} retries={})",
            a.crashes,
            a.retries
        );
        if a.retries > 0 {
            assert!(a.crashes > 0, "retries without any crash");
        }
        let b =
            simulator::simulate_with_faults(&spec, &profiles, &config, &trace, &params, &faults);
        assert_eq!(a.latencies.len(), b.latencies.len());
        for (x, y) in a.latencies.iter().zip(&b.latencies) {
            assert_eq!(x.to_bits(), y.to_bits(), "faulted latencies diverged");
        }
        assert_eq!((a.crashes, a.retries, a.shed), (b.crashes, b.retries, b.shed));
    });
}

/// Shed queries count against the miss ceiling, never the hit tally: a
/// root-stage outage spanning the whole trace with an aggressive shed
/// policy sheds every query, and the budgeted feasibility check must
/// call that infeasible — an implementation that credited sheds as hits
/// (or simply ignored them) would prove feasibility of a run that
/// completed nothing.
#[test]
fn all_shed_runs_are_never_proved_feasible() {
    prop::check("shed is never a hit", 15, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(20.0 + rng.f64() * 40.0, 1.0, 6.0, rng.next_u64());
        let fault_spec = FaultSpec {
            nodes: vec![FaultNode::Outage { stage: 0, start: 0.0, end: 16.0 }],
            max_retries: 1,
            shed_after: Some(0.001),
        };
        let faults = fault_spec.compile(spec.stages.len(), rng.next_u64());
        let params = SimParams::default();
        let full =
            simulator::simulate_with_faults(&spec, &profiles, &config, &trace, &params, &faults);
        assert_eq!(full.shed, trace.len() as u64, "outage + aggressive shed left survivors");
        assert!(full.latencies.is_empty(), "shed queries produced completions");
        let check = simulator::check_feasible_with_faults(
            &spec, &profiles, &config, &trace, 0.3, &params, None, &faults,
        );
        assert!(!check.feasible, "an all-shed run was proved feasible");
        assert!(!check.accepted, "fast-accept fired on an all-shed run");
    });
}

/// Probe conservation on random pipelines under random chaos: the
/// recording probe's counters must agree with the engine's — every
/// arrival it saw either completed or was shed, never both, never
/// neither — and its completion count matches the latency vector.
#[test]
fn probe_counters_conserve_queries_under_chaos() {
    use inferline::simulator::probe::RecordingProbe;
    prop::check("probe conservation", 25, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let fault_spec = random_fault_spec(rng, spec.stages.len());
        let faults = fault_spec.compile(spec.stages.len(), rng.next_u64());
        let trace =
            gamma_trace(20.0 + rng.f64() * 60.0, 0.5 + rng.f64() * 2.0, 8.0, rng.next_u64());
        let mut probe = RecordingProbe::new(0.3);
        let result = simulator::simulate_probed(
            &spec,
            &profiles,
            &config,
            &trace,
            &SimParams::default(),
            Some(&faults),
            &mut probe,
        );
        let report = probe.finish();
        assert_eq!(report.arrivals, trace.len(), "probe missed arrivals");
        assert_eq!(
            report.completed + report.shed,
            trace.len(),
            "probe counters leak queries (crashes={})",
            result.crashes
        );
        assert_eq!(report.completed, result.latencies.len(), "probe vs engine completions");
        assert_eq!(report.shed as u64, result.shed, "probe vs engine sheds");
    });
}

/// Span-chain exactness: with the reservoir sized to hold every query,
/// the per-query span latency (`done - arrival`) reproduces the engine's
/// latency vector bit for bit as a multiset (completion order differs
/// from qid order, so compare bit-pattern counts, not sequences).
#[test]
fn probe_spans_reproduce_latencies_bit_exactly() {
    use inferline::simulator::probe::RecordingProbe;
    use std::collections::HashMap;
    prop::check("span-chain latency exactness", 20, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(30.0 + rng.f64() * 60.0, 1.0, 6.0, rng.next_u64());
        let mut probe = RecordingProbe::new(0.3).with_sample_cap(trace.len());
        let result = simulator::simulate_probed(
            &spec,
            &profiles,
            &config,
            &trace,
            &SimParams::default(),
            None,
            &mut probe,
        );
        let report = probe.finish();
        let mut expected: HashMap<u64, isize> = HashMap::new();
        for &l in &result.latencies {
            *expected.entry(l.to_bits()).or_default() += 1;
        }
        let done: Vec<_> = report.spans.iter().filter(|s| !s.shed).collect();
        assert_eq!(done.len(), result.latencies.len(), "cap covers every query");
        for s in done {
            let slot = expected.entry(s.latency().to_bits()).or_default();
            *slot -= 1;
            assert!(*slot >= 0, "span latency {} not produced by the engine", s.latency());
            // Every completed span has a coherent hop chain: finite,
            // ordered timestamps within the query's lifetime.
            for h in &s.hops {
                assert!(h.enqueued >= s.arrival, "hop enqueued before arrival");
                if h.completed.is_finite() {
                    assert!(h.dispatched >= h.enqueued, "dispatch before enqueue");
                    assert!(h.completed >= h.dispatched, "completion before dispatch");
                    assert!(h.completed <= s.done, "hop outlived the query");
                }
            }
        }
        assert!(expected.values().all(|&c| c == 0), "engine latencies missing from spans");
    });
}

#[test]
fn horizon_covers_trace() {
    prop::check("horizon bound", 20, |rng| {
        let (spec, profiles, config) = random_setup(rng);
        let trace = gamma_trace(30.0, 1.0, 10.0, rng.next_u64());
        let result = simulator::simulate(&spec, &profiles, &config, &trace, &SimParams::default());
        let last = *trace.arrivals.last().unwrap();
        assert!(result.horizon >= last, "horizon {} < last arrival {last}", result.horizon);
    });
}
