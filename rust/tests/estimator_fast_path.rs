//! Regression tests for the Estimator fast path: shared routing plans,
//! early-abort budgeted feasibility, O(n) selection quantiles, and the
//! cross-SLO estimator memo-cache. The invariant under test throughout:
//! the fast path changes *nothing* about simulated outcomes or planner
//! decisions — only how fast they are reached.

use inferline::config::pipelines;
use inferline::planner::{EstimatorCache, Planner};
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::{self, RoutingPlan, SimParams};
use inferline::util::rng::Rng;
use inferline::util::stats;
use inferline::workload::{gamma_trace, Trace};

/// Budgeted and unbudgeted `feasible()` agree across all four pipelines,
/// a spread of SLOs, and configurations on both sides of the feasibility
/// boundary (including deliberately under-provisioned ones).
#[test]
fn budgeted_feasibility_matches_unbudgeted() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in pipelines::all() {
        let trace = gamma_trace(120.0, 2.0, 30.0, 7);
        let planner = Planner::new(&spec, &profiles);
        let base = planner.initialize(&trace, 0.5).unwrap();
        let mut candidates = vec![base.clone()];
        for i in 0..spec.stages.len() {
            let mut under = base.clone();
            under.stages[i].replicas = 1;
            candidates.push(under);
        }
        for config in &candidates {
            for &slo in &[0.05, 0.1, 0.2, 0.3, 0.5, 1.0] {
                let fast = simulator::feasible(&spec, &profiles, config, &trace, slo, &params);
                let slow =
                    simulator::feasible_unbudgeted(&spec, &profiles, config, &trace, slo, &params);
                assert_eq!(fast, slow, "{} slo={slo} config={config:?}", spec.name);
            }
        }
    }
}

/// A simulation fed a shared `RoutingPlan` is bit-identical to one that
/// samples routing itself.
#[test]
fn routing_plan_reuse_is_bit_identical() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in pipelines::all() {
        let trace = gamma_trace(100.0, 4.0, 30.0, 11);
        let planner = Planner::new(&spec, &profiles);
        let config = planner.initialize(&trace, 0.5).unwrap();
        let plain = simulator::simulate(&spec, &profiles, &config, &trace, &params);
        let routing = RoutingPlan::build(&spec, &trace, params.routing_seed);
        let shared = simulator::simulate_with_routing(
            &spec,
            &profiles,
            &config,
            &trace,
            &params,
            Some(&routing),
        );
        assert_eq!(plain.latencies.len(), shared.latencies.len(), "{}", spec.name);
        for (a, b) in plain.latencies.iter().zip(&shared.latencies) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}", spec.name);
        }
        assert_eq!(plain.horizon.to_bits(), shared.horizon.to_bits(), "{}", spec.name);
        for (a, b) in plain.completions.iter().zip(&shared.completions) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
    }
}

/// Selection-based quantiles equal sort-based quantiles bit for bit on
/// random samples.
#[test]
fn select_quantile_matches_sort_quantile_on_random_samples() {
    let mut rng = Rng::new(99);
    for n in [1usize, 2, 3, 10, 101, 1000, 4096] {
        let samples: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let by_select = stats::quantile(&samples, q);
            let by_sort = stats::quantile_sorted(&sorted, q);
            assert_eq!(by_select.to_bits(), by_sort.to_bits(), "n={n} q={q}");
        }
    }
}

/// The fast-path planner and the reference (full-simulation) planner emit
/// identical plans on every pipeline.
#[test]
fn fast_path_planner_matches_reference_planner() {
    let profiles = paper_profiles();
    for spec in pipelines::all() {
        let trace = gamma_trace(120.0, 1.0, 30.0, 42);
        let slo = 0.3;
        let fast = Planner::new(&spec, &profiles).plan(&trace, slo).unwrap();
        let reference = Planner::new(&spec, &profiles)
            .with_fast_path(false)
            .plan(&trace, slo)
            .unwrap();
        assert_eq!(fast.config, reference.config, "{}", spec.name);
        assert_eq!(fast.actions_taken, reference.actions_taken, "{}", spec.name);
        assert_eq!(fast.iterations, reference.iterations, "{}", spec.name);
        assert_eq!(
            fast.estimated_p99.to_bits(),
            reference.estimated_p99.to_bits(),
            "{}",
            spec.name
        );
    }
}

/// A hopeless configuration (SLO far below service time) aborts early and
/// still reports the same verdict as the full simulation.
#[test]
fn budgeted_sim_aborts_early_on_mass_misses() {
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let params = SimParams::default();
    let trace = gamma_trace(100.0, 1.0, 60.0, 9);
    let planner = Planner::new(&spec, &profiles);
    let config = planner.initialize(&trace, 0.5).unwrap();
    // 1 ms SLO is below the batch-1 service path: every query misses.
    let check = simulator::check_feasible(&spec, &profiles, &config, &trace, 0.001, &params, None);
    assert!(check.aborted, "expected an early abort");
    assert!(!check.accepted);
    assert!(!check.feasible);
    assert!(check.p99.is_none(), "aborted runs know only the sign of P99 - SLO");
    assert!(!simulator::feasible_unbudgeted(&spec, &profiles, &config, &trace, 0.001, &params));
}

/// The symmetric case: a clearly feasible configuration at a loose SLO
/// fast-accepts without simulating the whole trace, and the verdict
/// matches the full simulation.
#[test]
fn budgeted_sim_accepts_early_on_feasible_config() {
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let params = SimParams::default();
    let trace = gamma_trace(100.0, 1.0, 60.0, 9);
    let planner = Planner::new(&spec, &profiles);
    // Feasible at 250 ms and then over-provisioned further, checked
    // against a 1 s SLO: every query hits comfortably.
    let mut config = planner.initialize(&trace, 0.25).unwrap();
    for s in &mut config.stages {
        s.replicas += 2;
    }
    let check = simulator::check_feasible(&spec, &profiles, &config, &trace, 1.0, &params, None);
    assert!(check.accepted, "expected a fast accept");
    assert!(!check.aborted);
    assert!(check.feasible);
    assert!(check.p99.is_none(), "accepted runs know only the sign of P99 - SLO");
    assert!(simulator::feasible_unbudgeted(&spec, &profiles, &config, &trace, 1.0, &params));
}

/// Loose-SLO searches actually exercise the fast-accept path (telemetry).
#[test]
fn searches_report_early_accepts() {
    let profiles = paper_profiles();
    let mut total_accepts = 0usize;
    for spec in pipelines::all() {
        let trace = gamma_trace(120.0, 1.0, 30.0, 12);
        if let Ok(plan) = Planner::new(&spec, &profiles).plan(&trace, 0.5) {
            total_accepts += plan.telemetry.early_accepts;
        }
    }
    assert!(total_accepts > 0, "no search fast-accepted a single feasible candidate");
}

/// Regression for the late-arrival bug class around both budget proofs:
/// the thresholds must come from the *full* trace length, so stragglers
/// that only arrive after the decision point can never flip a verdict.
/// An accept implementation that reasoned about "completions so far"
/// would accept the burst-only prefix here and then be contradicted by
/// the straggler cohort, whose every query misses.
#[test]
fn straggler_misses_after_accept_window_block_the_accept() {
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let params = SimParams::default();
    // 2000-query burst the config digests comfortably, then 100
    // stragglers arriving in an instantaneous spike 60 s later: the spike
    // queues far past the 300 ms SLO on a single replica chain, dragging
    // the full-trace P99 (position ~0.99 * 2099) into the misses.
    let mut arrivals: Vec<f64> = (0..2000).map(|i| i as f64 * 0.02).collect();
    arrivals.extend((0..100).map(|_| 100.0));
    let trace = Trace::new(arrivals);
    let planner = Planner::new(&spec, &profiles);
    let config = planner.initialize(&gamma_trace(50.0, 1.0, 30.0, 8), 0.3).unwrap();
    let slo = 0.3;
    let check = simulator::check_feasible(&spec, &profiles, &config, &trace, slo, &params, None);
    let reference = simulator::estimate_p99(&spec, &profiles, &config, &trace, &params) <= slo;
    assert_eq!(check.feasible, reference, "straggler cohort flipped the verdict");
    assert!(
        !check.accepted || reference,
        "fast-accept fired on a trace the full simulation rejects"
    );
}

/// The abort-side twin: an overloaded burst proves infeasibility before
/// a straggler cohort (which would all hit) arrives — the early decision
/// must match the full simulation that does serve the stragglers.
#[test]
fn straggler_hits_after_abort_window_do_not_unabort() {
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let params = SimParams::default();
    // 400-query instantaneous spike (hopeless on this config at 50 ms),
    // then 4000 easy stragglers: 99% of the trace hits, but position
    // 0.99 * 4399 lands inside the 400 spike misses.
    let mut arrivals: Vec<f64> = vec![0.0; 400];
    arrivals.extend((0..4000).map(|i| 120.0 + i as f64 * 0.05));
    let trace = Trace::new(arrivals);
    let planner = Planner::new(&spec, &profiles);
    let config = planner.initialize(&gamma_trace(50.0, 1.0, 30.0, 8), 0.3).unwrap();
    let slo = 0.05;
    let check = simulator::check_feasible(&spec, &profiles, &config, &trace, slo, &params, None);
    let full_p99 = simulator::estimate_p99(&spec, &profiles, &config, &trace, &params);
    assert_eq!(check.feasible, full_p99 <= slo, "straggler cohort flipped the verdict");
    assert!(
        !check.aborted || full_p99 > slo,
        "early-abort fired on a trace the full simulation accepts"
    );
}

/// Tight-SLO searches actually exercise the early-abort path (telemetry).
#[test]
fn searches_report_early_aborts() {
    let profiles = paper_profiles();
    let mut total_aborts = 0usize;
    for spec in pipelines::all() {
        let trace = gamma_trace(150.0, 1.0, 30.0, 12);
        for &slo in &[0.1, 0.15] {
            if let Ok(plan) = Planner::new(&spec, &profiles).plan(&trace, slo) {
                total_aborts += plan.telemetry.early_aborts;
            }
        }
    }
    assert!(total_aborts > 0, "no search aborted a single hopeless candidate");
}

/// A cache shared across SLOs produces exactly the plans fresh planners
/// produce — exact-P99 entries answer feasibility at every SLO.
#[test]
fn shared_cache_across_slos_matches_fresh_planners() {
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let cache = EstimatorCache::shared(1 << 16);
    let trace = gamma_trace(100.0, 1.0, 30.0, 5);
    for &slo in &[0.15, 0.25, 0.4] {
        let shared = Planner::new(&spec, &profiles)
            .with_shared_cache(cache.clone())
            .plan(&trace, slo)
            .unwrap();
        let fresh = Planner::new(&spec, &profiles).plan(&trace, slo).unwrap();
        assert_eq!(shared.config, fresh.config, "slo={slo}");
        assert_eq!(shared.actions_taken, fresh.actions_taken, "slo={slo}");
        assert_eq!(
            shared.estimated_p99.to_bits(),
            fresh.estimated_p99.to_bits(),
            "slo={slo}"
        );
    }
    assert!(!cache.is_empty());
}

/// The segmented LRU keeps the cache within its configured bound, and
/// planning still succeeds (evicted entries are simply recomputed).
#[test]
fn estimator_cache_is_bounded() {
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let cache = EstimatorCache::shared(64);
    let trace = gamma_trace(100.0, 1.0, 25.0, 6);
    let unbounded = Planner::new(&spec, &profiles).plan(&trace, 0.3).unwrap();
    for &slo in &[0.2, 0.3, 0.4] {
        let bounded = Planner::new(&spec, &profiles)
            .with_shared_cache(cache.clone())
            .plan(&trace, slo)
            .unwrap();
        if slo == 0.3 {
            assert_eq!(bounded.config, unbounded.config);
        }
        assert!(cache.len() <= 64, "cache grew to {}", cache.len());
    }
    assert!(!cache.is_empty());
}

/// A three-way conditional fan-out tree (deeper and wider than the paper
/// pipelines — the adversarial shape for coalesced delivery, where one
/// finished batch feeds up to three children with per-query visit sets).
fn branchy_tree_spec() -> inferline::config::PipelineSpec {
    let stage = |name: &str, model: &str, s: f64, children: Vec<usize>| {
        inferline::config::StageSpec {
            name: name.to_string(),
            model: model.to_string(),
            scale_factor: s,
            children,
        }
    };
    inferline::config::PipelineSpec {
        name: "branchy-tree".to_string(),
        stages: vec![
            stage("ingest", "preprocess", 1.0, vec![1, 2, 3]),
            stage("detect", "yolo_lite", 0.7, vec![4]),
            stage("translate", "nmt_lite", 0.5, vec![5]),
            stage("fast", "tf_fast", 0.3, vec![]),
            stage("identify", "idmodel_lite", 0.35, vec![6]),
            stage("classify", "resnet_lite", 0.25, vec![]),
            stage("alpr", "alpr_lite", 0.2, vec![]),
        ],
        roots: vec![0],
        framework: inferline::config::Framework::Clipper,
    }
}

/// Routing-plan reuse stays bit-identical on multi-child conditional
/// fan-out, and the budgeted predicate still agrees with the unbudgeted
/// reference there — the DAG twin of the all-pipelines checks above.
#[test]
fn branchy_tree_routing_reuse_and_budgeted_verdicts_are_bit_identical() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = branchy_tree_spec();
    let trace = gamma_trace(110.0, 2.0, 30.0, 13);
    let planner = Planner::new(&spec, &profiles);
    let config = planner.initialize(&trace, 0.5).unwrap();

    let plain = simulator::simulate(&spec, &profiles, &config, &trace, &params);
    let routing = RoutingPlan::build(&spec, &trace, params.routing_seed);
    let shared = simulator::simulate_with_routing(
        &spec,
        &profiles,
        &config,
        &trace,
        &params,
        Some(&routing),
    );
    assert_eq!(plain.latencies.len(), shared.latencies.len());
    for (a, b) in plain.latencies.iter().zip(&shared.latencies) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(plain.horizon.to_bits(), shared.horizon.to_bits());

    let mut under = config.clone();
    for s in &mut under.stages {
        s.replicas = 1;
    }
    for cand in [&config, &under] {
        for &slo in &[0.05, 0.1, 0.2, 0.3, 0.5, 1.0] {
            let fast = simulator::feasible(&spec, &profiles, cand, &trace, slo, &params);
            let slow = simulator::feasible_unbudgeted(&spec, &profiles, cand, &trace, slo, &params);
            assert_eq!(fast, slow, "branchy-tree slo={slo}");
        }
    }
}

/// The open-loop fault entry point with an empty plan is the plain
/// estimator, bit for bit, on every pipeline: identical latencies,
/// completion stream, horizon, cost and per-stage stats, with zero
/// crash/retry/shed telemetry. The fault hook must cost the hot
/// estimator path nothing when no chaos is configured.
#[test]
fn empty_fault_plan_open_loop_is_bit_identical() {
    use inferline::simulator::faults::FaultSpec;
    let profiles = paper_profiles();
    let params = SimParams::default();
    let empty = FaultSpec { nodes: Vec::new(), max_retries: 2, shed_after: None }.compile(8, 3);
    assert!(empty.is_empty());
    for spec in pipelines::all() {
        let trace = gamma_trace(100.0, 4.0, 30.0, 11);
        let planner = Planner::new(&spec, &profiles);
        let config = planner.initialize(&trace, 0.5).unwrap();
        let plain = simulator::simulate(&spec, &profiles, &config, &trace, &params);
        let hooked =
            simulator::simulate_with_faults(&spec, &profiles, &config, &trace, &params, &empty);
        assert_eq!(plain.latencies.len(), hooked.latencies.len(), "{}", spec.name);
        for (a, b) in plain.latencies.iter().zip(&hooked.latencies) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}", spec.name);
        }
        assert_eq!(plain.completions.len(), hooked.completions.len(), "{}", spec.name);
        for (a, b) in plain.completions.iter().zip(&hooked.completions) {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "{}", spec.name);
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{}", spec.name);
        }
        assert_eq!(plain.horizon.to_bits(), hooked.horizon.to_bits(), "{}", spec.name);
        assert_eq!(plain.cost_dollars.to_bits(), hooked.cost_dollars.to_bits(), "{}", spec.name);
        for (i, (s1, s2)) in plain.stage_stats.iter().zip(&hooked.stage_stats).enumerate() {
            assert_eq!(s1.max_queue, s2.max_queue, "{} stage {i}", spec.name);
            assert_eq!(s1.batches, s2.batches, "{} stage {i}", spec.name);
            assert_eq!(s1.queries, s2.queries, "{} stage {i}", spec.name);
            assert_eq!(s1.busy_time.to_bits(), s2.busy_time.to_bits(), "{} stage {i}", spec.name);
        }
        assert_eq!((hooked.crashes, hooked.retries, hooked.shed), (0, 0, 0), "{}", spec.name);
    }
}

/// Windows with zero completions report NaN (no data), not a fabricated
/// perfect-attainment 0.0.
#[test]
fn miss_rate_series_reports_nan_for_empty_windows() {
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let params = SimParams::default();
    // Two bursts separated by a long silent gap.
    let mut arrivals: Vec<f64> = (0..50).map(|i| i as f64 * 0.1).collect();
    arrivals.extend((0..50).map(|i| 60.0 + i as f64 * 0.1));
    let trace = Trace::new(arrivals);
    let planner = Planner::new(&spec, &profiles);
    let config = planner.initialize(&gamma_trace(50.0, 1.0, 20.0, 3), 0.5).unwrap();
    let result = simulator::simulate(&spec, &profiles, &config, &trace, &params);
    let series = result.miss_rate_series(0.5, 5.0);
    assert!(
        series.iter().any(|(_, m)| m.is_nan()),
        "expected empty windows in {series:?}"
    );
    assert!(series.iter().any(|(_, m)| !m.is_nan()));
}
