//! Streaming conformance: the chunked pull-based arrival path must be
//! bit-identical to the materialized path, end to end.
//!
//! Two layers are locked down:
//!
//! 1. **Workload layer**: for every checked-in scenario family (the
//!    whole `scenarios/` grid, both modes) and any chunk size —
//!    including the pathological 1 — draining the family's
//!    [`ArrivalSource`] reproduces `Scenario::build` exactly, arrival
//!    by arrival. This is the spec + seed ⇒ byte-identical-stream
//!    contract of `workload::stream`.
//! 2. **Simulator layer**: a streamed open-loop run
//!    ([`simulate_streamed`]) folds completions into aggregates that
//!    equal — bit-exactly, not approximately — the same folds over the
//!    materialized [`simulate`] result, for conditional-routing and
//!    linear pipelines alike, across chunk sizes, whether the arrivals
//!    come from a replayed trace or a live generator.
//!
//! Plus the memory property the whole refactor exists for: resident
//! query state tracks the in-flight window, not the horizon.

use inferline::config::pipelines;
use inferline::experiments::robustness::{self, FAMILIES};
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::{self, SimParams, SimResult, StreamSummary};
use inferline::workload::stream::{drain, ArrivalSource, GammaSource, MaterializedSource};
use inferline::workload::{gamma_trace, Trace};

/// Every scenario family in the checked-in matrix streams bit-identically
/// to its materialized build — both modes, multiple seeds, chunk sizes
/// down to 1 (the worst case for any buffering bug) and past the
/// internal refill size.
#[test]
fn every_family_streams_bit_identically() {
    for family in FAMILIES {
        let spec = robustness::family_spec(family).unwrap();
        for quick in [true, false] {
            let scenario = spec.scenario_for(quick);
            for seed in [spec.seed, 7] {
                let built = scenario.build(seed).unwrap();
                for chunk in [1usize, 3, 1024] {
                    let mut source = scenario
                        .source(seed)
                        .unwrap_or_else(|e| panic!("{family}: {e}"));
                    let streamed = drain(source.as_mut(), chunk);
                    assert_eq!(
                        streamed.arrivals, built.arrivals,
                        "{family} (quick={quick}, seed={seed}, chunk={chunk}): \
                         streamed arrivals diverge from the materialized build"
                    );
                }
            }
        }
    }
}

/// Fold a materialized result into the aggregate form a streamed run
/// produces, in completion order (the order the engine would fold in).
fn fold(result: &SimResult, n_queries: u64, slo: f64) -> StreamSummary {
    let mut misses = 0u64;
    let mut latency_sum = 0.0f64;
    let mut max_latency = 0.0f64;
    for &l in &result.latencies {
        if l > slo {
            misses += 1;
        }
        latency_sum += l;
        if l > max_latency {
            max_latency = l;
        }
    }
    StreamSummary {
        queries: n_queries,
        completed: result.latencies.len() as u64,
        misses,
        latency_sum,
        max_latency,
        horizon: result.horizon,
        cost_dollars: result.cost_dollars,
        stage_stats: result.stage_stats.clone(),
        peak_queries_resident: 0,
    }
}

fn assert_summary_matches(streamed: &StreamSummary, expected: &StreamSummary, what: &str) {
    assert_eq!(streamed.queries, expected.queries, "{what}: queries");
    assert_eq!(streamed.completed, expected.completed, "{what}: completed");
    assert_eq!(streamed.misses, expected.misses, "{what}: misses");
    assert_eq!(streamed.latency_sum, expected.latency_sum, "{what}: latency_sum");
    assert_eq!(streamed.max_latency, expected.max_latency, "{what}: max_latency");
    assert_eq!(streamed.horizon, expected.horizon, "{what}: horizon");
    assert_eq!(streamed.cost_dollars, expected.cost_dollars, "{what}: cost");
    assert_eq!(streamed.stage_stats.len(), expected.stage_stats.len(), "{what}: stages");
    for (i, (s, e)) in streamed.stage_stats.iter().zip(&expected.stage_stats).enumerate() {
        assert_eq!(s.max_queue, e.max_queue, "{what}: stage {i} max_queue");
        assert_eq!(s.batches, e.batches, "{what}: stage {i} batches");
        assert_eq!(s.queries, e.queries, "{what}: stage {i} queries");
        assert_eq!(s.busy_time, e.busy_time, "{what}: stage {i} busy_time");
        assert_eq!(s.mean_batch, e.mean_batch, "{what}: stage {i} mean_batch");
    }
}

/// A streamed simulation's aggregates equal the materialized run's,
/// bit-exactly, on a conditional-routing pipeline (social-media — the
/// lazy routing sampler must reproduce the plan) and a linear one, for
/// both a replayed materialized source and a live generator source, at
/// chunk sizes 1 (maximal interleaving of pulls) and 4096.
#[test]
fn streamed_simulation_matches_materialized_fold() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let slo = 0.35;
    let (lambda, cv, duration, seed) = (120.0, 2.0, 30.0, 11);
    for spec in [pipelines::social_media(), pipelines::image_processing()] {
        let trace = gamma_trace(lambda, cv, duration, seed);
        let config = Planner::new(&spec, &profiles).initialize(&trace, slo).unwrap();
        let result = simulator::simulate(&spec, &profiles, &config, &trace, &params);
        // Open loop, no faults: every query completes.
        assert_eq!(result.latencies.len(), trace.len(), "{}: incomplete run", spec.name);
        let expected = fold(&result, trace.len() as u64, slo);
        for chunk in [1usize, 4096] {
            let mut sources: Vec<(&str, Box<dyn ArrivalSource>)> = vec![
                ("replayed", Box::new(MaterializedSource::new(trace.clone()))),
                ("generated", Box::new(GammaSource::new(lambda, cv, duration, seed))),
            ];
            for (kind, source) in &mut sources {
                let streamed = simulator::simulate_streamed(
                    &spec,
                    &profiles,
                    &config,
                    source.as_mut(),
                    &params,
                    slo,
                    chunk,
                );
                let what = format!("{} ({kind}, chunk {chunk})", spec.name);
                assert_summary_matches(&streamed, &expected, &what);
                assert!(
                    streamed.peak_queries_resident <= trace.len(),
                    "{what}: residency above trace length"
                );
            }
        }
    }
}

/// The point of streaming: resident query state tracks the in-flight
/// window, not the horizon. A long feasible run must complete with a
/// peak residency far below the total query count (the long-horizon CI
/// smoke asserts the same property at multi-hour scale via peak RSS).
#[test]
fn streamed_residency_tracks_the_window_not_the_horizon() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::image_processing();
    let sample = gamma_trace(200.0, 1.0, 30.0, 42);
    let config = Planner::new(&spec, &profiles).initialize(&sample, 0.35).unwrap();
    let mut source = GammaSource::new(200.0, 1.0, 600.0, 5);
    let summary = simulator::simulate_streamed(
        &spec,
        &profiles,
        &config,
        &mut source,
        &params,
        0.35,
        4096,
    );
    assert!(summary.queries > 100_000, "expected a long stream, got {}", summary.queries);
    assert_eq!(summary.completed, summary.queries);
    assert!(
        summary.peak_queries_resident < summary.queries as usize / 5,
        "peak residency {} of {} queries: compaction is not keeping up",
        summary.peak_queries_resident,
        summary.queries
    );
}

/// The replayed-trace source round-trips `Trace` exactly (also pins the
/// `MaterializedSource` re-export from `workload`).
#[test]
fn materialized_source_roundtrips_via_reexport() {
    let trace = gamma_trace(80.0, 1.0, 5.0, 3);
    let mut src = inferline::workload::MaterializedSource::new(trace.clone());
    let back: Trace = drain(&mut src, 7);
    assert_eq!(back.arrivals, trace.arrivals);
}
