//! Closed-loop Tuner regressions under the scenario workload subsystem
//! (`workload::scenarios`): deterministic-seed checks that a flash-crowd
//! spike triggers envelope scale-up within the detection ladder window,
//! and that the 15 s-stability scale-down returns toward the planned
//! floor — and never undercuts it — once the crowd passes.

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::control::{simulate_controlled, CountingController, NullController};
use inferline::simulator::{self, SimParams};
use inferline::tuner::{Tuner, TunerInputs};
use inferline::workload::{gamma_trace, scenarios};

const SLO: f64 = 0.3;
const BASE: f64 = 100.0;

/// Plan image-processing for nominal BASE-rate traffic and derive the
/// Tuner's inputs, exactly as the serving path does.
fn setup() -> (
    inferline::config::PipelineSpec,
    inferline::profiler::ProfileSet,
    inferline::config::PipelineConfig,
    TunerInputs,
) {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    let sample = gamma_trace(BASE, 1.0, 30.0, 21);
    let plan = Planner::new(&spec, &profiles).plan(&sample, SLO).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
    (spec, profiles, plan.config, inputs)
}

#[test]
fn flash_crowd_triggers_scale_up_within_ladder_window() {
    let (spec, profiles, config, inputs) = setup();
    let spike_start = 60.0;
    // 3x flash crowd: 2 s ramp, 40 s hold, 20 s decay.
    let live = scenarios::flash_crowd_trace(
        BASE,
        300.0,
        spike_start,
        2.0,
        40.0,
        20.0,
        1.0,
        180.0,
        51,
    );
    let mut tuner = Tuner::new(inputs);
    let mut counting = CountingController::new(&mut tuner);
    let tuned = simulate_controlled(
        &spec, &profiles, &config, &live, &SimParams::default(), &mut counting,
    );
    assert!(counting.scale_ups > 0, "flash crowd produced no scale-up actions");

    // The spike demands far more capacity than any baseline-jitter
    // excursion: the first provisioning level clearly above the pre-spike
    // maximum must appear within the envelope ladder's largest window
    // (60 s) plus one control tick of the spike's onset.
    let baseline_max = tuned
        .replica_timeline
        .iter()
        .filter(|&&(t, _)| t < spike_start)
        .map(|&(_, n)| n)
        .max()
        .expect("timeline starts at t=0");
    let first_big = tuned
        .replica_timeline
        .iter()
        .find(|&&(_, n)| n >= baseline_max + 2)
        .expect("spike never drove provisioning past the baseline excursions")
        .0;
    assert!(
        first_big <= spike_start + 61.0,
        "scale-up landed at t={first_big}, outside the ladder window after t={spike_start}"
    );

    // And the closed loop beats the static plan on SLO attainment.
    let mut null = NullController;
    let static_run = simulate_controlled(
        &spec, &profiles, &config, &live, &SimParams::default(), &mut null,
    );
    assert!(
        tuned.miss_rate(SLO) < static_run.miss_rate(SLO),
        "tuned miss {} should beat static {}",
        tuned.miss_rate(SLO),
        static_run.miss_rate(SLO)
    );
}

#[test]
fn scale_down_returns_to_planned_floor_after_flash_crowd() {
    let (spec, profiles, config, inputs) = setup();
    // 4x crowd early in a long trace: ~230 s of stable base traffic
    // remain after the decay, many 15 s stability windows.
    let live = scenarios::flash_crowd_trace(
        BASE,
        400.0,
        40.0,
        2.0,
        30.0,
        10.0,
        1.0,
        300.0,
        53,
    );
    let mut tuner = Tuner::new(inputs);
    let mut counting = CountingController::new(&mut tuner);
    let result = simulate_controlled(
        &spec, &profiles, &config, &live, &SimParams::default(), &mut counting,
    );
    assert!(counting.scale_ups > 0, "never scaled up");
    assert!(counting.scale_downs > 0, "never scaled down");

    let planned: usize = config.stages.iter().map(|s| s.replicas).sum();
    let max_seen = result.replica_timeline.iter().map(|&(_, n)| n).max().unwrap();
    let final_count = result.replica_timeline.last().unwrap().1;
    assert!(
        max_seen >= planned + planned / 2,
        "4x crowd only reached {max_seen} vs planned {planned}"
    );
    // Substantial descent back toward the planned configuration once the
    // trailing-rate statistic forgets the spike.
    assert!(
        final_count < max_seen && (final_count as f64) < 0.8 * max_seen as f64,
        "stuck at spike provisioning: {max_seen} -> {final_count} (planned {planned})"
    );
    // The planned floor is never undercut — before, during, or after.
    for &(t, n) in &result.replica_timeline {
        assert!(n >= planned, "t={t}: provisioned {n} under planned floor {planned}");
    }
}

#[test]
fn flash_crowd_runs_are_deterministic_per_seed() {
    let (spec, profiles, config, inputs) = setup();
    let live = scenarios::flash_crowd_trace(
        BASE, 300.0, 30.0, 2.0, 20.0, 10.0, 1.0, 120.0, 57,
    );
    let run = |inputs: TunerInputs| {
        let mut tuner = Tuner::new(inputs);
        simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut tuner,
        )
    };
    let a = run(inputs.clone());
    let b = run(inputs);
    assert_eq!(a.latencies, b.latencies);
    assert_eq!(a.replica_timeline, b.replica_timeline);
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits());
}
