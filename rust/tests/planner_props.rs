//! Property tests of the Planner's §4.3 guarantees over randomized
//! workloads and SLOs on the real paper pipelines:
//!
//!  1. If a feasible configuration exists, the planner returns one the
//!     Estimator deems feasible.
//!  2. At termination, no single action (batch x2 / replica −1 /
//!     hardware downgrade) both reduces cost and stays feasible.
//!  3. Sensitivity trends: cost is monotone non-increasing in SLO and
//!     non-decreasing in λ (within greedy tolerance, Fig 9).

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::{self, SimParams};
use inferline::util::prop;
use inferline::workload::gamma_trace;

fn random_pipeline(rng: &mut inferline::util::rng::Rng) -> inferline::config::PipelineSpec {
    let all = pipelines::all();
    all[rng.usize(all.len())].clone()
}

#[test]
fn plans_are_feasible() {
    prop::check("plan feasibility", 12, |rng| {
        let spec = random_pipeline(rng);
        let profiles = paper_profiles();
        let lambda = 40.0 + rng.f64() * 160.0;
        let cv = if rng.bool(0.5) { 1.0 } else { 4.0 };
        let slo = 0.2 + rng.f64() * 0.4;
        let trace = gamma_trace(lambda, cv, 30.0, rng.next_u64());
        match Planner::new(&spec, &profiles).plan(&trace, slo) {
            Ok(plan) => {
                let p99 = simulator::estimate_p99(
                    &spec, &profiles, &plan.config, &trace, &SimParams::default(),
                );
                assert!(p99 <= slo + 1e-9, "{}: p99 {p99} > slo {slo}", spec.name);
                assert!((plan.cost_per_hour - plan.config.cost_per_hour()).abs() < 1e-9);
            }
            Err(e) => {
                // Only acceptable if even the latency-minimizing config
                // can't make the SLO.
                let planner = Planner::new(&spec, &profiles);
                assert!(
                    planner.initialize(&trace, slo).is_err(),
                    "{}: plan failed ({e}) but initialize succeeds",
                    spec.name
                );
            }
        }
    });
}

#[test]
fn termination_means_no_single_cheaper_feasible_action() {
    prop::check("greedy termination guarantee", 8, |rng| {
        let spec = random_pipeline(rng);
        let profiles = paper_profiles();
        let lambda = 50.0 + rng.f64() * 100.0;
        let slo = 0.25 + rng.f64() * 0.25;
        let trace = gamma_trace(lambda, 1.0, 30.0, rng.next_u64());
        let planner = Planner::new(&spec, &profiles);
        let Ok(plan) = planner.plan(&trace, slo) else { return };
        for stage in 0..spec.n_stages() {
            for cand in [
                planner.try_increase_batch(&plan.config, stage, &trace, slo),
                planner.try_remove_replica(&plan.config, stage, &trace, slo),
                planner.try_downgrade_hw(&plan.config, stage, &trace, slo),
            ]
            .into_iter()
            .flatten()
            {
                assert!(
                    cand.cost_per_hour() >= plan.cost_per_hour - 1e-9,
                    "{} stage {stage}: residual action reduces cost {} -> {}",
                    spec.name,
                    plan.cost_per_hour,
                    cand.cost_per_hour()
                );
            }
        }
    });
}

#[test]
fn cost_monotone_in_slo() {
    prop::check("cost vs slo", 6, |rng| {
        let spec = random_pipeline(rng);
        let profiles = paper_profiles();
        let lambda = 60.0 + rng.f64() * 80.0;
        let trace = gamma_trace(lambda, 1.0, 30.0, rng.next_u64());
        let planner = Planner::new(&spec, &profiles);
        let mut last_cost = f64::INFINITY;
        for slo in [0.15, 0.3, 0.6] {
            if let Ok(plan) = planner.plan(&trace, slo) {
                // Greedy search is not globally optimal (the paper notes
                // occasional sub-optimal configs in Fig 9); allow 25% slack.
                assert!(
                    plan.cost_per_hour <= last_cost * 1.25 + 1e-9,
                    "{}: slo {slo} cost {} vs previous {last_cost}",
                    spec.name,
                    plan.cost_per_hour
                );
                last_cost = last_cost.min(plan.cost_per_hour);
            }
        }
    });
}

#[test]
fn cost_monotone_in_lambda() {
    prop::check("cost vs lambda", 6, |rng| {
        let spec = random_pipeline(rng);
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let slo = 0.3;
        let seed = rng.next_u64();
        let mut last_cost = 0.0f64;
        for lambda in [50.0, 120.0, 250.0] {
            let trace = gamma_trace(lambda, 1.0, 30.0, seed);
            if let Ok(plan) = planner.plan(&trace, slo) {
                assert!(
                    plan.cost_per_hour >= last_cost * 0.8 - 1e-9,
                    "{}: λ {lambda} cost {} fell below previous {last_cost}",
                    spec.name,
                    plan.cost_per_hour
                );
                last_cost = last_cost.max(plan.cost_per_hour);
            }
        }
    });
}

#[test]
fn burstier_workloads_cost_at_least_as_much() {
    prop::check("cost vs cv", 5, |rng| {
        let spec = random_pipeline(rng);
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let slo = 0.3;
        let lambda = 80.0 + rng.f64() * 80.0;
        let seed = rng.next_u64();
        let calm = planner.plan(&gamma_trace(lambda, 1.0, 40.0, seed), slo);
        let bursty = planner.plan(&gamma_trace(lambda, 4.0, 40.0, seed), slo);
        if let (Ok(c), Ok(b)) = (calm, bursty) {
            assert!(
                b.cost_per_hour >= c.cost_per_hour * 0.9 - 1e-9,
                "{}: cv4 cost {} << cv1 cost {}",
                spec.name,
                b.cost_per_hour,
                c.cost_per_hour
            );
        }
    });
}
