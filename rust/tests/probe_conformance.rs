//! Conformance suite for the telemetry probe layer.
//!
//! The probe contract (`simulator::probe`) is that observation is free:
//! a probe-less run takes zero probe branches, and an attached probe is
//! read-only — it may record anything but can perturb nothing. This
//! suite locks both halves down over the same grids the other
//! conformance suites use (every pipeline shape, open-loop and
//! controlled, fault-free and under a crash storm):
//!
//! * a [`NoopProbe`] run and a [`RecordingProbe`] run are bit-identical
//!   to the probe-less engine on every query-visible outcome (latencies,
//!   completions, horizon, per-stage stats, cost, fault counters);
//! * the recorded artifacts themselves are deterministic: the same seed
//!   produces byte-identical Chrome traces, time-series CSV rows and
//!   attribution tables across repeated runs.

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::control::{simulate_controlled, simulate_controlled_probed};
use inferline::simulator::faults::{FaultNode, FaultSpec};
use inferline::simulator::probe::{NoopProbe, RecordingProbe};
use inferline::simulator::{self, SimParams, SimResult};
use inferline::tuner::{Tuner, TunerInputs};
use inferline::workload::{scenarios, Trace};

const SLO: f64 = 0.3;

/// A flash crowd drives real queueing, retries under faults, and tuner
/// actions in controlled runs — every probe hook fires.
fn crowd_trace(seed: u64) -> Trace {
    scenarios::flash_crowd_trace(90.0, 280.0, 10.0, 2.0, 8.0, 4.0, 1.0, 45.0, seed)
}

/// Assert two results agree bit-for-bit on everything a query observes.
fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.latencies.len(), b.latencies.len(), "{ctx}: completion count");
    for (i, (x, y)) in a.latencies.iter().zip(&b.latencies).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: latency #{i}");
    }
    assert_eq!(a.completions.len(), b.completions.len(), "{ctx}: completions");
    for ((t1, l1), (t2, l2)) in a.completions.iter().zip(&b.completions) {
        assert_eq!(t1.to_bits(), t2.to_bits(), "{ctx}: completion time");
        assert_eq!(l1.to_bits(), l2.to_bits(), "{ctx}: completion latency");
    }
    assert_eq!(a.horizon.to_bits(), b.horizon.to_bits(), "{ctx}: horizon");
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits(), "{ctx}: cost");
    assert_eq!(a.crashes, b.crashes, "{ctx}: crashes");
    assert_eq!(a.retries, b.retries, "{ctx}: retries");
    assert_eq!(a.shed, b.shed, "{ctx}: shed");
    assert_eq!(a.stage_stats.len(), b.stage_stats.len(), "{ctx}: stage count");
    for (i, (s1, s2)) in a.stage_stats.iter().zip(&b.stage_stats).enumerate() {
        assert_eq!(s1.max_queue, s2.max_queue, "{ctx}: stage {i} max_queue");
        assert_eq!(s1.batches, s2.batches, "{ctx}: stage {i} batches");
        assert_eq!(s1.queries, s2.queries, "{ctx}: stage {i} queries");
        assert_eq!(s1.busy_time.to_bits(), s2.busy_time.to_bits(), "{ctx}: stage {i} busy");
        assert_eq!(s1.mean_batch.to_bits(), s2.mean_batch.to_bits(), "{ctx}: stage {i} batch");
    }
}

/// Open-loop grid: on every pipeline shape, a `NoopProbe` run and a full
/// `RecordingProbe` run must match the probe-less simulation bit for bit.
#[test]
fn probed_open_loop_is_bit_identical_on_every_pipeline() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in pipelines::all() {
        let live = crowd_trace(31);
        let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
        let plain = simulator::simulate(&spec, &profiles, &config, &live, &params);
        let mut noop = NoopProbe;
        let nooped =
            simulator::simulate_probed(&spec, &profiles, &config, &live, &params, None, &mut noop);
        assert_bit_identical(&plain, &nooped, &format!("{}: noop probe", spec.name));
        let mut rec = RecordingProbe::new(SLO).with_cadence(0.5);
        let recorded =
            simulator::simulate_probed(&spec, &profiles, &config, &live, &params, None, &mut rec);
        assert_bit_identical(&plain, &recorded, &format!("{}: recording probe", spec.name));
        let report = rec.finish();
        assert_eq!(report.completed, plain.latencies.len(), "{}: span count", spec.name);
        assert!(!report.series.is_empty(), "{}: no time-series points", spec.name);
    }
}

/// Faulted grid: a crash storm with retries and shedding exercises the
/// shed/retry/fault hooks; the probed runs must still be bit-identical,
/// fault counters included.
#[test]
fn probed_faulted_run_is_bit_identical() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::image_processing();
    let live = crowd_trace(7);
    let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
    let storm = FaultSpec {
        nodes: vec![FaultNode::CrashStorm {
            stage: None,
            start: 0.0,
            end: live.duration(),
            rate: 0.2,
        }],
        max_retries: 1,
        shed_after: Some(0.5),
    };
    let plan = storm.compile(spec.n_stages(), 13);
    let plain = simulator::simulate_with_faults(&spec, &profiles, &config, &live, &params, &plan);
    assert!(plain.crashes > 0, "storm must apply crashes for the grid to mean anything");
    let mut noop = NoopProbe;
    let nooped = simulator::simulate_probed(
        &spec,
        &profiles,
        &config,
        &live,
        &params,
        Some(&plan),
        &mut noop,
    );
    assert_bit_identical(&plain, &nooped, "faulted: noop probe");
    let mut rec = RecordingProbe::new(SLO);
    let recorded = simulator::simulate_probed(
        &spec,
        &profiles,
        &config,
        &live,
        &params,
        Some(&plan),
        &mut rec,
    );
    assert_bit_identical(&plain, &recorded, "faulted: recording probe");
    let report = rec.finish();
    assert_eq!(report.shed, plain.shed as usize, "probe shed counter matches engine");
    assert!(
        report.instants.iter().any(|i| i.name.starts_with("fault:")),
        "crash storm left no fault instants in the trace"
    );
}

/// Controlled grid: with the real Tuner in the loop (scale-ups during
/// the flash crowd land as probe instants), probed and plain controlled
/// runs must be bit-identical.
#[test]
fn probed_controlled_run_is_bit_identical() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::social_media();
    let live = crowd_trace(17);
    let sample = crowd_trace(18);
    let plan = Planner::new(&spec, &profiles).plan(&sample, SLO).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let mk_tuner =
        || Tuner::new(TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st));
    let mut plain_tuner = mk_tuner();
    let plain = simulate_controlled(
        &spec,
        &profiles,
        &plan.config,
        &live,
        &params,
        &mut plain_tuner,
    );
    let mut probed_tuner = mk_tuner();
    let mut rec = RecordingProbe::new(SLO);
    let recorded = simulate_controlled_probed(
        &spec,
        &profiles,
        &plan.config,
        &live,
        &params,
        &mut probed_tuner,
        None,
        &mut rec,
    );
    assert_bit_identical(&plain, &recorded, "controlled: recording probe");
    let report = rec.finish();
    assert!(
        report.instants.iter().any(|i| i.name.starts_with("tuner:")),
        "flash crowd produced no tuner-action instants"
    );
}

/// Determinism: two recording runs of the same cell produce byte-identical
/// artifacts — Chrome trace, series CSV and attribution JSON.
#[test]
fn recorded_artifacts_are_bit_reproducible() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::image_processing();
    let live = crowd_trace(23);
    let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
    let run = || {
        let mut rec = RecordingProbe::new(0.05).with_cadence(0.5);
        simulator::simulate_probed(&spec, &profiles, &config, &live, &params, None, &mut rec);
        rec.finish()
    };
    let a = run();
    let b = run();
    assert_eq!(a.chrome_trace().to_string(), b.chrome_trace().to_string());
    assert_eq!(a.series_csv(), b.series_csv());
    assert_eq!(a.attribution.to_json().to_string(), b.attribution.to_json().to_string());
    // The tight SLO guarantees misses, so the attribution table is live.
    assert!(a.attribution.missed > 0, "0.05s SLO on a flash crowd must miss");
    assert!(a.attribution.blame_stage().is_some());
    let blamed = a.attribution.blame_stage().unwrap();
    let share = a.attribution.blame_share(blamed);
    assert!(share > 0.0 && share <= 1.0, "blame share {share} out of range");
}
