//! Conformance suite for the telemetry probe layer.
//!
//! The probe contract (`simulator::probe`) is that observation is free:
//! a probe-less run takes zero probe branches, and an attached probe is
//! read-only — it may record anything but can perturb nothing. This
//! suite locks both halves down over the same grids the other
//! conformance suites use (every pipeline shape, open-loop and
//! controlled, fault-free and under a crash storm):
//!
//! * a [`NoopProbe`] run and a [`RecordingProbe`] run are bit-identical
//!   to the probe-less engine on every query-visible outcome (latencies,
//!   completions, horizon, per-stage stats, cost, fault counters);
//! * the recorded artifacts themselves are deterministic: the same seed
//!   produces byte-identical Chrome traces, time-series CSV rows and
//!   attribution tables across repeated runs.
//!
//! Since the entry-point unification, every `simulate*` free function is
//! a thin wrapper over the [`SimRun`] builder. The suite therefore also
//! locks down builder-vs-wrapper bit-identity for all nine wrappers
//! (open-loop, routed, budgeted, faulted, probed, controlled, streamed),
//! so neither path can drift from the other.

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::control::{
    simulate_controlled, simulate_controlled_probed, simulate_controlled_with_faults,
};
use inferline::simulator::faults::{FaultNode, FaultSpec};
use inferline::simulator::probe::{NoopProbe, RecordingProbe};
use inferline::simulator::{self, RoutingPlan, SimParams, SimResult, SimRun, StreamSummary};
use inferline::tuner::{Tuner, TunerInputs};
use inferline::workload::stream::GammaSource;
use inferline::workload::{scenarios, Trace};

const SLO: f64 = 0.3;

/// A flash crowd drives real queueing, retries under faults, and tuner
/// actions in controlled runs — every probe hook fires.
fn crowd_trace(seed: u64) -> Trace {
    scenarios::flash_crowd_trace(90.0, 280.0, 10.0, 2.0, 8.0, 4.0, 1.0, 45.0, seed)
}

/// Assert two results agree bit-for-bit on everything a query observes.
fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.latencies.len(), b.latencies.len(), "{ctx}: completion count");
    for (i, (x, y)) in a.latencies.iter().zip(&b.latencies).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: latency #{i}");
    }
    assert_eq!(a.completions.len(), b.completions.len(), "{ctx}: completions");
    for ((t1, l1), (t2, l2)) in a.completions.iter().zip(&b.completions) {
        assert_eq!(t1.to_bits(), t2.to_bits(), "{ctx}: completion time");
        assert_eq!(l1.to_bits(), l2.to_bits(), "{ctx}: completion latency");
    }
    assert_eq!(a.horizon.to_bits(), b.horizon.to_bits(), "{ctx}: horizon");
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits(), "{ctx}: cost");
    assert_eq!(a.crashes, b.crashes, "{ctx}: crashes");
    assert_eq!(a.retries, b.retries, "{ctx}: retries");
    assert_eq!(a.shed, b.shed, "{ctx}: shed");
    assert_eq!(a.stage_stats.len(), b.stage_stats.len(), "{ctx}: stage count");
    for (i, (s1, s2)) in a.stage_stats.iter().zip(&b.stage_stats).enumerate() {
        assert_eq!(s1.max_queue, s2.max_queue, "{ctx}: stage {i} max_queue");
        assert_eq!(s1.batches, s2.batches, "{ctx}: stage {i} batches");
        assert_eq!(s1.queries, s2.queries, "{ctx}: stage {i} queries");
        assert_eq!(s1.busy_time.to_bits(), s2.busy_time.to_bits(), "{ctx}: stage {i} busy");
        assert_eq!(s1.mean_batch.to_bits(), s2.mean_batch.to_bits(), "{ctx}: stage {i} batch");
    }
}

/// Open-loop grid: on every pipeline shape, a `NoopProbe` run and a full
/// `RecordingProbe` run must match the probe-less simulation bit for bit.
#[test]
fn probed_open_loop_is_bit_identical_on_every_pipeline() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in pipelines::all() {
        let live = crowd_trace(31);
        let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
        let plain = simulator::simulate(&spec, &profiles, &config, &live, &params);
        let mut noop = NoopProbe;
        let nooped =
            simulator::simulate_probed(&spec, &profiles, &config, &live, &params, None, &mut noop);
        assert_bit_identical(&plain, &nooped, &format!("{}: noop probe", spec.name));
        let mut rec = RecordingProbe::new(SLO).with_cadence(0.5);
        let recorded =
            simulator::simulate_probed(&spec, &profiles, &config, &live, &params, None, &mut rec);
        assert_bit_identical(&plain, &recorded, &format!("{}: recording probe", spec.name));
        let report = rec.finish();
        assert_eq!(report.completed, plain.latencies.len(), "{}: span count", spec.name);
        assert!(!report.series.is_empty(), "{}: no time-series points", spec.name);
    }
}

/// Faulted grid: a crash storm with retries and shedding exercises the
/// shed/retry/fault hooks; the probed runs must still be bit-identical,
/// fault counters included.
#[test]
fn probed_faulted_run_is_bit_identical() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::image_processing();
    let live = crowd_trace(7);
    let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
    let storm = FaultSpec {
        nodes: vec![FaultNode::CrashStorm {
            stage: None,
            start: 0.0,
            end: live.duration(),
            rate: 0.2,
        }],
        max_retries: 1,
        shed_after: Some(0.5),
    };
    let plan = storm.compile(spec.n_stages(), 13);
    let plain = simulator::simulate_with_faults(&spec, &profiles, &config, &live, &params, &plan);
    assert!(plain.crashes > 0, "storm must apply crashes for the grid to mean anything");
    let mut noop = NoopProbe;
    let nooped = simulator::simulate_probed(
        &spec,
        &profiles,
        &config,
        &live,
        &params,
        Some(&plan),
        &mut noop,
    );
    assert_bit_identical(&plain, &nooped, "faulted: noop probe");
    let mut rec = RecordingProbe::new(SLO);
    let recorded = simulator::simulate_probed(
        &spec,
        &profiles,
        &config,
        &live,
        &params,
        Some(&plan),
        &mut rec,
    );
    assert_bit_identical(&plain, &recorded, "faulted: recording probe");
    let report = rec.finish();
    assert_eq!(report.shed, plain.shed as usize, "probe shed counter matches engine");
    assert!(
        report.instants.iter().any(|i| i.name.starts_with("fault:")),
        "crash storm left no fault instants in the trace"
    );
}

/// Controlled grid: with the real Tuner in the loop (scale-ups during
/// the flash crowd land as probe instants), probed and plain controlled
/// runs must be bit-identical.
#[test]
fn probed_controlled_run_is_bit_identical() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::social_media();
    let live = crowd_trace(17);
    let sample = crowd_trace(18);
    let plan = Planner::new(&spec, &profiles).plan(&sample, SLO).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let mk_tuner =
        || Tuner::new(TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st));
    let mut plain_tuner = mk_tuner();
    let plain = simulate_controlled(
        &spec,
        &profiles,
        &plan.config,
        &live,
        &params,
        &mut plain_tuner,
    );
    let mut probed_tuner = mk_tuner();
    let mut rec = RecordingProbe::new(SLO);
    let recorded = simulate_controlled_probed(
        &spec,
        &profiles,
        &plan.config,
        &live,
        &params,
        &mut probed_tuner,
        None,
        &mut rec,
    );
    assert_bit_identical(&plain, &recorded, "controlled: recording probe");
    let report = rec.finish();
    assert!(
        report.instants.iter().any(|i| i.name.starts_with("tuner:")),
        "flash crowd produced no tuner-action instants"
    );
}

/// Determinism: two recording runs of the same cell produce byte-identical
/// artifacts — Chrome trace, series CSV and attribution JSON.
#[test]
fn recorded_artifacts_are_bit_reproducible() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::image_processing();
    let live = crowd_trace(23);
    let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
    let run = || {
        let mut rec = RecordingProbe::new(0.05).with_cadence(0.5);
        simulator::simulate_probed(&spec, &profiles, &config, &live, &params, None, &mut rec);
        rec.finish()
    };
    let a = run();
    let b = run();
    assert_eq!(a.chrome_trace().to_string(), b.chrome_trace().to_string());
    assert_eq!(a.series_csv(), b.series_csv());
    assert_eq!(a.attribution.to_json().to_string(), b.attribution.to_json().to_string());
    // The tight SLO guarantees misses, so the attribution table is live.
    assert!(a.attribution.missed > 0, "0.05s SLO on a flash crowd must miss");
    assert!(a.attribution.blame_stage().is_some());
    let blamed = a.attribution.blame_stage().unwrap();
    let share = a.attribution.blame_share(blamed);
    assert!(share > 0.0 && share <= 1.0, "blame share {share} out of range");
}

/// Assert two stream summaries agree bit-for-bit.
fn assert_stream_bit_identical(a: &StreamSummary, b: &StreamSummary, ctx: &str) {
    assert_eq!(a.queries, b.queries, "{ctx}: queries");
    assert_eq!(a.completed, b.completed, "{ctx}: completed");
    assert_eq!(a.misses, b.misses, "{ctx}: misses");
    assert_eq!(a.latency_sum.to_bits(), b.latency_sum.to_bits(), "{ctx}: latency sum");
    assert_eq!(a.max_latency.to_bits(), b.max_latency.to_bits(), "{ctx}: max latency");
    assert_eq!(a.horizon.to_bits(), b.horizon.to_bits(), "{ctx}: horizon");
    assert_eq!(a.cost_dollars.to_bits(), b.cost_dollars.to_bits(), "{ctx}: cost");
    assert_eq!(a.stage_stats.len(), b.stage_stats.len(), "{ctx}: stage count");
    for (i, (s1, s2)) in a.stage_stats.iter().zip(&b.stage_stats).enumerate() {
        assert_eq!(s1.max_queue, s2.max_queue, "{ctx}: stage {i} max_queue");
        assert_eq!(s1.batches, s2.batches, "{ctx}: stage {i} batches");
        assert_eq!(s1.queries, s2.queries, "{ctx}: stage {i} queries");
        assert_eq!(s1.busy_time.to_bits(), s2.busy_time.to_bits(), "{ctx}: stage {i} busy");
        assert_eq!(s1.mean_batch.to_bits(), s2.mean_batch.to_bits(), "{ctx}: stage {i} batch");
    }
}

/// Open-loop wrappers vs the builder, on every pipeline shape: `simulate`,
/// `simulate_with_routing`, `simulate_budgeted`, `simulate_with_faults`,
/// `simulate_budgeted_with_faults` and `simulate_probed` must each be
/// bit-identical to the equivalent [`SimRun`] chain.
#[test]
fn sim_run_builder_matches_open_loop_wrappers_bit_identically() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    for spec in pipelines::all() {
        let live = crowd_trace(41);
        let config = Planner::new(&spec, &profiles).initialize(&live, SLO).unwrap();
        let routing = RoutingPlan::build(&spec, &live, params.routing_seed);
        let storm = FaultSpec {
            nodes: vec![FaultNode::CrashStorm {
                stage: None,
                start: 0.0,
                end: live.duration(),
                rate: 0.1,
            }],
            max_retries: 1,
            shed_after: Some(0.5),
        };
        let faults = storm.compile(spec.n_stages(), 29);

        let w = simulator::simulate(&spec, &profiles, &config, &live, &params);
        let b = SimRun::new(&spec, &profiles, &config, &params).run(&live).0;
        assert_bit_identical(&w, &b, &format!("{}: simulate", spec.name));

        let w = simulator::simulate_with_routing(
            &spec,
            &profiles,
            &config,
            &live,
            &params,
            Some(&routing),
        );
        let b = SimRun::new(&spec, &profiles, &config, &params).routing(&routing).run(&live).0;
        assert_bit_identical(&w, &b, &format!("{}: simulate_with_routing", spec.name));

        let (w, wv) = simulator::simulate_budgeted(
            &spec,
            &profiles,
            &config,
            &live,
            SLO,
            &params,
            Some(&routing),
        );
        let (b, bv) = SimRun::new(&spec, &profiles, &config, &params)
            .routing(&routing)
            .budget(SLO)
            .run(&live);
        assert_bit_identical(&w, &b, &format!("{}: simulate_budgeted", spec.name));
        assert_eq!(wv, bv, "{}: budget verdict", spec.name);

        let w = simulator::simulate_with_faults(&spec, &profiles, &config, &live, &params, &faults);
        let b = SimRun::new(&spec, &profiles, &config, &params).faults(&faults).run(&live).0;
        assert_bit_identical(&w, &b, &format!("{}: simulate_with_faults", spec.name));

        let (w, wv) = simulator::simulate_budgeted_with_faults(
            &spec,
            &profiles,
            &config,
            &live,
            SLO,
            &params,
            Some(&routing),
            &faults,
        );
        let (b, bv) = SimRun::new(&spec, &profiles, &config, &params)
            .routing(&routing)
            .faults(&faults)
            .budget(SLO)
            .run(&live);
        assert_bit_identical(&w, &b, &format!("{}: simulate_budgeted_with_faults", spec.name));
        assert_eq!(wv, bv, "{}: faulted budget verdict", spec.name);

        let mut wp = RecordingProbe::new(SLO);
        let w = simulator::simulate_probed(
            &spec,
            &profiles,
            &config,
            &live,
            &params,
            Some(&faults),
            &mut wp,
        );
        let mut bp = RecordingProbe::new(SLO);
        let b = SimRun::new(&spec, &profiles, &config, &params)
            .faults(&faults)
            .probe(&mut bp)
            .run(&live)
            .0;
        assert_bit_identical(&w, &b, &format!("{}: simulate_probed", spec.name));
    }
}

/// Controlled and streamed wrappers vs the builder: `simulate_controlled`,
/// `simulate_controlled_with_faults`, `simulate_controlled_probed` and
/// `simulate_streamed` must each match the equivalent [`SimRun`] chain,
/// with a fresh (identically seeded) Tuner or arrival source per run.
#[test]
fn sim_run_builder_matches_controlled_and_streamed_wrappers() {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let spec = pipelines::social_media();
    let live = crowd_trace(43);
    let sample = crowd_trace(44);
    let plan = Planner::new(&spec, &profiles).plan(&sample, SLO).unwrap();
    let st = simulator::service_time(&spec, &profiles, &plan.config);
    let mk_tuner =
        || Tuner::new(TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st));
    let storm = FaultSpec {
        nodes: vec![FaultNode::CrashStorm {
            stage: None,
            start: 0.0,
            end: live.duration(),
            rate: 0.1,
        }],
        max_retries: 1,
        shed_after: Some(0.5),
    };
    let faults = storm.compile(spec.n_stages(), 37);

    let mut t = mk_tuner();
    let w = simulate_controlled(&spec, &profiles, &plan.config, &live, &params, &mut t);
    let mut t = mk_tuner();
    let b = SimRun::new(&spec, &profiles, &plan.config, &params).controller(&mut t).run(&live).0;
    assert_bit_identical(&w, &b, "simulate_controlled");

    let mut t = mk_tuner();
    let w = simulate_controlled_with_faults(
        &spec,
        &profiles,
        &plan.config,
        &live,
        &params,
        &mut t,
        &faults,
    );
    let mut t = mk_tuner();
    let b = SimRun::new(&spec, &profiles, &plan.config, &params)
        .controller(&mut t)
        .faults(&faults)
        .run(&live)
        .0;
    assert_bit_identical(&w, &b, "simulate_controlled_with_faults");

    let mut t = mk_tuner();
    let mut wp = RecordingProbe::new(SLO);
    let w = simulate_controlled_probed(
        &spec,
        &profiles,
        &plan.config,
        &live,
        &params,
        &mut t,
        Some(&faults),
        &mut wp,
    );
    let mut t = mk_tuner();
    let mut bp = RecordingProbe::new(SLO);
    let b = SimRun::new(&spec, &profiles, &plan.config, &params)
        .controller(&mut t)
        .faults(&faults)
        .probe(&mut bp)
        .run(&live)
        .0;
    assert_bit_identical(&w, &b, "simulate_controlled_probed");

    let mut source = GammaSource::new(120.0, 1.0, 40.0, 9);
    let w = simulator::simulate_streamed(
        &spec,
        &profiles,
        &plan.config,
        &mut source,
        &params,
        SLO,
        512,
    );
    let mut source = GammaSource::new(120.0, 1.0, 40.0, 9);
    let b = SimRun::new(&spec, &profiles, &plan.config, &params)
        .run_streamed(&mut source, SLO, 512);
    assert_stream_bit_identical(&w, &b, "simulate_streamed");
}
