//! Persistence tests for the cross-process [`EstimatorCache`]:
//! save → load → plan must be bit-identical to a cold run; corrupted,
//! version-mismatched, or otherwise malformed cache files must be
//! rejected wholesale (never silently trusted); foreign-fingerprint
//! entries must be inert; and concurrent sweep shards sharing one warm
//! cache must not drift.

use std::path::PathBuf;
use std::sync::Arc;

use inferline::config::pipelines;
use inferline::experiments::{sweep_grid, sweep_grid_with_cache};
use inferline::planner::{EstimatorCache, Planner};
use inferline::profiler::analytic::paper_profiles;
use inferline::util::json::Json;
use inferline::workload::gamma_trace;

/// A per-test scratch file under the target dir (kept unique so the
/// test binary's threads don't collide).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("inferline-cache-tests");
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

#[test]
fn save_load_plan_is_bit_identical_to_cold() {
    let spec = pipelines::social_media();
    let profiles = paper_profiles();
    let trace = gamma_trace(110.0, 1.0, 25.0, 17);
    let slo = 0.3;
    let path = scratch("roundtrip.json");

    let cold_cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    let cold = Planner::new(&spec, &profiles)
        .with_shared_cache(cold_cache.clone())
        .plan(&trace, slo)
        .unwrap();
    let saved = cold_cache.save(&path).unwrap();
    assert!(saved > 0, "search must persist entries");

    let warm_cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    let loaded = warm_cache.load_from(&path).unwrap();
    assert_eq!(loaded, saved, "every persisted entry loads");
    let warm = Planner::new(&spec, &profiles)
        .with_shared_cache(warm_cache)
        .plan(&trace, slo)
        .unwrap();

    assert_eq!(warm.config, cold.config);
    assert_eq!(warm.actions_taken, cold.actions_taken);
    assert_eq!(warm.iterations, cold.iterations);
    assert_eq!(warm.cost_per_hour.to_bits(), cold.cost_per_hour.to_bits());
    assert_eq!(warm.estimated_p99.to_bits(), cold.estimated_p99.to_bits());
    // The warm planner answers (nearly) everything from the loaded file.
    assert!(
        warm.telemetry.hit_rate() > 0.9,
        "warm-start hit rate {} too low",
        warm.telemetry.hit_rate()
    );
    assert!(warm.telemetry.cache_hits > 0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serialization_is_canonical_and_roundtrips() {
    let spec = pipelines::image_processing();
    let profiles = paper_profiles();
    let trace = gamma_trace(90.0, 1.0, 20.0, 5);
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    Planner::new(&spec, &profiles)
        .with_shared_cache(cache.clone())
        .plan(&trace, 0.25)
        .unwrap();
    let doc = cache.to_json();
    let text = doc.to_string();
    // Parse → merge into a fresh cache → re-serialize: byte-identical
    // (floats round-trip exactly; entries are key-sorted).
    let reparsed = Json::parse(&text).unwrap();
    let fresh = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    let n = fresh.merge_json(&reparsed).unwrap();
    assert!(n > 0);
    assert_eq!(fresh.to_json().to_string(), text, "canonical bytes must be stable");
}

#[test]
fn corrupt_and_mismatched_files_are_rejected() {
    let cache = EstimatorCache::shared(64);
    let path = scratch("bad.json");

    // Unreadable: no such file.
    let missing = scratch("does-not-exist.json");
    let _ = std::fs::remove_file(&missing);
    assert!(cache.load_from(&missing).is_err());

    // Unparsable garbage.
    std::fs::write(&path, "{not json at all").unwrap();
    assert!(cache.load_from(&path).unwrap_err().contains("parse"));

    // Valid JSON, wrong format marker.
    std::fs::write(&path, r#"{"format":"something-else","version":1,"entries":[]}"#).unwrap();
    assert!(cache.load_from(&path).unwrap_err().contains("format"));

    // Version from the future must be rejected, not silently trusted.
    std::fs::write(
        &path,
        r#"{"format":"inferline-estimator-cache","version":999,"entries":[]}"#,
    )
    .unwrap();
    assert!(cache.load_from(&path).unwrap_err().contains("version"));

    // Malformed entries reject the whole file: bad fingerprint, unknown
    // hardware tier, zero replicas, non-finite value, no knowledge.
    for entry in [
        r#"{"fp":"xyz","config":[[0,1,1]],"exact":0.1}"#,
        r#"{"fp":"00000000000000ab","config":[[9,1,1]],"exact":0.1}"#,
        r#"{"fp":"00000000000000ab","config":[[0,1,0]],"exact":0.1}"#,
        r#"{"fp":"00000000000000ab","config":[[0,1,1]],"exact":"oops"}"#,
        r#"{"fp":"00000000000000ab","config":[[0,1,1]]}"#,
    ] {
        let text = format!(
            r#"{{"format":"inferline-estimator-cache","version":1,"entries":[{entry}]}}"#
        );
        std::fs::write(&path, &text).unwrap();
        assert!(cache.load_from(&path).is_err(), "accepted malformed entry {entry}");
    }

    // A partially bad file must not be partially merged.
    let good = r#"{"fp":"00000000000000ab","config":[[0,1,1]],"exact":0.1}"#;
    let bad = r#"{"fp":"short","config":[[0,1,1]],"exact":0.1}"#;
    let text = format!(
        r#"{{"format":"inferline-estimator-cache","version":1,"entries":[{good},{bad}]}}"#
    );
    std::fs::write(&path, &text).unwrap();
    assert!(cache.load_from(&path).is_err());
    assert!(cache.is_empty(), "rejected file leaked entries into the cache");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn foreign_fingerprint_cache_is_inert() {
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let other_spec = pipelines::tf_cascade();
    let other_trace = gamma_trace(140.0, 2.0, 25.0, 99);
    let path = scratch("foreign.json");

    // Persist knowledge from a completely different planning context.
    let foreign = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    Planner::new(&other_spec, &profiles)
        .with_shared_cache(foreign.clone())
        .plan(&other_trace, 0.4)
        .unwrap();
    foreign.save(&path).unwrap();

    // Loading it is fine — and changes nothing about this context's plan.
    // Serial planners: cache-telemetry counts are only deterministic
    // without candidate-evaluation races.
    let trace = gamma_trace(90.0, 1.0, 20.0, 3);
    let warm_cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    assert!(warm_cache.load_from(&path).unwrap() > 0);
    let warm = Planner::serial(&spec, &profiles)
        .with_shared_cache(warm_cache)
        .plan(&trace, 0.25)
        .unwrap();
    let cold = Planner::serial(&spec, &profiles).plan(&trace, 0.25).unwrap();
    assert_eq!(warm.config, cold.config);
    assert_eq!(warm.actions_taken, cold.actions_taken);
    assert_eq!(warm.estimated_p99.to_bits(), cold.estimated_p99.to_bits());
    // Foreign entries can never answer this context's queries.
    assert_eq!(warm.telemetry.cache_hits, cold.telemetry.cache_hits);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn concurrent_sweep_shards_share_warm_cache_without_drift() {
    let lambdas = [60.0, 120.0];
    let cvs = [1.0];
    let slos = [0.2, 0.35];
    let path = scratch("sweep.json");

    // Cold reference sweep, persisting its cache.
    let cache = EstimatorCache::shared(1 << 16);
    let cold = sweep_grid_with_cache(&lambdas, &cvs, &slos, 20.0, Arc::clone(&cache));
    cache.save(&path).unwrap();

    // Warm sweep: every parallel shard shares the one loaded cache.
    let warm_cache = EstimatorCache::shared(1 << 16);
    assert!(warm_cache.load_from(&path).unwrap() > 0);
    let warm = sweep_grid_with_cache(&lambdas, &cvs, &slos, 20.0, warm_cache);

    // And an entirely cache-free reference.
    let plain = sweep_grid(&lambdas, &cvs, &slos, 20.0);

    assert_eq!(cold.len(), warm.len());
    assert_eq!(plain.len(), warm.len());
    for ((a, b), c) in cold.iter().zip(&warm).zip(&plain) {
        assert_eq!(a.pipeline, b.pipeline);
        match (&a.outcome, &b.outcome, &c.outcome) {
            (Ok(x), Ok(y), Ok(z)) => {
                assert_eq!(x.cost_per_hour.to_bits(), y.cost_per_hour.to_bits());
                assert_eq!(x.cost_per_hour.to_bits(), z.cost_per_hour.to_bits());
                assert_eq!(x.estimated_p99.to_bits(), y.estimated_p99.to_bits());
                assert_eq!(x.iterations, y.iterations);
                assert_eq!(x.total_replicas, y.total_replicas);
            }
            (Err(x), Err(y), Err(z)) => {
                assert_eq!(x, y);
                assert_eq!(x, z);
            }
            _ => panic!("warm/cold outcome mismatch for {}", a.pipeline),
        }
    }
    let _ = std::fs::remove_file(&path);
}
