//! Conformance and behavior suite for fleet-scale planning
//! (`inferline::fleet`) and the planner's inventory restriction.
//!
//! The load-bearing invariant is conformance: a 1-tenant fleet on an
//! unbounded inventory is `Planner::plan`, bit for bit — the fleet
//! layer may only *add* behavior (packing, repair, sharing), never
//! perturb the single-pipeline search it is built on. The rest of the
//! suite locks down the packer's typed infeasibility, the
//! prefix-sharing accounting identities, and determinism of the whole
//! fleet plan.

use inferline::config::pipelines;
use inferline::fleet::{synth_tenants, FleetError, FleetPlanner, FleetSpec, Tenant};
use inferline::hardware::{Hardware, Inventory};
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::workload::gamma_trace;

fn one_tenant_fleet(name: &str, lambda: f64, slo: f64, seed: u64) -> FleetSpec {
    let spec = pipelines::by_name(name).expect("checked-in pipeline");
    FleetSpec {
        tenants: vec![Tenant {
            name: format!("solo-{name}"),
            spec,
            slo,
            sample: gamma_trace(lambda, 1.0, 30.0, seed),
        }],
        inventory: Inventory::unbounded(),
    }
}

#[test]
fn one_tenant_unbounded_fleet_is_planner_plan_bit_identical() {
    let profiles = paper_profiles();
    for (name, lambda, slo) in [
        ("image-processing", 120.0, 0.3),
        ("video-monitoring", 80.0, 0.35),
        ("social-media", 100.0, 0.35),
        ("tf-cascade", 150.0, 0.25),
    ] {
        let fleet = one_tenant_fleet(name, lambda, slo, 7);
        let solo = Planner::new(&fleet.tenants[0].spec, &profiles)
            .plan(&fleet.tenants[0].sample, slo)
            .expect("solo plan");
        let plan = FleetPlanner::new(&profiles).plan(&fleet).expect("fleet plan");
        assert_eq!(plan.tenants.len(), 1);
        let t = &plan.tenants[0];
        assert_eq!(t.plan.config, solo.config, "{name}: config");
        assert_eq!(
            t.plan.cost_per_hour.to_bits(),
            solo.cost_per_hour.to_bits(),
            "{name}: cost"
        );
        assert_eq!(
            t.plan.estimated_p99.to_bits(),
            solo.estimated_p99.to_bits(),
            "{name}: estimated p99"
        );
        assert_eq!(t.plan.iterations, solo.iterations, "{name}: iterations");
        assert_eq!(t.plan.actions_taken, solo.actions_taken, "{name}: actions");
        // No peer to share with, nothing to repair: the fleet layer
        // must be invisible.
        assert!(plan.shared.is_empty(), "{name}: shared stages");
        assert_eq!(plan.repairs, 0, "{name}: repairs");
        assert!(t.excluded.is_empty(), "{name}: exclusions");
        assert_eq!(
            plan.total_cost_per_hour.to_bits(),
            solo.cost_per_hour.to_bits(),
            "{name}: fleet total"
        );
        assert_eq!(t.effective_cost_per_hour.to_bits(), solo.cost_per_hour.to_bits());
        assert_eq!(plan.savings_per_hour, 0.0, "{name}: savings");
    }
}

#[test]
fn planner_inventory_unbounded_is_default_bit_identical() {
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let sample = gamma_trace(120.0, 1.0, 30.0, 3);
    let a = Planner::new(&spec, &profiles).plan(&sample, 0.35).expect("default");
    let b = Planner::new(&spec, &profiles)
        .with_inventory(Inventory::unbounded())
        .plan(&sample, 0.35)
        .expect("explicit unbounded");
    assert_eq!(a.config, b.config);
    assert_eq!(a.cost_per_hour.to_bits(), b.cost_per_hour.to_bits());
    assert_eq!(a.estimated_p99.to_bits(), b.estimated_p99.to_bits());
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.actions_taken, b.actions_taken);
}

#[test]
fn planner_respects_tier_exclusions() {
    let profiles = paper_profiles();
    let spec = pipelines::tf_cascade();
    let sample = gamma_trace(60.0, 1.0, 30.0, 5);
    // CPU-only inventory: every stage must land on CPU.
    let cpu_only = Inventory::unbounded()
        .with_count(Hardware::GpuK80, Some(0))
        .with_count(Hardware::GpuV100, Some(0));
    let plan = Planner::new(&spec, &profiles)
        .with_inventory(cpu_only)
        .plan(&sample, 0.5)
        .expect("cpu-only plan");
    for s in &plan.config.stages {
        assert_eq!(s.hw, Hardware::Cpu);
    }
    // A GPU-only inventory must keep the search off the CPU entirely.
    let gpu_only = Inventory::unbounded().with_count(Hardware::Cpu, Some(0));
    let gpu_plan = Planner::new(&spec, &profiles)
        .with_inventory(gpu_only)
        .plan(&sample, 0.5)
        .expect("gpu-only plan");
    for s in &gpu_plan.config.stages {
        assert_ne!(s.hw, Hardware::Cpu);
    }
}

#[test]
fn oversubscribed_inventory_is_typed_infeasible_naming_the_tier() {
    let profiles = paper_profiles();
    // One V100 for a fleet that needs several devices, and no other
    // tier to repair onto.
    let mut fleet = one_tenant_fleet("image-processing", 150.0, 0.3, 11);
    fleet.inventory = Inventory::bounded(0, 0, 1);
    let err = FleetPlanner::new(&profiles).plan(&fleet).expect_err("must not fit");
    match err {
        FleetError::Infeasible { tier, demand, capacity } => {
            assert_eq!(tier, Hardware::GpuV100);
            assert_eq!(capacity, 1);
            assert!(demand > capacity, "demand {demand} vs capacity {capacity}");
        }
        other => panic!("expected Infeasible, got {other}"),
    }
}

#[test]
fn repair_moves_tenants_off_a_capped_tier() {
    let profiles = paper_profiles();
    // Plan unbounded first to learn the fleet's natural tier usage.
    let population = synth_tenants(8, 21, 20.0);
    let tenants: Vec<Tenant> = population.into_iter().map(|t| t.tenant).collect();
    let unbounded = FleetPlanner::new(&profiles)
        .plan(&FleetSpec { tenants: tenants.clone(), inventory: Inventory::unbounded() })
        .expect("unbounded fleet");
    let (tier, used) = Hardware::ALL
        .into_iter()
        .map(|hw| (hw, unbounded.usage[hw.index()]))
        .max_by_key(|&(_, used)| used)
        .expect("three tiers");
    assert!(used > 1, "fleet should use devices on its busiest tier");
    // Halve the busiest tier: local repair must re-plan someone and the
    // constrained fleet must respect the cap.
    let cap = used / 2;
    let constrained = FleetPlanner::new(&profiles)
        .plan(&FleetSpec {
            tenants,
            inventory: Inventory::unbounded().with_count(tier, Some(cap)),
        })
        .expect("repairable fleet");
    assert!(constrained.repairs > 0, "cap below usage must force repairs");
    assert!(
        constrained.usage[tier.index()] <= cap,
        "constrained usage {} exceeds cap {cap}",
        constrained.usage[tier.index()]
    );
    assert!(
        constrained.tenants.iter().any(|t| t.excluded.contains(&tier)),
        "some tenant must have been moved off {tier}"
    );
    // Moving off the preferred tier can only cost more (or equal).
    assert!(constrained.total_cost_per_hour >= unbounded.total_cost_per_hour - 1e-9);
}

#[test]
fn prefix_sharing_saves_and_conserves_cost() {
    let profiles = paper_profiles();
    // Two image-processing tenants with identical plans share their
    // whole 2-stage prefix chain.
    let mut tenants = Vec::new();
    for i in 0..2 {
        let mut fleet = one_tenant_fleet("image-processing", 100.0, 0.3, 13);
        fleet.tenants[0].name = format!("twin-{i}");
        tenants.push(fleet.tenants.remove(0));
    }
    let plan = FleetPlanner::new(&profiles)
        .plan(&FleetSpec { tenants, inventory: Inventory::unbounded() })
        .expect("twin fleet");
    assert!(!plan.shared.is_empty(), "identical prefixes must merge");
    for g in &plan.shared {
        assert_eq!(g.tenants.len(), 2);
        let per_tenant_max = plan
            .tenants
            .iter()
            .map(|t| t.plan.config.stages[g.depth].replicas)
            .max()
            .unwrap();
        assert!(
            g.replicas >= per_tenant_max && g.replicas <= g.replicas_unshared,
            "merged {} outside [{per_tenant_max}, {}]",
            g.replicas,
            g.replicas_unshared
        );
    }
    assert!(plan.savings_per_hour >= 0.0);
    assert!(
        (plan.unshared_cost_per_hour - plan.savings_per_hour - plan.total_cost_per_hour).abs()
            < 1e-9
    );
    // Routing credit conserves the fleet total exactly.
    let effective: f64 = plan.tenants.iter().map(|t| t.effective_cost_per_hour).sum();
    assert!(
        (effective - plan.total_cost_per_hour).abs() < 1e-6,
        "effective {effective} vs total {}",
        plan.total_cost_per_hour
    );
    // Identical twins split the merged stages evenly.
    let d = (plan.tenants[0].effective_cost_per_hour - plan.tenants[1].effective_cost_per_hour)
        .abs();
    assert!(d < 1e-9, "twins should pay the same: delta {d}");
}

#[test]
fn tenants_on_different_hardware_do_not_merge() {
    let profiles = paper_profiles();
    // Same pipeline, very different load: plans can differ in batch or
    // hardware at some depth; groups only form where (hw, batch) agree,
    // so every shared group must be internally consistent.
    let mut fleet_a = one_tenant_fleet("tf-cascade", 40.0, 0.5, 17);
    let fleet_b = one_tenant_fleet("tf-cascade", 220.0, 0.25, 19);
    fleet_a.tenants.extend(fleet_b.tenants);
    let plan = FleetPlanner::new(&profiles)
        .plan(&FleetSpec { tenants: fleet_a.tenants, inventory: Inventory::unbounded() })
        .expect("mixed fleet");
    for g in &plan.shared {
        for &ti in &g.tenants {
            let sc = plan.tenants[ti].plan.config.stages[g.depth];
            assert_eq!(sc.hw, g.hw, "group member hardware mismatch");
            assert_eq!(sc.batch, g.batch, "group member batch mismatch");
        }
    }
}

#[test]
fn fleet_plan_is_deterministic() {
    let profiles = paper_profiles();
    let make = || {
        let tenants = synth_tenants(10, 33, 20.0).into_iter().map(|t| t.tenant).collect();
        FleetPlanner::new(&profiles)
            .plan(&FleetSpec { tenants, inventory: Inventory::unbounded() })
            .expect("synth fleet")
    };
    let (a, b) = (make(), make());
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.tenant, y.tenant);
        assert_eq!(x.plan.config, y.plan.config);
        assert_eq!(
            x.effective_cost_per_hour.to_bits(),
            y.effective_cost_per_hour.to_bits()
        );
    }
    assert_eq!(a.total_cost_per_hour.to_bits(), b.total_cost_per_hour.to_bits());
    assert_eq!(a.savings_per_hour.to_bits(), b.savings_per_hour.to_bits());
    assert_eq!(a.usage, b.usage);
    assert_eq!(a.shared.len(), b.shared.len());
    for (g, h) in a.shared.iter().zip(&b.shared) {
        assert_eq!(g.prefix, h.prefix);
        assert_eq!(g.replicas, h.replicas);
        assert_eq!(g.tenants, h.tenants);
    }
}

#[test]
fn zero_count_tier_is_skipped_by_tiers_iterator() {
    let inv = Inventory::unbounded().with_count(Hardware::GpuK80, Some(0));
    let tiers: Vec<Hardware> = inv.tiers().collect();
    assert_eq!(tiers, vec![Hardware::Cpu, Hardware::GpuV100]);
    assert!(!inv.has(Hardware::GpuK80));
    assert!(inv.has(Hardware::Cpu));
}
