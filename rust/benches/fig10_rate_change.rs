//! Bench target for paper fig10: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig10`.

fn main() {
    inferline::util::bench::bench("fig10 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig10", true));
    });
}
