//! Bench target for paper fig5: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig5`.

fn main() {
    inferline::util::bench::bench("fig5 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig5", true));
    });
}
