//! Bench target for paper fig9: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig9`.

fn main() {
    inferline::util::bench::bench("fig9 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig9", true));
    });
}
