//! Bench target for paper fig6: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig6`.

fn main() {
    inferline::util::bench::bench("fig6 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig6", true));
    });
}
