//! Bench target for paper fig12: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig12`.

fn main() {
    inferline::util::bench::bench("fig12 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig12", true));
    });
}
