//! Bench target for paper fig13: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig13`.

fn main() {
    inferline::util::bench::bench("fig13 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig13", true));
    });
}
