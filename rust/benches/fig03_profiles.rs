//! Bench target for paper fig3: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig3`.

fn main() {
    inferline::util::bench::bench("fig3 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig3", true));
    });
}
