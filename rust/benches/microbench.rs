//! Micro-benchmarks of the hot paths (§Perf in EXPERIMENTS.md):
//!
//! * the discrete-event Estimator — the planner invokes it for every
//!   candidate configuration, and the paper claims hours of trace
//!   simulate in hundreds of milliseconds (§4.2);
//! * traffic-envelope construction + live rate monitoring — the Tuner's
//!   per-arrival / per-tick work;
//! * a full planner run — the end-to-end low-frequency path;
//! * workload generation (Gamma sampling);
//! * the event core in isolation — old-style heap churn (owned `Vec`
//!   payloads, one record per hop) vs the slab queue with coalesced
//!   delivery, on an identical synthetic workload.

use inferline::config::pipelines;
use inferline::planner::Planner;
use inferline::profiler::analytic::paper_profiles;
use inferline::simulator::{self, SimParams};
use inferline::tuner::envelope::{RateMonitor, TrafficEnvelope};
use inferline::util::bench::{bench, black_box};
use inferline::workload::gamma_trace;

fn main() {
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let params = SimParams::default();

    // --- Estimator throughput: one hour of 150 QPS trace. -----------------
    let hour_trace = gamma_trace(150.0, 1.0, 3600.0, 1);
    let plan = Planner::new(&spec, &profiles)
        .plan(&gamma_trace(150.0, 1.0, 30.0, 2), 0.3)
        .expect("plan");
    let queries = hour_trace.len();
    let r = bench("estimator: 1h @150qps social-media", 1, 5, || {
        let result = simulator::simulate(&spec, &profiles, &plan.config, &hour_trace, &params);
        black_box(result.latencies.len());
    });
    println!(
        "  -> {:.2} M queries/sec simulated ({} queries/run; paper: 'hours in hundreds of ms')",
        queries as f64 / r.mean_s / 1e6,
        queries
    );

    // --- Estimator on the short planning trace (the inner-loop call). -----
    let plan_trace = gamma_trace(150.0, 1.0, 60.0, 3);
    bench("estimator: 60s planning trace (planner inner loop)", 3, 20, || {
        black_box(simulator::estimate_p99(&spec, &profiles, &plan.config, &plan_trace, &params));
    });

    // --- Full planner run: serial vs parallel candidate evaluation. --------
    // A fresh planner per run keeps the feasibility memo-cache cold, so
    // both sides measure one complete search.
    let serial = bench("planner: full plan (serial), social-media @150qps", 1, 5, || {
        black_box(
            Planner::serial(&spec, &profiles).plan(&plan_trace, 0.3).unwrap().cost_per_hour,
        );
    });
    let parallel = bench("planner: full plan (parallel), social-media @150qps", 1, 5, || {
        black_box(Planner::new(&spec, &profiles).plan(&plan_trace, 0.3).unwrap().cost_per_hour);
    });
    let telemetry = Planner::new(&spec, &profiles).plan(&plan_trace, 0.3).unwrap().telemetry;
    println!(
        "  -> parallel speedup {:.2}x on {} threads; feasibility cache: {} hits / {} evals \
         ({:.0}% hit rate), {} pruned analytically",
        serial.mean_s / parallel.mean_s,
        telemetry.threads,
        telemetry.cache_hits,
        telemetry.cache_hits + telemetry.cache_misses,
        telemetry.hit_rate() * 100.0,
        telemetry.pruned
    );

    // --- Envelope construction over a full hour trace. ---------------------
    let windows = inferline::tuner::envelope::window_ladder(0.1);
    bench("envelope: build from 1h @150qps trace (all windows)", 1, 10, || {
        black_box(TrafficEnvelope::from_arrivals(&hour_trace.arrivals, &windows).rates());
    });

    // --- Live monitor: per-arrival cost + per-tick rates. -------------------
    bench("monitor: 540k arrivals + 3.6k rate queries", 1, 5, || {
        let mut mon = RateMonitor::new(windows.clone());
        let mut next_tick = 1.0;
        let mut acc = 0.0;
        for &t in &hour_trace.arrivals {
            mon.on_arrival(t);
            if t >= next_tick {
                acc += mon.rates(t)[0];
                next_tick += 1.0;
            }
        }
        black_box(acc);
    });

    // --- Workload generation. ----------------------------------------------
    bench("workload: generate 1h @150qps CV=4 gamma trace", 1, 10, || {
        black_box(gamma_trace(150.0, 4.0, 3600.0, 7).len());
    });

    // --- Event core in isolation: heap churn, old queue vs slab queue. ------
    // Both drivers process the same 10^6-hop synthetic batch/fan-out
    // workload and fold every hop into a checksum (equal checksums =>
    // identical work in identical order, asserted in event_core's tests).
    let hops = 1_000_000usize;
    let reference = bench("event core: 1M hops, reference heap (Vec payloads)", 1, 5, || {
        black_box(simulator::event_core::churn_reference(hops));
    });
    let core = bench("event core: 1M hops, slab queue + coalesced delivery", 1, 5, || {
        black_box(simulator::event_core::churn_event_core(hops));
    });
    println!(
        "  -> event-core speedup {:.2}x ({:.2} M hops/sec vs {:.2} M hops/sec)",
        reference.mean_s / core.mean_s,
        hops as f64 / core.mean_s / 1e6,
        hops as f64 / reference.mean_s / 1e6
    );
}
