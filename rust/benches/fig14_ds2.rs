//! Bench target for paper fig14: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig14`.

fn main() {
    inferline::util::bench::bench("fig14 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig14", true));
    });
}
