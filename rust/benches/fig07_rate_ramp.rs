//! Bench target for paper fig7: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig7`.

fn main() {
    inferline::util::bench::bench("fig7 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig7", true));
    });
}
