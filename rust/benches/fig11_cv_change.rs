//! Bench target for paper fig11: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig11`.

fn main() {
    inferline::util::bench::bench("fig11 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig11", true));
    });
}
