//! Bench target for paper fig8: regenerates the figure rows (quick
//! mode) and reports the wall time of one full regeneration.
//! Full-scale data: `inferline experiment fig8`.

fn main() {
    inferline::util::bench::bench("fig8 regeneration (quick)", 0, 1, || {
        assert!(inferline::experiments::run_by_name("fig8", true));
    });
}
