//! Stub of the `xla` PJRT bindings used by `inferline::runtime`.
//!
//! The real crate wraps a PJRT CPU client and compiled HLO executables;
//! this image has neither the crate nor the native library, so the stub
//! mirrors the API surface exactly and fails gracefully at runtime:
//! [`PjRtClient::cpu`] returns an error, which the serving layer already
//! treats as "executor init failed" (workers report and exit; the
//! calibrated backend is unaffected). All `runtime` tests gate on the
//! presence of `artifacts/manifest.json`, which a stub-only image does
//! not have, so nothing downstream ever reaches a stubbed execution path.
//!
//! Swapping in the real bindings is a one-line Cargo.toml change; no
//! source edits are needed (ROADMAP "Open items").

use std::fmt;

/// Error type; the callers only format it with `{:?}`.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla unavailable: {}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla unavailable: {}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the PJRT runtime is not present on this image (stub crate; \
         use the calibrated backend, or vendor the real xla bindings)"
    )))
}

/// Host literal (stub: shape + data are retained so construction works).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from f32 data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// First element of a 1-tuple result (stub: never reached at runtime).
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("to_tuple1")
    }

    /// Typed host copy (stub: never reached at runtime).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("to_vec")
    }

    /// Declared dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; generic over the argument type to
    /// match the real API's `execute::<Literal>(..)` call sites.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute")
    }
}

/// PJRT client (stub): construction fails, so callers bail out before any
/// execution path is reached.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("unavailable"));
    }

    #[test]
    fn literal_construction_and_reshape_work() {
        let lit = Literal::vec1(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert!(lit.reshape(&[4, 4]).is_err());
    }
}
