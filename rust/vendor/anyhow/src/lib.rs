//! Minimal, API-compatible subset of the `anyhow` crate, vendored because
//! this image has no crates.io access (DESIGN.md §8). Covers what the
//! InferLine codebase uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! `Error` keeps a context chain: `{e}` prints the outermost message,
//! `{e:#}` prints the whole chain joined with `: ` (mirroring anyhow's
//! alternate formatting).

use std::fmt;

/// A string-backed error with a context chain. Intentionally does *not*
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` impl below coherent (the same trick the
/// real anyhow uses).
pub struct Error {
    /// Context chain, outermost first; always non-empty.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        Error::msg(err)
    }
}

/// Drop-in alias for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing"))
    }

    #[test]
    fn context_chains_and_formats() {
        let err = io_fail().with_context(|| "reading manifest".to_string()).unwrap_err();
        assert_eq!(format!("{err}"), "reading manifest");
        assert_eq!(format!("{err:#}"), "reading manifest: missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_construct_errors() {
        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("always bails")
        }
        assert_eq!(format!("{}", bails(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", bails(true).unwrap_err()), "always bails");
        let e: Error = anyhow!("value {}", 7);
        assert_eq!(format!("{e}"), "value 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("empty").is_err());
        assert_eq!(Some(3u8).context("empty").unwrap(), 3);
    }
}
