//! PJRT runtime: loads the AOT-compiled HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched. Python never runs on
//! the request path: `make artifacts` lowers the L2 JAX models (with their
//! L1 Pallas kernels inlined) to HLO text once; from then on the rust
//! binary is self-contained.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto` — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! PJRT wrapper objects hold raw pointers and are not `Send`, so each
//! serving replica worker owns its *own* [`ReplicaExecutor`] (client +
//! compiled executables), constructed on the worker thread.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
}

/// One model's artifact metadata.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub in_dim: usize,
    pub out_dim: usize,
    pub description: String,
    /// batch size -> artifact file name.
    pub batches: BTreeMap<usize, String>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut models = BTreeMap::new();
        for (name, entry) in v
            .req("models")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest.models must be an object"))?
        {
            let mut batches = BTreeMap::new();
            for (b, meta) in entry
                .req("batches")
                .as_obj()
                .ok_or_else(|| anyhow!("batches must be an object"))?
            {
                batches.insert(
                    b.parse::<usize>().context("batch key")?,
                    meta.req("file")
                        .as_str()
                        .ok_or_else(|| anyhow!("file"))?
                        .to_string(),
                );
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    in_dim: entry.req("in_dim").as_usize().ok_or_else(|| anyhow!("in_dim"))?,
                    out_dim: entry.req("out_dim").as_usize().ok_or_else(|| anyhow!("out_dim"))?,
                    description: entry
                        .get("description")
                        .and_then(|d| d.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    batches,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    /// The dyadic artifact batch sizes available for a model, ascending.
    pub fn batch_sizes(&self, name: &str) -> Result<Vec<usize>> {
        Ok(self.model(name)?.batches.keys().copied().collect())
    }

    /// Smallest artifact batch size >= n (Clipper-style dyadic rounding),
    /// or the largest available if n exceeds all.
    pub fn round_batch(&self, name: &str, n: usize) -> Result<usize> {
        let meta = self.model(name)?;
        Ok(meta
            .batches
            .keys()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *meta.batches.keys().last().unwrap()))
    }
}

/// A per-thread executor for one model: owns a PJRT client and the
/// compiled executables for every artifact batch size up to the replica's
/// configured maximum. A batch of n queries runs through the smallest
/// executable with batch >= n.
pub struct ReplicaExecutor {
    model: String,
    in_dim: usize,
    out_dim: usize,
    /// (batch size, executable, prebuilt input literal) ascending by batch.
    execs: Vec<(usize, xla::PjRtLoadedExecutable, xla::Literal)>,
}

impl ReplicaExecutor {
    /// Compile the model's artifacts for all batch sizes <= `max_batch`
    /// (plus the smallest one above, for rounding) on this thread.
    pub fn new(manifest: &Manifest, model: &str, max_batch: usize) -> Result<Self> {
        let meta = manifest.model(model)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut execs = Vec::new();
        let cap = manifest.round_batch(model, max_batch)?;
        for (&b, file) in &meta.batches {
            if b > cap {
                break;
            }
            let path = manifest.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {file}: {e:?}"))?;
            // Prebuilt deterministic input (contents are irrelevant to the
            // serving measurements; shape must match the artifact).
            let data: Vec<f32> = (0..b * meta.in_dim)
                .map(|i| ((i % 97) as f32) * 0.01 - 0.5)
                .collect();
            let lit = xla::Literal::vec1(&data)
                .reshape(&[b as i64, meta.in_dim as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            execs.push((b, exe, lit));
        }
        if execs.is_empty() {
            bail!("no artifacts for model {model} (max_batch {max_batch})");
        }
        Ok(ReplicaExecutor {
            model: model.to_string(),
            in_dim: meta.in_dim,
            out_dim: meta.out_dim,
            execs,
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Largest artifact batch size this executor holds.
    pub fn max_batch(&self) -> usize {
        self.execs.last().map(|e| e.0).unwrap_or(1)
    }

    /// Execute a batch of `n` queries with the prebuilt input, returning
    /// the executable batch size used and the first output element (a
    /// liveness check that the computation really ran).
    pub fn run(&self, n: usize) -> Result<(usize, f32)> {
        let (b, exe, lit) = self
            .execs
            .iter()
            .find(|(b, _, _)| *b >= n)
            .or_else(|| self.execs.last())
            .ok_or_else(|| anyhow!("no executable"))?;
        let result = exe
            .execute::<xla::Literal>(std::slice::from_ref(lit))
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if values.len() != b * self.out_dim {
            bail!(
                "{}: output len {} != {} x {}",
                self.model,
                values.len(),
                b,
                self.out_dim
            );
        }
        Ok((*b, values[0]))
    }

    /// Execute with caller-provided input data (`n x in_dim` f32s).
    pub fn run_with_input(&self, input: &[f32]) -> Result<Vec<f32>> {
        let n = input.len() / self.in_dim;
        anyhow::ensure!(n * self.in_dim == input.len(), "ragged input");
        let (b, exe, _) = self
            .execs
            .iter()
            .find(|(b, _, _)| *b >= n)
            .or_else(|| self.execs.last())
            .ok_or_else(|| anyhow!("no executable"))?;
        // Pad to the executable's batch.
        let mut data = input.to_vec();
        data.resize(b * self.in_dim, 0.0);
        let lit = xla::Literal::vec1(&data)
            .reshape(&[*b as i64, self.in_dim as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(values[..n * self.out_dim].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_loads_and_covers_zoo() {
        if !have_artifacts() {
            crate::log_warn!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for model in ["preprocess", "resnet_lite", "langid", "nmt_lite", "tf_fast", "tf_slow"] {
            let meta = m.model(model).unwrap();
            assert!(!meta.batches.is_empty(), "{model}");
        }
    }

    #[test]
    fn round_batch_is_dyadic_ceiling() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.round_batch("langid", 3).unwrap(), 4);
        assert_eq!(m.round_batch("langid", 8).unwrap(), 8);
        assert_eq!(m.round_batch("langid", 1000).unwrap(), 32);
    }

    #[test]
    fn executor_runs_real_model() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let exec = ReplicaExecutor::new(&m, "langid", 4).unwrap();
        let (b, probe) = exec.run(3).unwrap();
        assert_eq!(b, 4);
        assert!(probe.is_finite());
    }

    #[test]
    fn executor_roundtrips_real_input() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let exec = ReplicaExecutor::new(&m, "tf_fast", 2).unwrap();
        let input = vec![0.1f32; 2 * exec.in_dim()];
        let out = exec.run_with_input(&input).unwrap();
        assert_eq!(out.len(), 2 * 16);
        assert!(out.iter().all(|x| x.is_finite()));
        // Identical rows in, identical rows out (determinism end to end).
        assert_eq!(out[..16], out[16..32]);
    }

    #[test]
    fn missing_model_is_error() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.model("ghost").is_err());
        assert!(ReplicaExecutor::new(&m, "ghost", 1).is_err());
    }
}
