//! InferLine command-line launcher.
//!
//! Subcommands:
//!   plan        plan a pipeline configuration for a workload + SLO
//!   profile     measure real CPU model profiles through PJRT
//!   simulate    run the Estimator on a configuration
//!   stream      run the constant-memory streamed Estimator on a scenario
//!   serve       serve a trace on the physical plane (PJRT or calibrated)
//!   experiment  regenerate a paper figure (fig3..fig14, headline, all)
//!   trace       generate workload traces to files
//!
//! Argument parsing is hand-rolled (no crate network access on this
//! image — DESIGN.md §8).

use std::path::PathBuf;
use std::process::ExitCode;

use inferline::baselines::coarse::{self, CoarseTarget};
use inferline::config::pipelines;
use inferline::planner::{EstimatorCache, Planner};
use inferline::profiler::analytic::paper_profiles;
use inferline::profiler::ProfileSet;
use inferline::runtime::Manifest;
use inferline::serving::{profile as phys_profile, Backend, ServingEngine};
use inferline::simulator::probe::{ProbeReport, RecordingProbe};
use inferline::simulator::{self, SimParams};
use inferline::util::stats;
use inferline::workload::{autoscale, gamma_trace, scenarios, Trace};

/// Minimal flag parser: --key value pairs after the subcommand.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn bool(&self, key: &str) -> bool {
        self.get(key).is_some_and(|v| v != "false")
    }

    /// Reject flags the command does not define: a typo'd flag must not
    /// silently fall back to a default and masquerade as the requested
    /// run. Prints the offending flag and the usage text; the caller
    /// exits nonzero. The global `--verbose` is always accepted.
    fn reject_unknown(&self, cmd: &str, allowed: &[&str]) -> bool {
        for key in self.flags.keys() {
            if key != "verbose" && !allowed.contains(&key.as_str()) {
                inferline::log_error!("unknown flag --{key} for {cmd:?}\n{USAGE}");
                return false;
            }
        }
        true
    }

    /// Resolve the estimator-cache persistence flags: `--no-cache` wins,
    /// `--cache <path>` names a file, a bare `--cache` (and, when
    /// `default_on` — the sweep/robustness experiments — no flag at all)
    /// uses the standard `results/estimator_cache.json`.
    fn cache_path(&self, default_on: bool) -> Option<PathBuf> {
        const DEFAULT: &str = "results/estimator_cache.json";
        if self.bool("no-cache") {
            return None;
        }
        match self.get("cache") {
            // Bare `--cache` parses as "true"; `--cache false` mirrors the
            // bool() convention and disables persistence.
            Some("true") => Some(PathBuf::from(DEFAULT)),
            Some("false") => None,
            Some(path) => Some(PathBuf::from(path)),
            None if default_on => Some(PathBuf::from(DEFAULT)),
            None => None,
        }
    }
}

const USAGE: &str = "\
InferLine: ML prediction pipeline provisioning for tight latency SLOs

USAGE: inferline <command> [flags]

COMMANDS:
  plan        --pipeline <name> --slo <s> --lambda <qps> [--cv <v>]
              [--profiles <file.json>] [--compare-cg] [--cache [<file>]]
              (--cache persists the estimator cache so a repeated plan
              warm-starts; default file results/estimator_cache.json)
  profile     --artifacts <dir> [--out <file.json>] [--max-batch <b>]
  simulate    --pipeline <name> --slo <s> --lambda <qps> [--cv <v>]
              [--faults <spec.json>] [--seed <n>]
              [--trace-out <file.json>] [--series-out <file.csv>]
              (--faults injects a chaos plan — crashes, slowdowns,
              outages; see simulator::faults for the JSON schema — and
              reports crash/retry/shed counts alongside the latencies;
              --trace-out observes the run through the telemetry probe
              and writes a Perfetto-loadable Chrome trace-event file,
              --series-out the per-stage time-series CSV, and either
              flag prints the SLO-miss attribution blame table)
  stream      --scenario <spec.json> --pipeline <name> [--slo <s>]
              [--lambda <qps>] [--quick] [--seed <n>] [--chunk <n>]
              [--planner inferline|cg-peak] [--max-rss-mb <mb>]
              (streamed open loop: arrivals are pulled from the scenario
              in bounded chunks and folded into aggregates, so memory
              tracks the in-flight window, not the horizon — multi-hour
              scenarios simulate without materializing the trace;
              --max-rss-mb makes the process fail if its peak RSS
              exceeded the ceiling, which is the CI long-horizon smoke)
  serve       --pipeline <name> --lambda <qps> --duration <s>
              [--backend pjrt|calibrated] [--artifacts <dir>] [--slo <s>]
  experiment  <fig3|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|fig14|headline|sweep|all>
              [--quick]
              (sweep persists its estimator cache across runs; override
              the file with --cache <file> or disable with --no-cache)
  experiment  robustness [--quick] [--seed <n>] [--cache <file>|--no-cache]
              (closed-loop Planner+Tuner scenario matrix vs the coarse
              baselines -> robustness.json + robustness_baselines.csv;
              the matrix is the checked-in scenarios/*.json specs)
  experiment  fleet [--quick] [--seed <n>] [--cache <file>|--no-cache]
              (joint provisioning of 10/100/1000-tenant populations over
              a shared accelerator inventory, with prefix-stage sharing
              and a constrained-inventory replan -> fleet.json +
              fleet.csv; see the fleet module docs for the rules)
  budget      check|update [--report <robustness.json>] [--budgets <BUDGETS.json>]
              (check: compare a robustness report against the checked-in
              per-scenario SLO budget ledger, exit nonzero on regression;
              update: re-baseline the ledger from the report)
  bench       estimator [--out <file.json>] [--quick]
              (writes the Estimator/Planner perf-trajectory JSON)
  bench       check|update [--current <file.json>] [--baseline <file.json>] [--quick]
              (check: measure the current tree — or read --current — and
              compare against the checked-in BENCH_estimator.json perf
              baseline, exit nonzero naming each regressed metric;
              update: re-baseline the file from a fresh run)
  trace       --kind gamma|big-spike|instant-spike --out <file>
              [--lambda <qps>] [--cv <v>] [--duration <s>]
  trace       scenario <spec.json> [--out <file>] [--seed <n>]
              (build a declarative scenario; see workload::scenarios docs)
  pipelines   list the built-in paper pipelines

Pipelines: image-processing, video-monitoring, social-media, tf-cascade

Global flags: --verbose raises diagnostics to debug level; the
INFERLINE_LOG env var (error|warn|info|debug) sets it explicitly.
Flags a command does not define are rejected, not ignored.
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    inferline::util::log::init(argv.iter().any(|a| a == "--verbose"));
    let Some(cmd) = argv.first() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let args = Args::parse(&argv[1..]);
    let ok = match cmd.as_str() {
        "plan" => cmd_plan(&args),
        "profile" => cmd_profile(&args),
        "simulate" => cmd_simulate(&args),
        "stream" => cmd_stream(&args),
        "serve" => cmd_serve(&args),
        "experiment" => cmd_experiment(&args),
        "budget" => cmd_budget(&args),
        "bench" => cmd_bench(&args),
        "trace" => cmd_trace(&args),
        "pipelines" => {
            if !args.reject_unknown("pipelines", &[]) {
                return ExitCode::FAILURE;
            }
            for p in pipelines::all() {
                println!(
                    "{:<18} {} stages, framework {}",
                    p.name,
                    p.n_stages(),
                    p.framework.id()
                );
                for s in &p.stages {
                    println!("    {:<14} model={:<14} s={:.2}", s.name, s.model, s.scale_factor);
                }
            }
            true
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            true
        }
        other => {
            inferline::log_error!("unknown command {other:?}\n{USAGE}");
            false
        }
    };
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn load_profiles(args: &Args) -> ProfileSet {
    match args.get("profiles") {
        Some(path) => match ProfileSet::load(std::path::Path::new(path)) {
            Ok(p) => p,
            Err(e) => {
                inferline::log_warn!("could not load profiles {path}: {e}; using paper profiles");
                paper_profiles()
            }
        },
        None => paper_profiles(),
    }
}

fn get_pipeline(args: &Args) -> Option<inferline::config::PipelineSpec> {
    let name = args.get("pipeline").unwrap_or("image-processing");
    let p = pipelines::by_name(name);
    if p.is_none() {
        inferline::log_error!("unknown pipeline {name:?}; see `inferline pipelines`");
    }
    p
}

fn cmd_plan(args: &Args) -> bool {
    let allowed = [
        "pipeline",
        "slo",
        "lambda",
        "cv",
        "sample-duration",
        "profiles",
        "compare-cg",
        "cache",
        "no-cache",
    ];
    if !args.reject_unknown("plan", &allowed) {
        return false;
    }
    let Some(spec) = get_pipeline(args) else { return false };
    let profiles = load_profiles(args);
    let slo = args.f64("slo", 0.15);
    let lambda = args.f64("lambda", 100.0);
    let cv = args.f64("cv", 1.0);
    let sample = gamma_trace(lambda, cv, args.f64("sample-duration", 60.0), 42);
    println!("planning {} for λ={lambda} cv={cv} slo={slo}s ...", spec.name);
    // Optional persistent estimator cache: plans are bit-identical warm
    // or cold; the second identical invocation just skips simulations.
    let cache_path = args.cache_path(false);
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    if let Some(path) = &cache_path {
        inferline::experiments::common::warm_cache_from(path, &cache);
    }
    let planner = Planner::new(&spec, &profiles).with_shared_cache(cache.clone());
    let ok = match planner.plan(&sample, slo) {
        Ok(plan) => {
            println!("  config:    {}", plan.config.summary(&spec));
            println!("  cost:      ${:.2}/hr", plan.cost_per_hour);
            println!("  est. P99:  {:.1} ms (SLO {:.0} ms)", plan.estimated_p99 * 1e3, slo * 1e3);
            println!("  search:    {} iterations; actions: {}", plan.iterations,
                     plan.actions_taken.join(", "));
            println!(
                "  estimator: {} sims ({} early-aborted, {} fast-accepted) + {} pruned, \
                 {} cache hits ({:.0}% hit rate), {} threads",
                plan.telemetry.cache_misses - plan.telemetry.pruned,
                plan.telemetry.early_aborts,
                plan.telemetry.early_accepts,
                plan.telemetry.pruned,
                plan.telemetry.cache_hits,
                plan.telemetry.hit_rate() * 100.0,
                plan.telemetry.threads
            );
            if args.bool("compare-cg") {
                for target in [CoarseTarget::Mean, CoarseTarget::Peak] {
                    let cg = coarse::plan(&spec, &profiles, &sample, slo, target);
                    println!(
                        "  {:?}: batch {} x {} units = ${:.2}/hr ({:.1}x InferLine)",
                        target, cg.batch, cg.units, cg.cost_per_hour,
                        cg.cost_per_hour / plan.cost_per_hour
                    );
                }
            }
            true
        }
        Err(e) => {
            inferline::log_error!("  {e}");
            false
        }
    };
    // Persist even after an infeasible search: the simulations it ran
    // (aborted bounds, exact P99s) answer the natural looser-SLO retry.
    if let Some(path) = &cache_path {
        inferline::experiments::common::persist_cache_to(path, &cache);
    }
    ok
}

fn cmd_profile(args: &Args) -> bool {
    if !args.reject_unknown("profile", &["artifacts", "out", "max-batch"]) {
        return false;
    }
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            inferline::log_error!("{e:#}");
            return false;
        }
    };
    let opts = phys_profile::ProfileOptions {
        max_batch: args.get("max-batch").and_then(|v| v.parse().ok()),
        ..Default::default()
    };
    println!("profiling {} models through PJRT (cpu)...", manifest.models.len());
    match phys_profile::profile_all(&manifest, &opts) {
        Ok(set) => {
            for (model, mp) in &set.models {
                if let Some(p) = mp.get(inferline::hardware::Hardware::Cpu) {
                    let pts: Vec<String> = p
                        .points
                        .iter()
                        .map(|&(b, l)| format!("b{b}:{:.2}ms", l * 1e3))
                        .collect();
                    println!("  {model:<14} {}", pts.join(" "));
                }
            }
            if let Some(out) = args.get("out") {
                if let Err(e) = set.save(std::path::Path::new(out)) {
                    inferline::log_error!("save failed: {e}");
                    return false;
                }
                println!("wrote {out}");
            }
            true
        }
        Err(e) => {
            inferline::log_error!("{e:#}");
            false
        }
    }
}

fn cmd_simulate(args: &Args) -> bool {
    let allowed = [
        "pipeline",
        "slo",
        "lambda",
        "cv",
        "duration",
        "faults",
        "seed",
        "trace-out",
        "series-out",
        "profiles",
    ];
    if !args.reject_unknown("simulate", &allowed) {
        return false;
    }
    let Some(spec) = get_pipeline(args) else { return false };
    let profiles = load_profiles(args);
    let slo = args.f64("slo", 0.15);
    let lambda = args.f64("lambda", 100.0);
    let cv = args.f64("cv", 1.0);
    let sample = gamma_trace(lambda, cv, 60.0, 42);
    let live = gamma_trace(lambda, cv, args.f64("duration", 120.0), 43);
    let plan = match Planner::new(&spec, &profiles).plan(&sample, slo) {
        Ok(p) => p,
        Err(e) => {
            inferline::log_error!("{e}");
            return false;
        }
    };
    // Optional chaos plan: compiled deterministically from the spec file,
    // the pipeline's stage count and --seed (default 42).
    let fault_plan = match args.get("faults") {
        None => None,
        Some(path) => {
            match inferline::simulator::faults::FaultSpec::load(std::path::Path::new(path)) {
                Ok(fs) => {
                    let seed = args.f64("seed", 42.0) as u64;
                    Some(fs.compile(spec.n_stages(), seed))
                }
                Err(e) => {
                    inferline::log_error!("{e}");
                    return false;
                }
            }
        }
    };
    // Telemetry exports ride on the recording probe; without either flag
    // the engine runs probe-less (bit-identical results either way).
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let series_out = args.get("series-out").map(PathBuf::from);
    let mut probe =
        (trace_out.is_some() || series_out.is_some()).then(|| RecordingProbe::new(slo));
    let result = match &mut probe {
        Some(p) => simulator::simulate_probed(
            &spec,
            &profiles,
            &plan.config,
            &live,
            &SimParams::default(),
            fault_plan.as_ref(),
            p,
        ),
        None => match &fault_plan {
            Some(faults) => simulator::simulate_with_faults(
                &spec, &profiles, &plan.config, &live, &SimParams::default(), faults,
            ),
            None => {
                simulator::simulate(&spec, &profiles, &plan.config, &live, &SimParams::default())
            }
        },
    };
    println!("config: {}", plan.config.summary(&spec));
    println!(
        "simulated {} queries: p50 {:.1} ms, p99 {:.1} ms, miss rate {:.3}%, cost ${:.2}",
        result.latencies.len(),
        stats::quantile(&result.latencies, 0.5) * 1e3,
        stats::p99(&result.latencies) * 1e3,
        result.miss_rate(slo) * 100.0,
        result.cost_dollars
    );
    if fault_plan.is_some() {
        println!(
            "faults: {} crashes, {} retries, {} shed",
            result.crashes, result.retries, result.shed
        );
    }
    for (i, st) in result.stage_stats.iter().enumerate() {
        println!(
            "  stage {:<14} batches {:>6}  mean batch {:>5.2}  max queue {:>5}",
            spec.stages[i].name, st.batches, st.mean_batch, st.max_queue
        );
    }
    if let Some(p) = probe {
        let report = p.finish();
        let a = &report.attribution;
        if let Some(stage) = a.blame_stage() {
            println!(
                "attribution: {} of {} completed queries missed the {slo}s SLO; \
                 blame stage {stage} ({}) with {:.0}% of missed latency",
                a.missed,
                a.completed,
                spec.stages[stage].name,
                a.blame_share(stage) * 100.0
            );
        } else {
            println!("attribution: no SLO misses among {} completed queries", a.completed);
        }
        if let Some(path) = &trace_out {
            let doc = report.chrome_trace();
            if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
                inferline::log_error!("could not write {}: {e}", path.display());
                return false;
            }
            println!(
                "wrote {} ({} sampled query span records)",
                path.display(),
                report.spans.len()
            );
        }
        if let Some(path) = &series_out {
            let mut text = String::from(ProbeReport::SERIES_HEADER);
            for row in report.series_csv() {
                text.push('\n');
                text.push_str(&row);
            }
            text.push('\n');
            if let Err(e) = std::fs::write(path, text) {
                inferline::log_error!("could not write {}: {e}", path.display());
                return false;
            }
            println!("wrote {} ({} time-series points)", path.display(), report.series.len());
        }
    }
    true
}

/// Peak resident-set size of this process in KiB (`VmHWM` from
/// `/proc/self/status`; `None` off Linux). The streamed smoke gates on
/// this — it is the one number that catches *any* accidental
/// materialization, wherever it hides.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// `stream`: run the constant-memory streamed Estimator on a scenario
/// spec. Arrivals come from the scenario's chunked arrival source
/// (never materialized), provisioning comes from planning for nominal
/// `--lambda` traffic — the robustness harness's "the operator planned
/// for nominal; the scenario is what arrived" convention — and the run
/// reports the aggregate summary plus its memory footprint.
fn cmd_stream(args: &Args) -> bool {
    let allowed = [
        "scenario",
        "pipeline",
        "slo",
        "lambda",
        "quick",
        "seed",
        "chunk",
        "planner",
        "max-rss-mb",
        "profiles",
    ];
    if !args.reject_unknown("stream", &allowed) {
        return false;
    }
    let Some(spec_path) = args.get("scenario") else {
        inferline::log_error!("--scenario <spec.json> is required");
        return false;
    };
    let Some(spec) = get_pipeline(args) else { return false };
    let profiles = load_profiles(args);
    let slo = args.f64("slo", 0.35);
    let lambda = args.f64("lambda", 100.0);
    let chunk = args.f64("chunk", 4096.0) as usize;
    let scenario_spec = match scenarios::ScenarioSpec::load(std::path::Path::new(spec_path)) {
        Ok(s) => s,
        Err(e) => {
            inferline::log_error!("{e}");
            return false;
        }
    };
    let seed = args.get("seed").and_then(|v| v.parse().ok()).unwrap_or(scenario_spec.seed);
    let scenario = scenario_spec.scenario_for(args.bool("quick"));
    let mut source = match scenario.source(seed) {
        Ok(s) => s,
        Err(e) => {
            inferline::log_error!("scenario {:?} failed to build: {e}", scenario_spec.name);
            return false;
        }
    };
    // CG-Peak is analytic (no simulation search), so the long-horizon CI
    // smoke uses it to keep provisioning off the measured path; the
    // default is the real InferLine planner.
    let sample = gamma_trace(lambda, 1.0, 60.0, 42);
    let config = match args.get("planner").unwrap_or("inferline") {
        "cg-peak" => coarse::plan(&spec, &profiles, &sample, slo, CoarseTarget::Peak).config,
        "inferline" => match Planner::new(&spec, &profiles).plan(&sample, slo) {
            Ok(p) => p.config,
            Err(e) => {
                inferline::log_error!("{e}");
                return false;
            }
        },
        other => {
            inferline::log_error!("unknown planner {other:?} (available: inferline, cg-peak)");
            return false;
        }
    };
    println!("config: {}", config.summary(&spec));
    println!(
        "streaming scenario {:?} (seed {seed}, chunk {chunk}) ...",
        scenario_spec.name
    );
    let summary = simulator::simulate_streamed(
        &spec,
        &profiles,
        &config,
        source.as_mut(),
        &SimParams::default(),
        slo,
        chunk,
    );
    println!(
        "streamed {} queries over {:.0}s: mean latency {:.1} ms, max {:.1} ms, \
         miss rate {:.3}%, cost ${:.2}",
        summary.queries,
        summary.horizon,
        summary.mean_latency() * 1e3,
        summary.max_latency * 1e3,
        summary.miss_rate() * 100.0,
        summary.cost_dollars
    );
    println!(
        "resident: peak {} query records ({:.4}% of the stream)",
        summary.peak_queries_resident,
        summary.peak_queries_resident as f64 / summary.queries.max(1) as f64 * 100.0
    );
    let ceiling_mb = args.get("max-rss-mb").and_then(|v| v.parse::<f64>().ok());
    match peak_rss_kb() {
        Some(kb) => {
            let mb = kb as f64 / 1024.0;
            println!("peak RSS: {mb:.1} MiB");
            if let Some(ceiling) = ceiling_mb {
                if mb > ceiling {
                    inferline::log_error!("peak RSS {mb:.1} MiB exceeds the {ceiling} MiB ceiling");
                    return false;
                }
            }
        }
        None => {
            if ceiling_mb.is_some() {
                inferline::log_error!("--max-rss-mb needs /proc/self/status (Linux only)");
                return false;
            }
        }
    }
    true
}

fn cmd_serve(args: &Args) -> bool {
    let allowed = ["pipeline", "lambda", "duration", "slo", "backend", "artifacts", "profiles"];
    if !args.reject_unknown("serve", &allowed) {
        return false;
    }
    let Some(spec) = get_pipeline(args) else { return false };
    let profiles = load_profiles(args);
    let lambda = args.f64("lambda", 20.0);
    let duration = args.f64("duration", 10.0);
    let slo = args.f64("slo", 0.3);
    let backend_kind = args.get("backend").unwrap_or("calibrated");
    let sample = gamma_trace(lambda, 1.0, 30.0, 42);
    let plan = match Planner::new(&spec, &profiles).plan(&sample, slo) {
        Ok(p) => p,
        Err(e) => {
            inferline::log_error!("{e}");
            return false;
        }
    };
    println!("serving {} at λ={lambda} for {duration}s on {backend_kind} backend", spec.name);
    println!("  config: {}", plan.config.summary(&spec));
    let backends: Vec<Backend> = match backend_kind {
        "pjrt" => {
            let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
            let manifest = match Manifest::load(&dir) {
                Ok(m) => std::sync::Arc::new(m),
                Err(e) => {
                    inferline::log_error!("{e:#}");
                    return false;
                }
            };
            spec.stages.iter().map(|_| Backend::Pjrt { manifest: manifest.clone() }).collect()
        }
        _ => spec
            .stages
            .iter()
            .zip(&plan.config.stages)
            .map(|(s, c)| Backend::Calibrated {
                profile: profiles.get(&s.model).get(c.hw).unwrap().clone(),
            })
            .collect(),
    };
    let live = gamma_trace(lambda, 1.0, duration, 77);
    let n = live.len();
    let engine = match ServingEngine::start(&spec, &plan.config, backends) {
        Ok(e) => e,
        Err(e) => {
            inferline::log_error!("{e:#}");
            return false;
        }
    };
    let result = engine.serve_trace(&live, 1.0, 7);
    println!(
        "  served {}/{} queries in {:.1}s ({:.1} qps): p50 {:.1} ms  p99 {:.1} ms  attainment {:.2}%",
        result.latencies.len(),
        n,
        result.makespan,
        result.achieved_qps,
        stats::quantile(&result.latencies, 0.5) * 1e3,
        stats::p99(&result.latencies) * 1e3,
        stats::attainment(&result.latencies, slo) * 100.0
    );
    result.latencies.len() == n
}

/// Parse `--seed` for the report-writing experiments: exact u64 (the
/// reports are bit-reproducible per seed; parse as u64, not via f64, so
/// every value round-trips), below 2^53 (report and budget-ledger seeds
/// are JSON numbers, and only such integers round-trip exactly). `None`
/// — after an error message — on a malformed or oversized value: a
/// typo'd seed must not silently fall back to the default and
/// masquerade as a run at the requested seed.
fn report_seed(args: &Args) -> Option<u64> {
    let seed: u64 = match args.get("seed") {
        None => 42,
        Some(v) => match v.parse() {
            Ok(s) => s,
            Err(_) => {
                inferline::log_error!("--seed {v:?} is not an unsigned integer");
                return None;
            }
        },
    };
    if seed >= (1u64 << 53) {
        inferline::log_error!(
            "--seed {seed} exceeds 2^53 and cannot round-trip through the report"
        );
        return None;
    }
    Some(seed)
}

fn cmd_experiment(args: &Args) -> bool {
    if !args.reject_unknown("experiment", &["quick", "seed", "cache", "no-cache"]) {
        return false;
    }
    let Some(name) = args.positional.first() else {
        inferline::log_error!("experiment id required: {:?}", inferline::experiments::ALL_FIGURES);
        return false;
    };
    let quick = args.bool("quick");
    if name == "robustness" {
        // Separately dispatched so the seed flag reaches the harness.
        let Some(seed) = report_seed(args) else { return false };
        let ctx = inferline::experiments::Ctx::new(quick).with_cache(args.cache_path(true));
        return inferline::experiments::robustness::run(&ctx, seed);
    }
    if name == "fleet" {
        let Some(seed) = report_seed(args) else { return false };
        let ctx = inferline::experiments::Ctx::new(quick).with_cache(args.cache_path(true));
        return inferline::experiments::fleet::run(&ctx, seed);
    }
    if name == "sweep" {
        // Separately dispatched so the cache flags reach the harness:
        // the sweep persists its shared estimator cache across processes
        // by default (disable with --no-cache).
        let ctx = inferline::experiments::Ctx::new(quick).with_cache(args.cache_path(true));
        inferline::experiments::run_sweep(&ctx);
        return true;
    }
    if !inferline::experiments::run_by_name(name, quick) {
        inferline::log_error!(
            "unknown experiment {name:?}: {:?}",
            inferline::experiments::ALL_FIGURES
        );
        return false;
    }
    true
}

/// `budget check` / `budget update`: the SLO budget ledger over the
/// robustness report (see `experiments::budgets` for file format and
/// re-baselining workflow). `check` is the CI gate: nonzero exit on any
/// violated scenario budget.
fn cmd_budget(args: &Args) -> bool {
    if !args.reject_unknown("budget", &["report", "budgets"]) {
        return false;
    }
    let report = PathBuf::from(args.get("report").unwrap_or("results/robustness.json"));
    let budgets = PathBuf::from(args.get("budgets").unwrap_or("BUDGETS.json"));
    match args.positional.first().map(String::as_str) {
        Some("check") | None => inferline::experiments::budgets::run_check(&report, &budgets),
        Some("update") => inferline::experiments::budgets::run_update(&report, &budgets),
        Some(other) => {
            inferline::log_error!("unknown budget action {other:?} (available: check, update)");
            false
        }
    }
}

fn cmd_bench(args: &Args) -> bool {
    if !args.reject_unknown("bench", &["out", "quick", "current", "baseline"]) {
        return false;
    }
    let what = args.positional.first().map(String::as_str).unwrap_or("estimator");
    match what {
        "estimator" => {
            let out = PathBuf::from(args.get("out").unwrap_or("BENCH_estimator.json"));
            match inferline::experiments::estbench::run(&out, args.bool("quick")) {
                Ok(()) => true,
                Err(e) => {
                    inferline::log_error!("bench failed: {e}");
                    false
                }
            }
        }
        // The perf ledger over the checked-in baseline (see
        // `experiments::benchcheck` for the ratio-threshold semantics and
        // re-baselining workflow). With no --current, both actions run
        // the benchmark in-process at the requested mode.
        "check" | "update" => {
            let baseline = PathBuf::from(args.get("baseline").unwrap_or("BENCH_estimator.json"));
            let current = args.get("current").map(PathBuf::from);
            let run = if what == "check" {
                inferline::experiments::benchcheck::run_check
            } else {
                inferline::experiments::benchcheck::run_update
            };
            run(current.as_deref(), &baseline, args.bool("quick"))
        }
        other => {
            inferline::log_error!("unknown bench {other:?} (available: estimator, check, update)");
            false
        }
    }
}

fn cmd_trace(args: &Args) -> bool {
    if !args.reject_unknown("trace", &["kind", "out", "lambda", "cv", "duration", "seed"]) {
        return false;
    }
    let out = PathBuf::from(args.get("out").unwrap_or("trace.txt"));
    if args.positional.first().map(String::as_str) == Some("scenario") {
        return cmd_trace_scenario(args, &out);
    }
    let kind = args.get("kind").unwrap_or("gamma");
    let trace: Trace = match kind {
        "gamma" => gamma_trace(
            args.f64("lambda", 100.0),
            args.f64("cv", 1.0),
            args.f64("duration", 60.0),
            args.f64("seed", 42.0) as u64,
        ),
        "big-spike" => autoscale::big_spike_trace(args.f64("seed", 42.0) as u64),
        "instant-spike" => autoscale::instant_spike_trace(args.f64("seed", 42.0) as u64),
        other => {
            inferline::log_error!("unknown trace kind {other:?}");
            return false;
        }
    };
    println!(
        "generated {} arrivals over {:.0}s (mean {:.1} qps, cv {:.2})",
        trace.len(),
        trace.duration(),
        trace.mean_rate(),
        trace.cv()
    );
    match trace.save(&out) {
        Ok(()) => {
            println!("wrote {}", out.display());
            true
        }
        Err(e) => {
            inferline::log_error!("write failed: {e}");
            false
        }
    }
}

/// `trace scenario <spec.json>`: build a declarative scenario spec into
/// an arrival-trace file (deterministic in the spec's — or `--seed`'s —
/// seed).
fn cmd_trace_scenario(args: &Args, out: &std::path::Path) -> bool {
    let Some(spec_path) = args.positional.get(1) else {
        inferline::log_error!(
            "usage: inferline trace scenario <spec.json> [--out <file>] [--seed <n>]"
        );
        return false;
    };
    let spec = match scenarios::ScenarioSpec::load(std::path::Path::new(spec_path)) {
        Ok(s) => s,
        Err(e) => {
            inferline::log_error!("{e}");
            return false;
        }
    };
    let seed = args.get("seed").and_then(|v| v.parse().ok()).unwrap_or(spec.seed);
    let trace = match spec.scenario.build(seed) {
        Ok(t) => t,
        Err(e) => {
            inferline::log_error!("scenario {:?} failed to build: {e}", spec.name);
            return false;
        }
    };
    println!(
        "scenario {:?} (seed {seed}): {} arrivals over {:.0}s (mean {:.1} qps, cv {:.2})",
        spec.name,
        trace.len(),
        trace.duration(),
        trace.mean_rate(),
        trace.cv()
    );
    match trace.save(out) {
        Ok(()) => {
            println!("wrote {}", out.display());
            true
        }
        Err(e) => {
            inferline::log_error!("write failed: {e}");
            false
        }
    }
}
