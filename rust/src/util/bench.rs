//! Criterion-style micro-bench harness (offline replacement for criterion).
//!
//! Each `rust/benches/*.rs` target (harness = false) uses this to time its
//! hot loops and to print the paper-figure rows. Reports mean / p50 / p95
//! per iteration and derived throughput.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} mean {:>10} p50 {:>10} p95 {:>10} ({} samples)",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p95_s),
            self.samples
        );
    }
}

/// Human-readable seconds.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        samples,
        mean_s: times.iter().sum::<f64>() / samples as f64,
        p50_s: super::stats::quantile_sorted(&times, 0.5),
        p95_s: super::stats::quantile_sorted(&times, 0.95),
    };
    result.report();
    result
}

/// Prevent the optimizer from discarding a value (std::hint based).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a figure/table header in a consistent style across benches.
pub fn figure_header(fig: &str, caption: &str) {
    println!("\n=== {fig}: {caption} ===");
}

/// Print one figure row: a label plus (column, value) pairs.
pub fn figure_row(label: &str, cols: &[(&str, String)]) {
    print!("{label:<44}");
    for (k, v) in cols {
        print!("  {k}={v}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-spin", 2, 16, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_s >= 0.0 && r.p50_s <= r.p95_s + 1e-12);
        assert_eq!(r.samples, 16);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500us");
        assert_eq!(fmt_duration(2.5e-9), "2.5ns");
    }
}
