//! Deterministic pseudo-random numbers: xoshiro256++ + distribution samplers.
//!
//! This image has no crate network access (`rand`/`rand_distr` are
//! unavailable), so the library carries its own small, well-tested RNG:
//! splitmix64 seeding, xoshiro256++ generation, Box–Muller normals and the
//! Marsaglia–Tsang gamma sampler the workload generator needs (paper §6
//! samples inter-arrival times from a Gamma distribution parameterised by
//! rate λ and coefficient of variation CV).
//!
//! Everything in InferLine that draws randomness takes an explicit seed, so
//! experiments are bit-for-bit reproducible.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. per-query routing RNG).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) excluding 0 (safe for log()).
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang; handles shape < 1 via boost.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0, "gamma({shape}, {scale})");
        if shape < 1.0 {
            // Boosting: X ~ Gamma(a+1), U^(1/a) correction.
            let u = self.f64_open();
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64_open();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2
                || u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64_open().ln() / rate
    }

    /// Gamma-distributed inter-arrival time for a process with mean rate
    /// `lambda` and coefficient of variation `cv` (paper §6): shape = 1/cv²,
    /// scale = cv²/λ, so E = 1/λ and CV = cv.
    pub fn interarrival(&mut self, lambda: f64, cv: f64) -> f64 {
        assert!(lambda > 0.0 && cv > 0.0);
        let shape = 1.0 / (cv * cv);
        let scale = cv * cv / lambda;
        self.gamma(shape, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var.sqrt())
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (mean, _) = moments(&xs);
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn bounded_usize_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.usize(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let (mean, std) = moments(&xs);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((std - 1.0).abs() < 0.02, "std {std}");
    }

    #[test]
    fn gamma_moments_shape_ge_one() {
        let mut r = Rng::new(5);
        let (shape, scale) = (4.0, 0.5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.gamma(shape, scale)).collect();
        let (mean, std) = moments(&xs);
        assert!((mean - shape * scale).abs() < 0.03, "mean {mean}");
        assert!((std - shape.sqrt() * scale).abs() < 0.03, "std {std}");
    }

    #[test]
    fn gamma_moments_shape_lt_one() {
        let mut r = Rng::new(6);
        let (shape, scale) = (0.25, 2.0);
        let xs: Vec<f64> = (0..100_000).map(|_| r.gamma(shape, scale)).collect();
        let (mean, std) = moments(&xs);
        assert!((mean - shape * scale).abs() < 0.05, "mean {mean}");
        assert!((std - shape.sqrt() * scale).abs() < 0.1, "std {std}");
    }

    #[test]
    fn interarrival_matches_lambda_and_cv() {
        let mut r = Rng::new(9);
        for &(lambda, cv) in &[(100.0, 1.0), (150.0, 4.0), (50.0, 0.5)] {
            let xs: Vec<f64> =
                (0..100_000).map(|_| r.interarrival(lambda, cv)).collect();
            let (mean, std) = moments(&xs);
            let got_lambda = 1.0 / mean;
            let got_cv = std / mean;
            assert!(
                (got_lambda - lambda).abs() / lambda < 0.05,
                "lambda {got_lambda} want {lambda}"
            );
            assert!((got_cv - cv).abs() / cv < 0.05, "cv {got_cv} want {cv}");
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| r.exp(4.0)).collect();
        let (mean, _) = moments(&xs);
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
