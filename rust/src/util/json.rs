//! Minimal JSON encode/decode (offline replacement for serde_json).
//!
//! Used for the artifact manifest, profile stores, traces and experiment
//! result files. Supports the full JSON value model; numbers are f64
//! (adequate for everything this library persists).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful message (for trusted files).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing key {key:?} in {self}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// A number, or `null` when `x` is not finite. JSON has no encoding
    /// for NaN/∞; consumers (figures, the budget checker) must see "no
    /// data", never a fabricated value. `Display` has the same backstop
    /// for a bare `Json::Num(NAN)`; this constructor states the intent
    /// at the call site. Use it for any metric that can be undefined
    /// (miss rates over empty windows, ratios with a zero denominator).
    pub fn num_or_null(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    /// Pretty-printed encoding (2-space indent, canonical key order,
    /// trailing newline) for checked-in, human-reviewed documents like
    /// `BUDGETS.json` — a re-baseline must produce a reviewable diff.
    /// [`Json::parse`] accepts both forms; `to_string` stays compact
    /// for machine artifacts.
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(depth + 1));
                    x.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(depth + 1));
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at {}", p.at(p.pos)));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // Backstop: NaN/∞ have no JSON representation, and
                    // emitting them would corrupt the whole document.
                    // Encode as null ("no data"), like num_or_null.
                    write!(f, "null")
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Required numeric field with a path-prefixed error message — the
/// shared shape of the scenario-spec and budget-ledger parsers, so
/// their "name the offending node" error convention cannot drift.
pub fn req_f64_at(node: &Json, key: &str, path: &str) -> Result<f64, String> {
    node.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing numeric field {key:?}"))
}

/// Optional numeric field: `None` when absent or JSON `null`, the same
/// path-prefixed error as [`req_f64_at`] when present but non-numeric.
pub fn opt_f64_at(node: &Json, key: &str, path: &str) -> Result<Option<f64>, String> {
    match node.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| format!("{path}: field {key:?} must be a number")),
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    /// "line L, byte B" for error messages. Line is 1-based, counted
    /// by newlines before `pos`, so errors in a multi-line document
    /// (a scenario spec, a budget ledger) name the offending line
    /// directly instead of just a byte offset.
    fn at(&self, pos: usize) -> String {
        let line = self.bytes[..pos.min(self.bytes.len())]
            .iter()
            .filter(|&&b| b == b'\n')
            .count()
            + 1;
        format!("line {line}, byte {pos}")
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at {} got {:?}",
                c as char,
                self.at(self.pos),
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at {}", self.at(self.pos))),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at {}", self.at(self.pos)))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {}", self.at(start)))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (may be multi-byte).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    return Err(format!(
                        "expected , or ] at {} got {other:?}",
                        self.at(self.pos)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(format!(
                        "expected , or }} at {} got {other:?}",
                        self.at(self.pos)
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
        assert_eq!(v.req("c"), &Json::Null);
    }

    #[test]
    fn roundtrip_object_is_stable() {
        let mut o = Json::obj();
        o.set("name", "resnet").set("batch", 32usize).set("ok", true);
        let text = o.to_string();
        assert_eq!(Json::parse(&text).unwrap(), o);
        // BTreeMap ordering -> deterministic bytes.
        assert_eq!(text, Json::parse(&text).unwrap().to_string());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\te".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        let u = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(u.as_str(), Some("Aé"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∞"));
    }

    #[test]
    fn scientific_numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "{\"a\":}"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn parse_errors_name_the_offending_line() {
        // The bad literal sits on line 3 of a multi-line document.
        let text = "{\n  \"a\": 1,\n  \"b\": nope\n}";
        let err = Json::parse(text).unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        // Trailing data after a complete value, on line 2.
        let err = Json::parse("1\n2").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        // Single-line input still reads naturally.
        let err = Json::parse("{\"a\":}").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn pretty_roundtrips_and_is_line_oriented() {
        let v = Json::parse(
            r#"{"b": [1, {"x": null}, "s"], "a": 2.5, "empty_arr": [], "empty_obj": {}}"#,
        )
        .unwrap();
        let pretty = v.to_pretty_string();
        assert_eq!(Json::parse(&pretty).unwrap(), v, "{pretty}");
        assert!(pretty.ends_with('\n'));
        assert!(pretty.lines().count() > 5, "{pretty}");
        // Empty containers stay compact; scalars are unchanged.
        assert!(pretty.contains("\"empty_arr\": []"), "{pretty}");
        assert!(pretty.contains("\"empty_obj\": {}"), "{pretty}");
        assert_eq!(Json::Num(2.0).to_pretty_string(), "2\n");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::num_or_null(0.25), Json::Num(0.25));
        assert_eq!(Json::num_or_null(f64::NAN), Json::Null);
        assert_eq!(Json::num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(Json::num_or_null(f64::NEG_INFINITY), Json::Null);
        // Even a Num constructed directly must never emit invalid JSON.
        let mut o = Json::obj();
        o.set("miss", Json::Num(f64::NAN)).set("ratio", Json::Num(f64::INFINITY));
        let text = o.to_string();
        assert_eq!(text, r#"{"miss":null,"ratio":null}"#);
        assert!(Json::parse(&text).is_ok(), "{text}");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"format": "hlo-text", "models": {"langid": {
            "in_dim": 256, "out_dim": 32, "description": "d",
            "batches": {"1": {"file": "langid_b1.hlo.txt", "bytes": 10}}}}}"#;
        let v = Json::parse(text).unwrap();
        let m = v.req("models").req("langid");
        assert_eq!(m.req("in_dim").as_usize(), Some(256));
        assert_eq!(
            m.req("batches").req("1").req("file").as_str(),
            Some("langid_b1.hlo.txt")
        );
    }
}
