//! Small statistics helpers: quantiles, moments, inter-arrival CV.

/// Quantile of a sample by linear interpolation on the order statistics
/// (numpy's default definition). `q` in [0, 1]. Returns NaN on empty
/// input. Computed by O(n) selection, not an O(n log n) sort — the value
/// is bit-identical to sorting first (`tests/estimator_fast_path.rs`).
pub fn quantile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut scratch: Vec<f64> = samples.to_vec();
    quantile_in_place(&mut scratch, q)
}

/// [`quantile`] on a mutable buffer the caller is willing to have
/// reordered: avoids the scratch copy. This is the Estimator feasibility
/// hot path — `p99` over every simulated latency, once per candidate.
pub fn quantile_in_place(samples: &mut [f64], q: f64) -> f64 {
    let n = samples.len();
    if n == 0 {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    // Partition so samples[lo] holds the lo-th order statistic and
    // everything above it lands (unordered) in `above`.
    let (_, lo_val, above) =
        samples.select_nth_unstable_by(lo, |a, b| a.partial_cmp(b).unwrap());
    let lo_val = *lo_val;
    if pos.ceil() as usize == lo {
        return lo_val;
    }
    // The (lo+1)-th order statistic is the minimum of the upper partition.
    let hi_val = above.iter().copied().fold(f64::INFINITY, f64::min);
    let frac = pos - lo as f64;
    // Same clamp as `quantile_sorted` (bit-identical results, and the
    // early-abort bound needs quantile(q) >= sorted[floor(pos)] exactly).
    (lo_val * (1.0 - frac) + hi_val * frac).clamp(lo_val, hi_val)
}

/// Quantile of an already-sorted sample.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        // Clamp: fp rounding in the lerp can land an ulp outside
        // [sorted[lo], sorted[hi]], but a quantile must lie within its
        // bracketing order statistics — the Estimator's early-abort bound
        // relies on quantile(q) >= sorted[floor(pos)] holding exactly.
        (sorted[lo] * (1.0 - frac) + sorted[hi] * frac).clamp(sorted[lo], sorted[hi])
    }
}

/// P99 convenience wrapper.
pub fn p99(samples: &[f64]) -> f64 {
    quantile(samples, 0.99)
}

/// P99 by in-place selection (reorders `samples`, saves the copy).
pub fn p99_in_place(samples: &mut [f64]) -> f64 {
    quantile_in_place(samples, 0.99)
}

/// Sample mean; NaN on empty.
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Population standard deviation; NaN on empty.
pub fn std_dev(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let m = mean(samples);
    (samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / samples.len() as f64)
        .sqrt()
}

/// Coefficient of variation of inter-arrival times derived from arrival
/// timestamps (paper §2.1: CV = σ/μ of the inter-arrival process).
pub fn interarrival_cv(arrivals: &[f64]) -> f64 {
    if arrivals.len() < 3 {
        return f64::NAN;
    }
    let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
    std_dev(&gaps) / mean(&gaps)
}

/// Mean arrival rate (queries/sec) from timestamps.
pub fn arrival_rate(arrivals: &[f64]) -> f64 {
    if arrivals.len() < 2 {
        return f64::NAN;
    }
    let span = arrivals[arrivals.len() - 1] - arrivals[0];
    if span <= 0.0 {
        return f64::NAN;
    }
    (arrivals.len() - 1) as f64 / span
}

/// Fraction of samples at or below the threshold (SLO attainment).
pub fn attainment(latencies: &[f64], slo: f64) -> f64 {
    if latencies.is_empty() {
        return 1.0;
    }
    latencies.iter().filter(|&&l| l <= slo).count() as f64 / latencies.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.99) - 9.9).abs() < 1e-9);
    }

    #[test]
    fn quantile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }

    #[test]
    fn selection_quantile_matches_sorted_quantile() {
        // Including duplicates and a two-element edge case.
        let cases: &[&[f64]] = &[
            &[7.0],
            &[2.0, 1.0],
            &[3.0, 3.0, 3.0, 1.0, 9.0],
            &[0.5, 0.25, 0.125, 8.0, 4.0, 2.0, 1.0, 0.0625],
        ];
        for xs in cases {
            let mut sorted = xs.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let by_select = quantile(xs, q);
                let by_sort = quantile_sorted(&sorted, q);
                assert_eq!(by_select.to_bits(), by_sort.to_bits(), "{xs:?} q={q}");
                let mut buf = xs.to_vec();
                assert_eq!(quantile_in_place(&mut buf, q).to_bits(), by_sort.to_bits());
            }
        }
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(quantile(&[], 0.5).is_nan());
        assert!(mean(&[]).is_nan());
        assert!(std_dev(&[]).is_nan());
    }

    #[test]
    fn attainment_counts() {
        let lat = [0.1, 0.2, 0.3, 0.4];
        assert_eq!(attainment(&lat, 0.25), 0.5);
        assert_eq!(attainment(&lat, 1.0), 1.0);
        assert_eq!(attainment(&lat, 0.05), 0.0);
        assert_eq!(attainment(&[], 0.1), 1.0);
    }

    #[test]
    fn interarrival_stats() {
        // Uniform 10 qps arrivals: CV = 0, rate = 10.
        let arrivals: Vec<f64> = (0..101).map(|i| i as f64 * 0.1).collect();
        assert!((arrival_rate(&arrivals) - 10.0).abs() < 1e-9);
        assert!(interarrival_cv(&arrivals).abs() < 1e-9);
    }
}
