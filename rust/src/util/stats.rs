//! Small statistics helpers: quantiles, moments, inter-arrival CV.

/// Quantile of a sample by linear interpolation on the sorted data
/// (numpy's default). `q` in [0, 1]. Returns NaN on empty input.
pub fn quantile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&sorted, q)
}

/// Quantile of an already-sorted sample.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// P99 convenience wrapper.
pub fn p99(samples: &[f64]) -> f64 {
    quantile(samples, 0.99)
}

/// Sample mean; NaN on empty.
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Population standard deviation; NaN on empty.
pub fn std_dev(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let m = mean(samples);
    (samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / samples.len() as f64)
        .sqrt()
}

/// Coefficient of variation of inter-arrival times derived from arrival
/// timestamps (paper §2.1: CV = σ/μ of the inter-arrival process).
pub fn interarrival_cv(arrivals: &[f64]) -> f64 {
    if arrivals.len() < 3 {
        return f64::NAN;
    }
    let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
    std_dev(&gaps) / mean(&gaps)
}

/// Mean arrival rate (queries/sec) from timestamps.
pub fn arrival_rate(arrivals: &[f64]) -> f64 {
    if arrivals.len() < 2 {
        return f64::NAN;
    }
    let span = arrivals[arrivals.len() - 1] - arrivals[0];
    if span <= 0.0 {
        return f64::NAN;
    }
    (arrivals.len() - 1) as f64 / span
}

/// Fraction of samples at or below the threshold (SLO attainment).
pub fn attainment(latencies: &[f64], slo: f64) -> f64 {
    if latencies.is_empty() {
        return 1.0;
    }
    latencies.iter().filter(|&&l| l <= slo).count() as f64 / latencies.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.99) - 9.9).abs() < 1e-9);
    }

    #[test]
    fn quantile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(quantile(&[], 0.5).is_nan());
        assert!(mean(&[]).is_nan());
        assert!(std_dev(&[]).is_nan());
    }

    #[test]
    fn attainment_counts() {
        let lat = [0.1, 0.2, 0.3, 0.4];
        assert_eq!(attainment(&lat, 0.25), 0.5);
        assert_eq!(attainment(&lat, 1.0), 1.0);
        assert_eq!(attainment(&lat, 0.05), 0.0);
        assert_eq!(attainment(&[], 0.1), 1.0);
    }

    #[test]
    fn interarrival_stats() {
        // Uniform 10 qps arrivals: CV = 0, rate = 10.
        let arrivals: Vec<f64> = (0..101).map(|i| i as f64 * 0.1).collect();
        assert!((arrival_rate(&arrivals) - 10.0).abs() < 1e-9);
        assert!(interarrival_cv(&arrivals).abs() < 1e-9);
    }
}
