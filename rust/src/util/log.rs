//! A tiny leveled stderr logger (no deps, offline-friendly), unifying
//! the previously ad-hoc `eprintln!` diagnostics so harness runs are
//! quiet by default and debuggable on demand.
//!
//! The level is a process-global [`AtomicU8`], defaulting to [`Level::Warn`]
//! and settable once at startup from `--verbose` / the `INFERLINE_LOG`
//! environment variable ([`init`]); `error` is reserved for failures the
//! user must see (gate violations, unusable inputs), `warn` for degraded
//! but continuing runs, `info` for progress narration, `debug` for
//! development tracing. Call sites use the `log_error!` / `log_warn!` /
//! `log_info!` / `log_debug!` macros (exported at the crate root), which
//! skip formatting entirely when the level is filtered out.
//!
//! CI-scraped *stdout* lines (e.g. the estimator-cache "warm-started
//! with N entries" message) are deliberately not routed through here:
//! they are machine-read output, not diagnostics.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

/// Quiet-by-default: errors and warnings only.
static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Set the global level (normally via [`init`]; tests may call directly).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` currently emitted? The macros consult this before
/// formatting, so filtered calls cost one relaxed atomic load.
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Initialize the level from the environment: `INFERLINE_LOG` may name a
/// level (`error` | `warn` | `info` | `debug`; unknown values are
/// ignored), and `--verbose` raises whatever that produced to `Debug`.
pub fn init(verbose: bool) {
    if let Ok(v) = std::env::var("INFERLINE_LOG") {
        match v.to_ascii_lowercase().as_str() {
            "error" => set_level(Level::Error),
            "warn" => set_level(Level::Warn),
            "info" => set_level(Level::Info),
            "debug" => set_level(Level::Debug),
            _ => {}
        }
    }
    if verbose {
        set_level(Level::Debug);
    }
}

/// Log at error level (stderr; always on short of tampering with
/// [`set_level`] — `Error` is the floor).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Error) {
            eprintln!($($arg)*);
        }
    };
}

/// Log at warn level (stderr; on by default).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            eprintln!($($arg)*);
        }
    };
}

/// Log at info level (stderr; off by default, on with `--verbose` or
/// `INFERLINE_LOG=info`).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            eprintln!($($arg)*);
        }
    };
}

/// Log at debug level (stderr; development tracing).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_filter_monotonically() {
        // NB: the level is process-global, so this test owns it for its
        // duration and restores the default before returning.
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_level(Level::Warn);
    }
}
