//! Minimal scoped fan-out helper (offline replacement for rayon-style
//! parallel iterators — no crate network access on this image).
//!
//! Used by the Planner's candidate evaluation and the experiment
//! scenario sweep: both need "evaluate N independent tasks on up to W
//! threads and get the results back in index order", which is exactly
//! what [`parallel_map_indexed`] provides. Index-ordered results are the
//! key property — callers replay deterministic selection logic over them
//! regardless of which thread computed what.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Evaluate `f(0)..f(n-1)` across up to `workers` scoped threads and
/// return the results in index order. Tasks are work-stolen off a shared
/// atomic counter, so uneven task costs balance automatically. Falls
/// back to a plain serial loop when one worker (or at most one task)
/// suffices. Panics in `f` propagate to the caller.
pub fn parallel_map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.min(n).max(1);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let idx = next.fetch_add(1, Ordering::Relaxed);
                        if idx >= n {
                            break;
                        }
                        local.push((idx, f(idx)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (idx, v) in h.join().expect("parallel_map worker panicked") {
                out[idx] = Some(v);
            }
        }
    });
    out.into_iter()
        .map(|v| v.expect("every index is computed exactly once"))
        .collect()
}

/// Default fan-out width: one worker per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order() {
        for workers in [1, 2, 3, 8, 64] {
            let got = parallel_map_indexed(37, workers, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "workers = {workers}");
        }
    }

    #[test]
    fn handles_empty_and_single_task() {
        let empty: Vec<usize> = parallel_map_indexed(0, 4, |i| i);
        assert!(empty.is_empty());
        assert_eq!(parallel_map_indexed(1, 4, |i| i + 10), vec![10]);
    }

    #[test]
    fn uneven_tasks_all_complete() {
        // Tasks of wildly different cost: the atomic work counter must
        // hand every index to exactly one worker.
        let got = parallel_map_indexed(64, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }
}
