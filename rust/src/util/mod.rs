//! Offline-friendly utility layer: RNG, JSON, stats, bench + property
//! harnesses (see DESIGN.md §8 — no crate network access on this image).

pub mod bench;
pub mod json;
pub mod log;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
