//! Tiny property-testing driver (offline replacement for proptest).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNGs. On a panic
//! it re-raises with the failing case index and seed so the case can be
//! replayed deterministically with `replay(seed, f)`.

use super::rng::Rng;

/// Run `f` for `cases` pseudo-random cases. Each case gets its own seeded
/// RNG. Panics (with seed info) if any case fails.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut f: F) {
    for i in 0..cases {
        let seed = 0xD00D_F00D ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 xor self is zero", 64, |rng| {
            let x = rng.next_u64();
            assert_eq!(x ^ x, 0);
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn reports_failing_case() {
        check("always fails eventually", 16, |rng| {
            assert!(rng.f64() < 0.5, "value too large");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        replay(42, |rng| first = Some(rng.next_u64()));
        let mut second = None;
        replay(42, |rng| second = Some(rng.next_u64()));
        assert_eq!(first, second);
    }
}
