//! Fleet provisioning experiment: plan synthetic tenant populations at
//! 10 → 100 → 1000 tenants against a shared inventory, then serve every
//! tenant's planned configuration against its live scenario family.
//!
//! Reported per scale (`results/fleet.json`, format [`REPORT_FORMAT`]):
//! total fleet $/hr with and without prefix-stage sharing, the sharing
//! savings, per-tier device usage, per-tenant SLO miss rates under live
//! traffic, and a constrained-inventory replan (GPU capacity capped
//! below the unbounded fleet's demand) exercising the packer's local
//! repair. The whole report is a deterministic function of the seed —
//! the same `(seed, quick)` pair always writes the same bytes. Quick
//! mode (CI) stops at 100 tenants and serves compressed schedules.

use std::sync::Arc;

use crate::fleet::{synth_tenants, FleetPlan, FleetPlanner, FleetSpec, SynthTenant};
use crate::hardware::{Hardware, Inventory};
use crate::planner::EstimatorCache;
use crate::profiler::analytic::paper_profiles;
use crate::simulator::{simulate, SimParams};
use crate::util::json::Json;
use crate::util::par::{default_workers, parallel_map_indexed};
use crate::workload::scenarios;

use super::common::{csv_num, Ctx};
use super::robustness::family_scenario;

/// Format tag of `fleet.json`.
pub const REPORT_FORMAT: &str = "inferline-fleet-v1";

/// Tenant scales of the sweep (paper-style order-of-magnitude steps).
pub const SCALES: [usize; 3] = [10, 100, 1000];

/// Seed stream for per-tenant live traces (disjoint from the synth
/// generator's 900/1000+ tags and the robustness harness's streams).
const LIVE_TAG: u64 = 10_000;

/// Fraction of the unbounded fleet's GPU demand the constrained replan
/// is allowed (caps the costlier tier the fleet actually leans on).
const CONSTRAIN_FRACTION: f64 = 0.75;

/// One tenant's serving outcome.
struct TenantOutcome {
    miss_rate: f64,
}

/// One scale's planning + serving results.
struct ScaleResult {
    n: usize,
    plan: FleetPlan,
    outcomes: Vec<TenantOutcome>,
    /// (capped tier, cap, repairs, total $/hr) on success, error text
    /// otherwise.
    constrained: Result<(Hardware, usize, usize, f64), String>,
}

fn run_scale(
    n: usize,
    seed: u64,
    quick: bool,
    cache: &Arc<EstimatorCache>,
) -> Result<ScaleResult, String> {
    let profiles = paper_profiles();
    let sample_secs = if quick { 25.0 } else { 60.0 };
    let population = synth_tenants(n, seed, sample_secs);
    let spec = FleetSpec {
        tenants: population.iter().map(|t| t.tenant.clone()).collect(),
        inventory: Inventory::unbounded(),
    };
    let planner = FleetPlanner::new(&profiles).with_shared_cache(Arc::clone(cache));
    let plan = planner.plan(&spec).map_err(|e| e.to_string())?;

    // Constrained replan: cap the tier the unbounded fleet uses most
    // (by device count) below its demand, forcing local repair.
    let (cap_tier, _) = Hardware::ALL
        .into_iter()
        .map(|hw| (hw, plan.usage[hw.index()]))
        .max_by_key(|&(hw, used)| (used, std::cmp::Reverse(hw.index())))
        .expect("three tiers");
    let demand = plan.usage[cap_tier.index()];
    let cap = ((demand as f64 * CONSTRAIN_FRACTION) as usize).max(1);
    let constrained_spec = FleetSpec {
        tenants: spec.tenants.clone(),
        inventory: Inventory::unbounded().with_count(cap_tier, Some(cap)),
    };
    let constrained = planner
        .plan(&constrained_spec)
        .map(|p| (cap_tier, cap, p.repairs, p.total_cost_per_hour))
        .map_err(|e| e.to_string());

    // Serve every tenant's (unbounded) planned configuration against its
    // live scenario family, each with its own arrival seed.
    let outcomes = parallel_map_indexed(n, default_workers(), |i| {
        let SynthTenant { tenant, family, .. } = &population[i];
        let live = family_scenario(family, quick)
            .expect("synth families are checked-in robustness families")
            .build(scenarios::child_seed(seed, LIVE_TAG + i as u64))
            .expect("checked-in scenario builds");
        let result = simulate(
            &tenant.spec,
            &profiles,
            &plan.tenants[i].plan.config,
            &live,
            &SimParams::default(),
        );
        TenantOutcome { miss_rate: result.miss_rate(tenant.slo) }
    });
    Ok(ScaleResult { n, plan, outcomes, constrained })
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0, 0usize);
    for x in xs {
        sum += x;
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Encode the sweep as the canonical machine-readable report. Key order
/// is canonical (`Json::Obj` is a `BTreeMap`) and every value is a
/// deterministic function of the seed, so the byte stream is too.
fn report_json(seed: u64, quick: bool, results: &[(usize, SweepOutcome)]) -> Json {
    let mut doc = Json::obj();
    doc.set("format", REPORT_FORMAT).set("seed", seed as usize).set("quick", quick);
    let rows: Vec<Json> = results
        .iter()
        .map(|(n, outcome)| {
            let mut o = Json::obj();
            o.set("tenants", *n);
            match outcome {
                Ok(r) => encode_scale(&mut o, r),
                Err(e) => {
                    o.set("error", e.as_str());
                }
            }
            o
        })
        .collect();
    doc.set("scales", Json::Arr(rows));
    doc
}

type SweepOutcome = Result<ScaleResult, String>;

fn encode_scale(o: &mut Json, r: &ScaleResult) {
    let p = &r.plan;
    let mean_miss = mean(r.outcomes.iter().map(|t| t.miss_rate));
    let worst_miss = r.outcomes.iter().map(|t| t.miss_rate).fold(f64::NAN, f64::max);
    o.set("unshared_cost_per_hour", p.unshared_cost_per_hour)
        .set("total_cost_per_hour", p.total_cost_per_hour)
        .set("savings_per_hour", p.savings_per_hour)
        .set(
            "savings_fraction",
            Json::num_or_null(p.savings_per_hour / p.unshared_cost_per_hour),
        )
        .set("shared_stages", p.shared.len())
        .set(
            "shared_replicas_saved",
            p.shared.iter().map(|g| g.saved_replicas()).sum::<usize>(),
        )
        .set("repairs", p.repairs)
        .set("mean_miss_rate", Json::num_or_null(mean_miss))
        .set("worst_miss_rate", Json::num_or_null(worst_miss));
    let mut usage = Json::obj();
    for hw in Hardware::ALL {
        usage.set(hw.id(), p.usage[hw.index()]);
    }
    o.set("usage", usage);
    let mut con = Json::obj();
    match &r.constrained {
        Ok((tier, cap, repairs, total)) => {
            con.set("capped_tier", tier.id())
                .set("cap", *cap)
                .set("repairs", *repairs)
                .set("total_cost_per_hour", *total);
        }
        Err(e) => {
            con.set("error", e.as_str());
        }
    }
    o.set("constrained", con);
    // Full per-tenant detail stays readable at small scales; the
    // aggregates above cover the 1000-tenant row.
    if r.n <= 100 {
        let detail: Vec<Json> = p
            .tenants
            .iter()
            .zip(&r.outcomes)
            .map(|(t, out)| {
                let mut row = Json::obj();
                row.set("tenant", t.tenant.as_str())
                    .set("cost_per_hour", t.plan.cost_per_hour)
                    .set("effective_cost_per_hour", t.effective_cost_per_hour)
                    .set("miss_rate", Json::num_or_null(out.miss_rate));
                row
            })
            .collect();
        o.set("tenants_detail", Json::Arr(detail));
    }
}

/// CLI entry point: sweep the tenant scales, print a table, write
/// `fleet.csv` and `fleet.json` into the results dir.
pub fn run(ctx: &Ctx, seed: u64) -> bool {
    crate::util::bench::figure_header(
        "Fleet",
        "joint provisioning of tenant populations over a shared inventory",
    );
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    super::common::warm_cache(ctx, &cache);
    let scales = if ctx.quick { &SCALES[..2] } else { &SCALES[..] };
    let results: Vec<(usize, SweepOutcome)> = scales
        .iter()
        .map(|&n| (n, run_scale(n, seed, ctx.quick, &cache)))
        .collect();
    super::common::persist_cache(ctx, &cache);
    let mut rows = Vec::new();
    for (n, outcome) in &results {
        match outcome {
            Ok(r) => {
                let p = &r.plan;
                let mean_miss = mean(r.outcomes.iter().map(|t| t.miss_rate));
                println!(
                    "  {:>5} tenants  ${:>9.2}/hr shared (${:>9.2} unshared, save ${:>7.2} = \
                     {:>4.1}%)  {} shared stages  mean miss {:>5.2}%",
                    n,
                    p.total_cost_per_hour,
                    p.unshared_cost_per_hour,
                    p.savings_per_hour,
                    100.0 * p.savings_per_hour / p.unshared_cost_per_hour,
                    p.shared.len(),
                    mean_miss * 100.0,
                );
                match &r.constrained {
                    Ok((tier, cap, repairs, total)) => println!(
                        "  {:>5}          constrained: {} capped at {cap} → {repairs} repairs, \
                         ${total:.2}/hr",
                        "",
                        tier.id(),
                    ),
                    Err(e) => println!("  {:>5}          constrained: {e}", ""),
                }
                rows.push(format!(
                    "{n},{},{},{},{}",
                    csv_num(p.unshared_cost_per_hour),
                    csv_num(p.total_cost_per_hour),
                    csv_num(p.savings_per_hour),
                    csv_num(mean_miss),
                ));
            }
            Err(e) => {
                println!("  {n:>5} tenants  {e}");
                rows.push(format!("{n},,,,"));
            }
        }
    }
    ctx.write_csv(
        "fleet.csv",
        "tenants,unshared_cost_per_hour,total_cost_per_hour,savings_per_hour,mean_miss_rate",
        &rows,
    );
    println!("  wrote {}", ctx.results_dir.join("fleet.csv").display());
    let doc = report_json(seed, ctx.quick, &results);
    let path = ctx.results_dir.join("fleet.json");
    match std::fs::write(&path, doc.to_string()) {
        Ok(()) => {
            println!("  wrote {}", path.display());
            results.iter().all(|(_, outcome)| outcome.is_ok())
        }
        Err(e) => {
            crate::log_warn!("could not write {}: {e}", path.display());
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_is_byte_identical_per_seed() {
        let cache_a = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
        let cache_b = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
        let a = report_json(5, true, &[(4, run_scale(4, 5, true, &cache_a))]);
        let b = report_json(5, true, &[(4, run_scale(4, 5, true, &cache_b))]);
        assert_eq!(a.to_string(), b.to_string());
        let c = report_json(6, true, &[(4, run_scale(4, 6, true, &cache_b))]);
        assert_ne!(a.to_string(), c.to_string(), "seed must reach the report");
    }

    #[test]
    fn scale_result_has_consistent_accounting() {
        let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
        let r = run_scale(6, 11, true, &cache).expect("small fleet plans");
        let p = &r.plan;
        assert_eq!(r.outcomes.len(), 6);
        assert!(p.savings_per_hour >= 0.0);
        let effective: f64 = p.tenants.iter().map(|t| t.effective_cost_per_hour).sum();
        assert!(
            (effective - p.total_cost_per_hour).abs() < 1e-6,
            "routing credit must conserve cost: {effective} vs {}",
            p.total_cost_per_hour
        );
        for t in &r.outcomes {
            assert!((0.0..=1.0).contains(&t.miss_rate));
        }
    }
}
