//! Estimator perf ledger: the checked-in `BENCH_estimator.json` baseline,
//! gated by `inferline bench check` the way `BUDGETS.json` gates SLO
//! drift (`experiments::budgets`).
//!
//! The perf-trajectory artifact gives successive PRs a comparable perf
//! trail, but a trail alone has no teeth: a change that halves Estimator
//! throughput ships silently unless something in CI knows what "fast"
//! looked like. This module is that memory. The repo root carries a
//! checked-in copy of the `bench estimator` report with one extra
//! `check` stanza:
//!
//! ```json
//! { "bench": "estimator", "quick": true, ...,
//!   "check": { "min_ratio": 0.5 } }
//! ```
//!
//! `inferline bench check` measures the current tree (or reads a
//! `--current` report), then requires every throughput/speedup metric to
//! hold `current >= baseline * min_ratio` — a ratio threshold, because
//! wall-clock numbers move with the host; `min_ratio` says how much of
//! the baselined performance any host must retain. It exits nonzero
//! naming each regressed metric. `inferline bench update` re-baselines
//! the file from a fresh run (preserving `min_ratio`); review the diff
//! like any other regression-test change.
//!
//! Compared metrics, all higher-is-better:
//!
//! * `sim_queries_per_sec` — raw Estimator throughput;
//! * `fast_accept.speedup` — budgeted feasibility vs full reference;
//! * `event_core.speedup` — slab queue vs old-style heap churn;
//! * `warm_start.speedup` — persisted-cache warm plan vs cold;
//! * `pipelines.<name>.plans_per_sec` — end-to-end `plan()` rate per
//!   pipeline.
//!
//! A `null`/missing metric is **no data** and fails the check — it must
//! never read as a pass. Pipelines the baseline knows but the current
//! run lacks (and vice versa) are violations too: the ledger and the
//! bench move together. `warm_start.bit_identical` must be `true` in the
//! current run — a fast-but-wrong warm start is not a perf win. Quick-
//! and full-mode numbers are not comparable, so `check` refuses a
//! current/baseline mode mismatch outright.

use std::path::Path;

use crate::util::json::Json;

/// Expected `bench` tag; reports with any other tag are rejected
/// wholesale (same policy as the SLO budget ledger).
pub const BENCH_TAG: &str = "estimator";

/// `min_ratio` used when `bench update` creates a baseline from scratch:
/// any host must retain at least half the baselined performance.
pub const DEFAULT_MIN_RATIO: f64 = 0.5;

/// The scalar (non-pipeline) metrics the ledger compares, as
/// (display name, JSON path) pairs.
const SCALAR_METRICS: &[(&str, &[&str])] = &[
    ("sim_queries_per_sec", &["sim_queries_per_sec"]),
    ("fast_accept.speedup", &["fast_accept", "speedup"]),
    ("event_core.speedup", &["event_core", "speedup"]),
    ("warm_start.speedup", &["warm_start", "speedup"]),
];

fn num_at(doc: &Json, path: &[&str]) -> Option<f64> {
    let mut node = doc;
    for key in path {
        node = node.get(key)?;
    }
    node.as_f64()
}

/// One ledger violation: which metric, and what went wrong.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub metric: String,
    pub what: String,
}

/// Outcome of a check: human-readable per-metric lines plus the
/// violations (empty = within the ledger).
#[derive(Debug, Clone)]
pub struct CheckReport {
    pub lines: Vec<String>,
    pub violations: Vec<Violation>,
}

/// Validate the `bench` tag; both sides of a comparison and every
/// baseline written by `update` must carry it.
fn require_tag(doc: &Json, what: &str) -> Result<(), String> {
    let tag = doc.get("bench").and_then(Json::as_str).unwrap_or("<missing>");
    if tag != BENCH_TAG {
        return Err(format!("{what}: bench tag {tag:?} (expected {BENCH_TAG:?})"));
    }
    Ok(())
}

fn quick_flag(doc: &Json, what: &str) -> Result<bool, String> {
    doc.get("quick")
        .and_then(Json::as_bool)
        .ok_or_else(|| format!("{what}: missing boolean field \"quick\""))
}

/// The baseline's ratio threshold (`check.min_ratio`), defaulting when
/// the stanza is absent. Rejects non-positive or >1 thresholds — a
/// ratio of 0 gates nothing and a ratio above 1 would fail a perfect
/// reproduction of the baseline.
pub fn min_ratio(baseline: &Json) -> Result<f64, String> {
    match num_at(baseline, &["check", "min_ratio"]) {
        None => Ok(DEFAULT_MIN_RATIO),
        Some(r) if r > 0.0 && r <= 1.0 => Ok(r),
        Some(r) => Err(format!("baseline check.min_ratio must be in (0, 1], got {r}")),
    }
}

/// Compare one higher-is-better metric; `None` = no data on that side.
fn compare(
    name: &str,
    base: Option<f64>,
    cur: Option<f64>,
    ratio: f64,
    lines: &mut Vec<String>,
    violations: &mut Vec<Violation>,
) {
    let (b, c) = match (base, cur) {
        (Some(b), Some(c)) => (b, c),
        (None, _) => {
            violations.push(Violation {
                metric: name.to_string(),
                what: "no data in baseline (run `inferline bench update`)".to_string(),
            });
            return;
        }
        (_, None) => {
            violations.push(Violation {
                metric: name.to_string(),
                what: "no data in current run".to_string(),
            });
            return;
        }
    };
    let floor = b * ratio;
    // NaN on either side must trip, so test for the pass and negate.
    let ok = c >= floor;
    if !ok {
        violations.push(Violation {
            metric: name.to_string(),
            what: format!("{c:.4} below {floor:.4} (baseline {b:.4} x min_ratio {ratio})"),
        });
    }
    lines.push(format!(
        "  {name:<34} {c:>12.4} vs baseline {b:>12.4}  (floor {floor:.4})  {}",
        if ok { "ok" } else { "FAIL" }
    ));
}

/// Compare a current `bench estimator` report against the checked-in
/// baseline. `Err` is reserved for unreadable inputs; a readable report
/// that regresses yields `Ok` with violations.
pub fn check(current: &Json, baseline: &Json) -> Result<CheckReport, String> {
    require_tag(current, "current report")?;
    require_tag(baseline, "baseline")?;
    let ratio = min_ratio(baseline)?;
    let mut lines = Vec::new();
    let mut violations = Vec::new();
    let cur_quick = quick_flag(current, "current report")?;
    let base_quick = quick_flag(baseline, "baseline")?;
    if cur_quick != base_quick {
        // Quick- and full-mode numbers are incomparable: refuse outright
        // instead of emitting per-metric "regressions" against a baseline
        // the run was never measured at.
        violations.push(Violation {
            metric: "<ledger>".to_string(),
            what: format!(
                "current quick={cur_quick} but baseline quick={base_quick}; \
                 re-run with the matching mode or re-baseline"
            ),
        });
        return Ok(CheckReport { lines, violations });
    }
    for &(name, path) in SCALAR_METRICS {
        let base = num_at(baseline, path);
        let cur = num_at(current, path);
        compare(name, base, cur, ratio, &mut lines, &mut violations);
    }
    let bit_identical = current
        .get("warm_start")
        .and_then(|w| w.get("bit_identical"))
        .and_then(Json::as_bool);
    match bit_identical {
        Some(true) => {}
        Some(false) => violations.push(Violation {
            metric: "warm_start.bit_identical".to_string(),
            what: "warm-started plan diverged from the cold plan".to_string(),
        }),
        None => violations.push(Violation {
            metric: "warm_start.bit_identical".to_string(),
            what: "no data in current run".to_string(),
        }),
    }
    let Some(base_map) = baseline.get("pipelines").and_then(Json::as_obj) else {
        return Err("baseline: \"pipelines\" missing or not an object".to_string());
    };
    let Some(cur_map) = current.get("pipelines").and_then(Json::as_obj) else {
        return Err("current report: \"pipelines\" missing or not an object".to_string());
    };
    for (name, entry) in base_map {
        let metric = format!("pipelines.{name}.plans_per_sec");
        if let Some(err) = entry.get("error").and_then(Json::as_str) {
            violations.push(Violation {
                metric,
                what: format!("baseline recorded an error ({err}); re-baseline"),
            });
            continue;
        }
        match cur_map.get(name) {
            None => violations.push(Violation {
                metric,
                what: "pipeline absent from current run".to_string(),
            }),
            Some(cur_entry) => {
                if let Some(err) = cur_entry.get("error").and_then(Json::as_str) {
                    violations.push(Violation {
                        metric,
                        what: format!("current run failed to plan ({err})"),
                    });
                    continue;
                }
                compare(
                    &metric,
                    entry.get("plans_per_sec").and_then(Json::as_f64),
                    cur_entry.get("plans_per_sec").and_then(Json::as_f64),
                    ratio,
                    &mut lines,
                    &mut violations,
                );
            }
        }
    }
    for name in cur_map.keys() {
        if !base_map.contains_key(name) {
            violations.push(Violation {
                metric: format!("pipelines.{name}"),
                what: "unbaselined pipeline (add it with `inferline bench update`)".to_string(),
            });
        }
    }
    Ok(CheckReport { lines, violations })
}

/// Build a new baseline document from a current run: the report itself
/// plus the `check` stanza, whose `min_ratio` is preserved from the old
/// baseline when one is given. Refuses reports with no-data metrics or
/// errored pipelines — a ledger must never be seeded from a broken run.
pub fn update(current: &Json, old_baseline: Option<&Json>) -> Result<Json, String> {
    require_tag(current, "current report")?;
    quick_flag(current, "current report")?;
    for &(name, path) in SCALAR_METRICS {
        if num_at(current, path).is_none() {
            return Err(format!("cannot baseline: metric {name} has no data"));
        }
    }
    let bit_identical = current
        .get("warm_start")
        .and_then(|w| w.get("bit_identical"))
        .and_then(Json::as_bool);
    if bit_identical != Some(true) {
        return Err("cannot baseline: warm_start.bit_identical is not true".to_string());
    }
    let pipelines = current
        .get("pipelines")
        .and_then(Json::as_obj)
        .ok_or("cannot baseline: missing object field \"pipelines\"")?;
    for (name, entry) in pipelines {
        if let Some(err) = entry.get("error").and_then(Json::as_str) {
            return Err(format!("cannot baseline: pipeline {name} errored ({err})"));
        }
        if entry.get("plans_per_sec").and_then(Json::as_f64).is_none() {
            return Err(format!("cannot baseline: pipeline {name} has no plans_per_sec"));
        }
    }
    let ratio = match old_baseline {
        Some(b) => min_ratio(b)?,
        None => DEFAULT_MIN_RATIO,
    };
    let mut doc = current.clone();
    let mut stanza = Json::obj();
    stanza.set("min_ratio", ratio);
    doc.set("check", stanza);
    Ok(doc)
}

// ---------------------------------------------------------------------------
// CLI entry points
// ---------------------------------------------------------------------------

fn load_doc(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Obtain the current report: read `--current` when given, else run the
/// benchmark in-process at the requested mode.
fn current_doc(
    current_path: Option<&Path>,
    baseline_path: &Path,
    quick: bool,
) -> Result<Json, String> {
    match current_path {
        Some(p) => load_doc(p),
        None => {
            let cache_file = baseline_path.with_file_name("BENCH_estimator_cache.json");
            Ok(super::estbench::collect(quick, &cache_file))
        }
    }
}

/// CLI `bench check`: true iff the current run holds the baseline's
/// ratio floor on every metric.
pub fn run_check(current_path: Option<&Path>, baseline_path: &Path, quick: bool) -> bool {
    crate::util::bench::figure_header(
        "Bench check",
        "current estimator bench vs the checked-in perf baseline",
    );
    let baseline = match load_doc(baseline_path) {
        Ok(b) => b,
        Err(e) => {
            crate::log_error!("{e} (baseline missing? create it with `inferline bench update`)");
            return false;
        }
    };
    let current = match current_doc(current_path, baseline_path, quick) {
        Ok(c) => c,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    let outcome = match check(&current, &baseline) {
        Ok(o) => o,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    for line in &outcome.lines {
        println!("{line}");
    }
    if outcome.violations.is_empty() {
        println!(
            "  bench check OK: {} metrics within ratio floor ({})",
            outcome.lines.len(),
            baseline_path.display()
        );
        true
    } else {
        for v in &outcome.violations {
            crate::log_error!("  BENCH REGRESSION [{}] {}", v.metric, v.what);
        }
        crate::log_error!(
            "  bench check FAILED: {} violation(s) against {}",
            outcome.violations.len(),
            baseline_path.display()
        );
        false
    }
}

/// CLI `bench update`: re-baseline the checked-in report from a current
/// run (preserving `check.min_ratio` when the file already exists).
pub fn run_update(current_path: Option<&Path>, baseline_path: &Path, quick: bool) -> bool {
    let old = if baseline_path.exists() {
        match load_doc(baseline_path) {
            Ok(b) => Some(b),
            Err(e) => {
                crate::log_error!("{e}");
                return false;
            }
        }
    } else {
        None
    };
    let current = match current_doc(current_path, baseline_path, quick) {
        Ok(c) => c,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    let doc = match update(&current, old.as_ref()) {
        Ok(d) => d,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    match std::fs::write(baseline_path, doc.to_pretty_string()) {
        Ok(()) => {
            println!("re-baselined estimator perf ledger into {}", baseline_path.display());
            true
        }
        Err(e) => {
            crate::log_error!("{}: {e}", baseline_path.display());
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal well-formed report with uniform speedups and two
    /// pipelines at `pps` plans/sec.
    fn report(qps: f64, speedup: f64, pps: f64) -> Json {
        let mut doc = Json::obj();
        doc.set("bench", BENCH_TAG)
            .set("quick", true)
            .set("sim_queries_per_sec", qps);
        for section in ["fast_accept", "event_core"] {
            let mut s = Json::obj();
            s.set("speedup", speedup);
            doc.set(section, s);
        }
        let mut ws = Json::obj();
        ws.set("speedup", speedup).set("bit_identical", true);
        doc.set("warm_start", ws);
        let mut pipelines = Json::obj();
        for name in ["image-processing", "social-media"] {
            let mut p = Json::obj();
            p.set("plans_per_sec", pps);
            pipelines.set(name, p);
        }
        doc.set("pipelines", pipelines);
        doc
    }

    fn baseline_for(r: &Json) -> Json {
        update(r, None).unwrap()
    }

    #[test]
    fn update_then_check_passes() {
        let r = report(2e5, 2.0, 0.5);
        let b = baseline_for(&r);
        assert_eq!(min_ratio(&b).unwrap(), DEFAULT_MIN_RATIO);
        let outcome = check(&r, &b).unwrap();
        assert!(outcome.violations.is_empty(), "{:?}", outcome.violations);
        // 4 scalar metrics + 2 pipelines.
        assert_eq!(outcome.lines.len(), 6);
        // Drift down to the floor (exactly half here) still passes;
        // drift below it fails.
        let half = report(1e5, 1.0, 0.25);
        assert!(check(&half, &b).unwrap().violations.is_empty());
        let worse = report(0.9e5, 0.9, 0.2);
        assert!(!check(&worse, &b).unwrap().violations.is_empty());
    }

    #[test]
    fn each_regressed_metric_is_named() {
        let base = report(2e5, 2.0, 0.5);
        let b = baseline_for(&base);
        // (bad report, expected metric substring)
        let cases = [
            (report(0.5e5, 2.0, 0.5), "sim_queries_per_sec"),
            (report(2e5, 0.5, 0.5), "speedup"),
            (report(2e5, 2.0, 0.1), "plans_per_sec"),
        ];
        for (bad, needle) in cases {
            let outcome = check(&bad, &b).unwrap();
            assert!(!outcome.violations.is_empty(), "{needle}: should have tripped");
            for v in &outcome.violations {
                assert!(v.metric.contains(needle), "{needle}: got {:?}", v.metric);
            }
        }
    }

    #[test]
    fn mode_mismatch_refuses_comparison() {
        let base = report(2e5, 2.0, 0.5);
        let b = baseline_for(&base);
        let mut full = report(2e5, 2.0, 0.5);
        full.set("quick", false);
        let outcome = check(&full, &b).unwrap();
        assert_eq!(outcome.violations.len(), 1);
        assert_eq!(outcome.violations[0].metric, "<ledger>");
        assert!(outcome.lines.is_empty(), "no per-metric noise on a refused comparison");
    }

    #[test]
    fn no_data_fails_instead_of_passing() {
        let base = report(2e5, 2.0, 0.5);
        let b = baseline_for(&base);
        // NaN serializes to null and parses back as no data; build the
        // gap directly: a current run missing a section entirely.
        let mut gap = report(2e5, 2.0, 0.5);
        if let Json::Obj(m) = &mut gap {
            m.remove("event_core");
        }
        let outcome = check(&gap, &b).unwrap();
        assert!(
            outcome
                .violations
                .iter()
                .any(|v| v.metric == "event_core.speedup" && v.what.contains("no data")),
            "{:?}",
            outcome.violations
        );
        // And update refuses to baseline such a run.
        assert!(update(&gap, None).is_err());
        // A diverged warm start is a violation even when fast.
        let mut diverged = report(2e5, 2.0, 0.5);
        if let Json::Obj(m) = &mut diverged {
            m.get_mut("warm_start").unwrap().set("bit_identical", false);
        }
        let outcome = check(&diverged, &b).unwrap();
        assert!(outcome
            .violations
            .iter()
            .any(|v| v.metric == "warm_start.bit_identical"));
        assert!(update(&diverged, None).is_err());
    }

    #[test]
    fn pipeline_set_must_match_the_ledger() {
        let base = report(2e5, 2.0, 0.5);
        let b = baseline_for(&base);
        // Baselined pipeline absent from the current run.
        let mut missing = report(2e5, 2.0, 0.5);
        if let Json::Obj(m) = &mut missing {
            if let Some(Json::Obj(p)) = m.get_mut("pipelines") {
                p.remove("social-media");
            }
        }
        let outcome = check(&missing, &b).unwrap();
        assert!(outcome
            .violations
            .iter()
            .any(|v| v.metric.contains("social-media") && v.what.contains("absent")));
        // Current pipeline the ledger has never seen.
        let mut extra = report(2e5, 2.0, 0.5);
        if let Some(p) = extra.get("pipelines") {
            let mut p = p.clone();
            let mut entry = Json::obj();
            entry.set("plans_per_sec", 1.0);
            p.set("tf-cascade", entry);
            extra.set("pipelines", p);
        }
        let outcome = check(&extra, &b).unwrap();
        assert!(outcome
            .violations
            .iter()
            .any(|v| v.metric.contains("tf-cascade") && v.what.contains("unbaselined")));
        // A current-run planning error is a violation, and update refuses
        // to baseline from it.
        let mut errored = report(2e5, 2.0, 0.5);
        if let Json::Obj(m) = &mut errored {
            if let Some(Json::Obj(p)) = m.get_mut("pipelines") {
                let mut entry = Json::obj();
                entry.set("error", "no feasible configuration");
                p.insert("social-media".to_string(), entry);
            }
        }
        let outcome = check(&errored, &b).unwrap();
        assert!(outcome
            .violations
            .iter()
            .any(|v| v.metric.contains("social-media") && v.what.contains("failed to plan")));
        assert!(update(&errored, None).is_err());
    }

    #[test]
    fn baseline_stanza_is_preserved_and_validated() {
        let r = report(2e5, 2.0, 0.5);
        let mut b = baseline_for(&r);
        // Tighten the ratio, then re-baseline: the stanza must survive.
        if let Some(stanza) = b.get("check") {
            let mut stanza = stanza.clone();
            stanza.set("min_ratio", 0.8);
            b.set("check", stanza);
        }
        let again = update(&r, Some(&b)).unwrap();
        assert_eq!(min_ratio(&again).unwrap(), 0.8);
        // The tightened floor actually bites: 0.7x of baseline fails.
        let drift = report(1.4e5, 1.4, 0.35);
        assert!(!check(&drift, &b).unwrap().violations.is_empty());
        // Out-of-range ratios are rejected, not silently used.
        let mut bad = b.clone();
        if let Some(stanza) = bad.get("check") {
            let mut stanza = stanza.clone();
            stanza.set("min_ratio", 1.5);
            bad.set("check", stanza);
        }
        assert!(check(&r, &bad).is_err());
        // Wrong bench tag is unreadable, not a pass.
        let mut alien = r.clone();
        alien.set("bench", "planner");
        assert!(check(&alien, &b).is_err());
        assert!(update(&alien, None).is_err());
    }
}
