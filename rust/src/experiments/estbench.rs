//! `inferline bench estimator` — the Estimator/Planner performance
//! microbench behind the perf-trajectory artifact.
//!
//! Measures (1) raw Estimator throughput (simulated queries per second on
//! a long trace), (2) end-to-end `plan()` latency per pipeline with the
//! fast path on and off, (3) the feasibility fast-accept against a full
//! reference simulation on a feasible (accept-heavy) workload, (4)
//! the persistent-cache warm-start: a second identical `plan()` that
//! loads the first run's cache file from disk, and (5) the event core in
//! isolation: the old-style heap churn driver vs the slab queue with
//! coalesced delivery on an identical synthetic workload. Two paired
//! sections price the engine's optional runtimes against the raw number:
//! a whole-run crash storm (the fault runtime) and a recording telemetry
//! probe (per-hop spans + time-series). Everything is
//! written as JSON (by default `BENCH_estimator.json`) so successive PRs
//! leave a comparable perf trail; the checked-in copy of that file is the
//! baseline `inferline bench check` compares against (see
//! `experiments::benchcheck`). CI runs it as a non-gating step with
//! `--quick`.

use std::path::Path;

use crate::config::pipelines;
use crate::planner::{EstimatorCache, Planner};
use crate::profiler::analytic::paper_profiles;
use crate::simulator::{self, SimParams};
use crate::util::bench::{bench, black_box};
use crate::util::json::Json;
use crate::workload::gamma_trace;
use crate::workload::stream::GammaSource;

/// Run the estimator benchmark and write the JSON report to `out`.
pub fn run(out: &Path, quick: bool) -> std::io::Result<()> {
    let cache_file = out.with_file_name("BENCH_estimator_cache.json");
    let doc = collect(quick, &cache_file);
    std::fs::write(out, format!("{doc}\n"))?;
    println!("  wrote {}", out.display());
    Ok(())
}

/// Run every benchmark section and return the report document.
/// `cache_file` is scratch space for the warm-start section (written,
/// re-read and removed). `bench check` calls this directly to measure
/// the current tree against the checked-in baseline.
pub fn collect(quick: bool, cache_file: &Path) -> Json {
    let profiles = paper_profiles();
    let params = SimParams::default();
    let samples = if quick { 3 } else { 5 };
    let mut doc = Json::obj();
    doc.set("bench", "estimator");
    doc.set("quick", quick);
    doc.set("threads", crate::util::par::default_workers());

    // --- Raw Estimator throughput on a long trace. -------------------------
    let sim_secs = if quick { 600.0 } else { 3600.0 };
    let spec = pipelines::social_media();
    let long_trace = gamma_trace(150.0, 1.0, sim_secs, 1);
    let warm_plan = Planner::new(&spec, &profiles)
        .plan(&gamma_trace(150.0, 1.0, 30.0, 2), 0.3)
        .expect("social-media plan");
    let r = bench("estimator: long trace @150qps social-media", 1, samples, || {
        black_box(
            simulator::simulate(&spec, &profiles, &warm_plan.config, &long_trace, &params)
                .latencies
                .len(),
        );
    });
    let sim_qps = long_trace.len() as f64 / r.mean_s;
    doc.set("sim_queries_per_sec", sim_qps);
    println!("  -> {:.2} M simulated queries/sec", sim_qps / 1e6);

    // --- Fault injection: no-fault vs crash-storm throughput. --------------
    // Same trace and configuration as the raw-throughput section, plus a
    // whole-run crash storm with retries and a shed policy — the cost of
    // the fault runtime (queue pruning, requeue, doomed-batch tracking)
    // on the engine's hottest loop. The no-fault number is the section
    // above; a fault-free run takes zero fault branches by construction
    // (bit-identity is asserted in the conformance suites, so this
    // section only has to price the *active* plan).
    let storm = crate::simulator::faults::FaultSpec {
        nodes: vec![crate::simulator::faults::FaultNode::CrashStorm {
            stage: None,
            start: 0.0,
            end: sim_secs,
            rate: 0.05,
        }],
        max_retries: 2,
        shed_after: Some(1.0),
    };
    let storm_plan = storm.compile(spec.n_stages(), 9);
    let storm_result = simulator::simulate_with_faults(
        &spec, &profiles, &warm_plan.config, &long_trace, &params, &storm_plan,
    );
    let rf = bench("estimator: long trace under crash storm", 1, samples, || {
        black_box(
            simulator::simulate_with_faults(
                &spec, &profiles, &warm_plan.config, &long_trace, &params, &storm_plan,
            )
            .latencies
            .len(),
        );
    });
    let storm_qps = long_trace.len() as f64 / rf.mean_s;
    let mut fl = Json::obj();
    fl.set("no_fault_queries_per_sec", sim_qps);
    fl.set("crash_storm_queries_per_sec", storm_qps);
    fl.set("overhead_ratio", r.mean_s / rf.mean_s);
    fl.set("crashes", storm_result.crashes as usize);
    fl.set("retries", storm_result.retries as usize);
    fl.set("shed", storm_result.shed as usize);
    doc.set("faults", fl);
    println!(
        "  -> crash-storm throughput {:.2} M queries/sec ({:.2}x of no-fault, {} crashes)",
        storm_qps / 1e6,
        r.mean_s / rf.mean_s,
        storm_result.crashes
    );

    // --- Telemetry probe: probe-off vs recording-probe throughput. ---------
    // Same trace and configuration once more. The probe-off number is the
    // raw section above — a probe-less engine takes zero probe branches
    // by construction (bit-identity is asserted in
    // tests/probe_conformance.rs) — so this section prices the *recording*
    // path: per-hop span tracking, reservoir sampling and cadenced stage
    // time-series on the engine's hottest loop.
    let rp = bench("estimator: long trace with recording probe", 1, samples, || {
        let mut probe = crate::simulator::probe::RecordingProbe::new(0.3);
        black_box(
            simulator::simulate_probed(
                &spec, &profiles, &warm_plan.config, &long_trace, &params, None, &mut probe,
            )
            .latencies
            .len(),
        );
    });
    let probe_qps = long_trace.len() as f64 / rp.mean_s;
    let mut po = Json::obj();
    po.set("off_queries_per_sec", sim_qps);
    po.set("recording_queries_per_sec", probe_qps);
    po.set("overhead_ratio", r.mean_s / rp.mean_s);
    doc.set("probe_overhead", po);
    println!(
        "  -> recording-probe throughput {:.2} M queries/sec ({:.2}x of probe-off)",
        probe_qps / 1e6,
        r.mean_s / rp.mean_s
    );

    // --- Feasibility fast-accept on a feasible-heavy workload. -------------
    // The planned configuration meets a loose SLO on the long trace, so
    // the budgeted check early-accepts (skipping the trace tail, the
    // backlog drain and the final P99 selection) while the reference path
    // simulates everything and selects the exact P99.
    let accept_slo = 0.5;
    let check = simulator::check_feasible(
        &spec,
        &profiles,
        &warm_plan.config,
        &long_trace,
        accept_slo,
        &params,
        None,
    );
    let fa = bench("feasibility: fast-accept check", 1, samples, || {
        black_box(
            simulator::check_feasible(
                &spec,
                &profiles,
                &warm_plan.config,
                &long_trace,
                accept_slo,
                &params,
                None,
            )
            .feasible,
        );
    });
    let full = bench("feasibility: full reference sim", 1, samples, || {
        black_box(simulator::feasible_unbudgeted(
            &spec,
            &profiles,
            &warm_plan.config,
            &long_trace,
            accept_slo,
            &params,
        ));
    });
    let mut accept = Json::obj();
    accept.set("slo", accept_slo);
    accept.set("accepted", check.accepted);
    accept.set("check_mean_s", fa.mean_s);
    accept.set("reference_mean_s", full.mean_s);
    accept.set("speedup", full.mean_s / fa.mean_s);
    doc.set("fast_accept", accept);
    println!(
        "  -> fast-accept on feasible workload: {:.2}x (accepted: {})",
        full.mean_s / fa.mean_s,
        check.accepted
    );

    // --- plan() end-to-end per pipeline, fast path on vs off. --------------
    // A fresh planner per run keeps the memo-cache cold, so each sample
    // measures one complete Algorithm 1 + 2 search.
    let plan_secs = if quick { 30.0 } else { 60.0 };
    let slo = 0.3;
    let mut per_pipeline = Json::obj();
    let mut heaviest: (String, f64) = (String::new(), 0.0);
    for spec in pipelines::all() {
        let sample = gamma_trace(150.0, 1.0, plan_secs, 3);
        // Surface infeasibility instead of timing an instant Err: a
        // silently-failing plan would report garbage plans/sec into the
        // perf trail this artifact exists to keep honest.
        if let Err(e) = Planner::new(&spec, &profiles).plan(&sample, slo) {
            println!("  -> {}: plan() failed ({e}); excluded from bench", spec.name);
            let mut entry = Json::obj();
            entry.set("error", e.to_string());
            per_pipeline.set(&spec.name, entry);
            continue;
        }
        let fast = bench(&format!("planner: plan() fast path, {}", spec.name), 1, samples, || {
            black_box(
                Planner::new(&spec, &profiles).plan(&sample, slo).expect("plan").cost_per_hour,
            );
        });
        let reference =
            bench(&format!("planner: plan() reference, {}", spec.name), 1, samples, || {
                black_box(
                    Planner::new(&spec, &profiles)
                        .with_fast_path(false)
                        .plan(&sample, slo)
                        .expect("plan")
                        .cost_per_hour,
                );
            });
        let mut entry = Json::obj();
        entry.set("plan_mean_s", fast.mean_s);
        entry.set("plans_per_sec", 1.0 / fast.mean_s);
        entry.set("reference_mean_s", reference.mean_s);
        entry.set("fast_path_speedup", reference.mean_s / fast.mean_s);
        println!(
            "  -> {}: {:.2} plans/sec, fast-path speedup {:.2}x",
            spec.name,
            1.0 / fast.mean_s,
            reference.mean_s / fast.mean_s
        );
        if fast.mean_s > heaviest.1 {
            heaviest = (spec.name.clone(), fast.mean_s);
        }
        per_pipeline.set(&spec.name, entry);
    }
    doc.set("pipelines", per_pipeline);
    let mut h = Json::obj();
    h.set("pipeline", heaviest.0.as_str());
    h.set("plan_mean_s", heaviest.1);
    h.set("plans_per_sec", 1.0 / heaviest.1);
    doc.set("heaviest", h);

    // --- Warm-start: persistent cache across two plan() invocations. -------
    // A cold search populates a cache that is saved to disk; each warm
    // sample then loads that file into a *fresh* cache (measuring the real
    // cross-process path, file parse included) and re-plans the identical
    // problem. Plans are bit-identical; only the time differs.
    let warm_spec = pipelines::social_media();
    let warm_sample = gamma_trace(150.0, 1.0, plan_secs, 3);
    let cold_cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    let cold = bench("planner: plan() cold cache", 0, samples, || {
        let c = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
        black_box(
            Planner::new(&warm_spec, &profiles)
                .with_shared_cache(c)
                .plan(&warm_sample, slo)
                .expect("plan")
                .cost_per_hour,
        );
    });
    let cold_plan = Planner::new(&warm_spec, &profiles)
        .with_shared_cache(cold_cache.clone())
        .plan(&warm_sample, slo)
        .expect("plan");
    let saved = cold_cache.save(&cache_file).expect("save estimator cache");
    let mut warm_hit_rate = 0.0;
    let mut warm_identical = true;
    let warm = bench("planner: plan() warm-started cache", 0, samples, || {
        let c = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
        c.load_from(&cache_file).expect("load estimator cache");
        let plan = Planner::new(&warm_spec, &profiles)
            .with_shared_cache(c)
            .plan(&warm_sample, slo)
            .expect("plan");
        warm_hit_rate = plan.telemetry.hit_rate();
        warm_identical &= plan.config == cold_plan.config;
        black_box(plan.cost_per_hour);
    });
    let _ = std::fs::remove_file(&cache_file);
    let mut ws = Json::obj();
    ws.set("entries", saved);
    ws.set("hit_rate", warm_hit_rate);
    ws.set("cold_mean_s", cold.mean_s);
    ws.set("warm_mean_s", warm.mean_s);
    ws.set("speedup", cold.mean_s / warm.mean_s);
    ws.set("bit_identical", warm_identical);
    doc.set("warm_start", ws);
    println!(
        "  -> warm-start: {:.2}x over cold ({} persisted entries, {:.0}% hit rate)",
        cold.mean_s / warm.mean_s,
        saved,
        warm_hit_rate * 100.0
    );

    // --- Event core in isolation: heap churn, old queue vs slab queue. -----
    // Both drivers process the same synthetic batch/fan-out workload and
    // fold every hop into a checksum (equal checksums => identical work in
    // identical order, asserted in event_core's unit tests), so the ratio
    // is the isolated event-core win, free of planner logic.
    let hops = if quick { 200_000 } else { 1_000_000 };
    let reference = bench("event core: churn, reference heap (Vec payloads)", 1, samples, || {
        black_box(simulator::event_core::churn_reference(hops));
    });
    let core = bench("event core: churn, slab queue + coalesced delivery", 1, samples, || {
        black_box(simulator::event_core::churn_event_core(hops));
    });
    let mut ec = Json::obj();
    ec.set("hops", hops);
    ec.set("reference_mean_s", reference.mean_s);
    ec.set("core_mean_s", core.mean_s);
    ec.set("speedup", reference.mean_s / core.mean_s);
    doc.set("event_core", ec);
    println!(
        "  -> event-core churn speedup {:.2}x over the reference heap",
        reference.mean_s / core.mean_s
    );

    // --- Streamed open loop vs materialized run. ---------------------------
    // The same workload as the raw-throughput section, pulled through the
    // chunked `ArrivalSource` path instead of a materialized trace
    // (`GammaSource` with the long trace's parameters generates the
    // identical arrival stream). Aggregates are bit-identical — asserted
    // in tests/streaming_conformance.rs — so this section prices the
    // streamed engine (lazy routing sampler, pull-refill, prefix
    // compaction) and records the memory win: peak resident query states
    // as a fraction of the horizon's total.
    let rs = bench("estimator: long trace, streamed open loop", 1, samples, || {
        let mut source = GammaSource::new(150.0, 1.0, sim_secs, 1);
        black_box(
            simulator::simulate_streamed(
                &spec, &profiles, &warm_plan.config, &mut source, &params, 0.3, 4096,
            )
            .completed,
        );
    });
    let mut source = GammaSource::new(150.0, 1.0, sim_secs, 1);
    let streamed_summary = simulator::simulate_streamed(
        &spec, &profiles, &warm_plan.config, &mut source, &params, 0.3, 4096,
    );
    let streamed_qps = long_trace.len() as f64 / rs.mean_s;
    let resident = streamed_summary.peak_queries_resident as f64 / long_trace.len() as f64;
    let mut st = Json::obj();
    st.set("materialized_queries_per_sec", sim_qps);
    st.set("streamed_queries_per_sec", streamed_qps);
    st.set("overhead_ratio", r.mean_s / rs.mean_s);
    st.set("peak_queries_resident", streamed_summary.peak_queries_resident);
    st.set("resident_fraction", resident);
    doc.set("streaming", st);
    println!(
        "  -> streamed throughput {:.2} M queries/sec ({:.2}x of materialized, \
         {:.2}% of queries resident at peak)",
        streamed_qps / 1e6,
        r.mean_s / rs.mean_s,
        resident * 100.0
    );

    // --- Fleet planning: tenant-population scaling. ------------------------
    // Joint fleet plans/sec at two population sizes, each sample on a
    // fresh estimator cache. The population collapses to a few dozen
    // distinct planning problems through the fleet memo, so the pair
    // prices the memoization + packing + dedup layers — near-flat
    // scaling is the expected shape. (The perf ledger compares a fixed
    // metric list, so this section rides along informationally.)
    let fleet_secs = if quick { 20.0 } else { 40.0 };
    let mut fleet = Json::obj();
    for n in [10usize, 100] {
        let population = crate::fleet::synth_tenants(n, 5, fleet_secs);
        let fleet_spec = crate::fleet::FleetSpec {
            tenants: population.into_iter().map(|t| t.tenant).collect(),
            inventory: crate::hardware::Inventory::unbounded(),
        };
        let rb = bench(&format!("fleet: plan() {n} tenants"), 0, samples, || {
            let planner = crate::fleet::FleetPlanner::new(&profiles)
                .with_shared_cache(EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY));
            black_box(planner.plan(&fleet_spec).expect("fleet plan").total_cost_per_hour);
        });
        let mut entry = Json::obj();
        entry.set("plan_mean_s", rb.mean_s);
        entry.set("plans_per_sec", 1.0 / rb.mean_s);
        fleet.set(&format!("tenants_{n}"), entry);
        println!("  -> fleet {n} tenants: {:.2} plans/sec", 1.0 / rb.mean_s);
    }
    doc.set("fleet", fleet);

    doc
}
