//! Shared plumbing for the figure-regeneration experiments: row/CSV
//! emission, standard system setups (InferLine plan+tune, CG plan+tune),
//! and controlled-run summaries.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::baselines::autoscale::AutoScaleTuner;
use crate::baselines::coarse::{self, CoarseTarget};
use crate::config::{PipelineConfig, PipelineSpec};
use crate::planner::{EstimatorCache, Plan, PlanError, Planner};
use crate::profiler::ProfileSet;
use crate::simulator::control::{simulate_controlled, simulate_controlled_with_faults, Controller};
use crate::simulator::faults::FaultPlan;
use crate::simulator::{self, SimParams, SimResult};
use crate::tuner::{Tuner, TunerInputs};
use crate::util::stats;
use crate::workload::Trace;

/// Experiment context: quick mode shrinks traces so `cargo bench` and CI
/// complete in seconds; full mode regenerates paper-scale data.
#[derive(Debug, Clone)]
pub struct Ctx {
    pub quick: bool,
    pub results_dir: PathBuf,
    /// Estimator-cache persistence path (`None` = in-memory only).
    /// Experiments with a path warm-start from it and write it back, so
    /// repeated invocations on the same traces skip re-simulation.
    pub cache_path: Option<PathBuf>,
}

impl Ctx {
    pub fn new(quick: bool) -> Self {
        let results_dir = PathBuf::from(
            std::env::var("INFERLINE_RESULTS_DIR").unwrap_or_else(|_| "results".into()),
        );
        let _ = std::fs::create_dir_all(&results_dir);
        Ctx { quick, results_dir, cache_path: None }
    }

    /// Enable estimator-cache persistence at `path`.
    pub fn with_cache(mut self, path: Option<PathBuf>) -> Self {
        self.cache_path = path;
        self
    }

    /// Scale a duration for quick mode.
    pub fn secs(&self, full: f64) -> f64 {
        if self.quick {
            (full / 6.0).max(20.0)
        } else {
            full
        }
    }

    /// Write a CSV of rows into the results dir.
    pub fn write_csv(&self, name: &str, header: &str, rows: &[String]) {
        let path = self.results_dir.join(name);
        let mut text = String::from(header);
        text.push('\n');
        for r in rows {
            text.push_str(r);
            text.push('\n');
        }
        if let Err(e) = std::fs::write(&path, text) {
            crate::log_warn!("warning: could not write {path:?}: {e}");
        }
    }
}

/// One CSV cell for a possibly-undefined metric: NaN/∞ (empty windows,
/// ratios with a zero denominator) become the empty field — "no data" —
/// so downstream tooling never parses a fabricated number.
pub fn csv_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        String::new()
    }
}

/// Summary of one serving run under a (planner, tuner) combination.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub system: String,
    /// $/hr averaged over the run (cost integral / duration).
    pub mean_cost_per_hour: f64,
    /// Total dollars for the run.
    pub total_cost: f64,
    pub p99: f64,
    pub miss_rate: f64,
    pub attainment: f64,
    pub result: SimResult,
}

impl RunSummary {
    pub fn from_result(system: &str, result: SimResult, slo: f64) -> Self {
        let hours = (result.horizon / 3600.0).max(1e-12);
        RunSummary {
            system: system.to_string(),
            mean_cost_per_hour: result.cost_dollars / hours,
            total_cost: result.cost_dollars,
            p99: stats::p99(&result.latencies),
            miss_rate: result.miss_rate(slo),
            attainment: 1.0 - result.miss_rate(slo),
            result,
        }
    }
}

/// Planner thread budget for one of `n_scenarios` figure scenarios
/// sharded across the machine: the cores the outer fan-out cannot fill.
/// 1 (serial planner) once the scenario count covers the core count.
pub fn shard_planner_threads(n_scenarios: usize) -> usize {
    (crate::util::par::default_workers() / n_scenarios.max(1)).max(1)
}

/// Warm `cache` from a persisted cache file. A missing file is a normal
/// cold start; a *rejected* file (corrupt, version-mismatched) is logged
/// and ignored — planner decisions are bit-identical warm or cold, so a
/// bad cache file must never abort or skew the run. Returns the number
/// of entries loaded. Single source of the warm-start log line the CI
/// warm-start check greps for.
pub fn warm_cache_from(path: &Path, cache: &Arc<EstimatorCache>) -> usize {
    if !path.exists() {
        return 0;
    }
    match cache.load_from(path) {
        Ok(n) => {
            println!("  estimator cache: warm-started with {n} entries from {}", path.display());
            n
        }
        Err(e) => {
            crate::log_warn!("  estimator cache: {e}; starting cold");
            0
        }
    }
}

/// Persist `cache` to a file (logged, best effort — a write failure must
/// not fail the run that produced the results).
pub fn persist_cache_to(path: &Path, cache: &Arc<EstimatorCache>) {
    match cache.save(path) {
        Ok(n) => println!("  estimator cache: saved {n} entries to {}", path.display()),
        Err(e) => crate::log_warn!("  estimator cache: {e}"),
    }
}

/// [`warm_cache_from`] the context's cache file, if any.
pub fn warm_cache(ctx: &Ctx, cache: &Arc<EstimatorCache>) -> usize {
    ctx.cache_path.as_deref().map_or(0, |path| warm_cache_from(path, cache))
}

/// [`persist_cache_to`] the context's cache file, if any.
pub fn persist_cache(ctx: &Ctx, cache: &Arc<EstimatorCache>) {
    if let Some(path) = ctx.cache_path.as_deref() {
        persist_cache_to(path, cache);
    }
}

/// Plan with InferLine and serve `live` with the InferLine Tuner in loop.
/// `planner_threads` is the candidate-evaluation fan-out — callers running
/// scenarios in parallel pass [`shard_planner_threads`] to avoid
/// oversubscribing the machine.
pub fn run_inferline(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    sample: &Trace,
    live: &Trace,
    slo: f64,
    planner_threads: usize,
) -> Result<(Plan, RunSummary), PlanError> {
    let planner = Planner::new(spec, profiles).with_threads(planner_threads);
    let plan = planner.plan(sample, slo)?;
    let st = simulator::service_time(spec, profiles, &plan.config);
    let inputs = TunerInputs::from_plan(spec, profiles, &plan.config, sample, st);
    let mut tuner = Tuner::new(inputs);
    let result = simulate_controlled(
        spec, profiles, &plan.config, live, &SimParams::default(), &mut tuner,
    );
    Ok((plan, RunSummary::from_result("InferLine", result, slo)))
}

/// Plan with InferLine and serve statically (no tuner). See
/// [`run_inferline`] for `planner_threads`.
pub fn run_inferline_static(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    sample: &Trace,
    live: &Trace,
    slo: f64,
    label: &str,
    planner_threads: usize,
) -> Result<(Plan, RunSummary), PlanError> {
    let planner = Planner::new(spec, profiles).with_threads(planner_threads);
    let plan = planner.plan(sample, slo)?;
    let mut null = crate::simulator::control::NullController;
    let result = simulate_controlled(
        spec, profiles, &plan.config, live, &SimParams::default(), &mut null,
    );
    Ok((plan, RunSummary::from_result(label, result, slo)))
}

/// Coarse-grained plan (Mean or Peak) served with the AutoScale tuner.
pub fn run_coarse(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    sample: &Trace,
    live: &Trace,
    slo: f64,
    target: CoarseTarget,
    tune: bool,
) -> RunSummary {
    run_coarse_with_faults(spec, profiles, sample, live, slo, target, tune, None)
}

/// [`run_coarse`] with an optional fault plan injected into the serving
/// run, so the chaos families compare baselines against InferLine under
/// the *same* failure schedule.
#[allow(clippy::too_many_arguments)]
pub fn run_coarse_with_faults(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    sample: &Trace,
    live: &Trace,
    slo: f64,
    target: CoarseTarget,
    tune: bool,
    faults: Option<&FaultPlan>,
) -> RunSummary {
    let cg = coarse::plan(spec, profiles, sample, slo, target);
    let label = match (target, tune) {
        (CoarseTarget::Mean, true) => "CG-Mean+AutoScale",
        (CoarseTarget::Peak, true) => "CG-Peak+AutoScale",
        (CoarseTarget::Mean, false) => "CG-Mean",
        (CoarseTarget::Peak, false) => "CG-Peak",
    };
    let params = SimParams::default();
    let result = if tune {
        let mut tuner = AutoScaleTuner::new(cg.unit_throughput, cg.units);
        match faults {
            Some(plan) => simulate_controlled_with_faults(
                spec, profiles, &cg.config, live, &params, &mut tuner, plan,
            ),
            None => simulate_controlled(spec, profiles, &cg.config, live, &params, &mut tuner),
        }
    } else {
        let mut null = crate::simulator::control::NullController;
        match faults {
            Some(plan) => simulate_controlled_with_faults(
                spec, profiles, &cg.config, live, &params, &mut null, plan,
            ),
            None => simulate_controlled(spec, profiles, &cg.config, live, &params, &mut null),
        }
    };
    RunSummary::from_result(label, result, slo)
}

/// Serve a static config with an arbitrary controller (helper for
/// attribution studies).
pub fn run_with_controller(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    live: &Trace,
    slo: f64,
    label: &str,
    controller: &mut dyn Controller,
) -> RunSummary {
    let result =
        simulate_controlled(spec, profiles, config, live, &SimParams::default(), controller);
    RunSummary::from_result(label, result, slo)
}

/// Pretty-print one summary row.
pub fn print_summary(prefix: &str, s: &RunSummary) {
    println!(
        "{prefix}{:<22} cost ${:>7.2}/hr  total ${:>7.2}  p99 {:>7.1}ms  miss {:>6.2}%  attain {:>6.2}%",
        s.system,
        s.mean_cost_per_hour,
        s.total_cost,
        s.p99 * 1e3,
        s.miss_rate * 100.0,
        s.attainment * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::profiler::analytic::paper_profiles;
    use crate::workload::gamma_trace;

    #[test]
    fn inferline_run_summary_is_consistent() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(80.0, 1.0, 30.0, 1);
        let live = gamma_trace(80.0, 1.0, 60.0, 2);
        let (plan, s) =
            run_inferline(&spec, &profiles, &sample, &live, 0.3, shard_planner_threads(1))
                .unwrap();
        assert!(s.miss_rate < 0.05, "miss {}", s.miss_rate);
        assert!((s.attainment + s.miss_rate - 1.0).abs() < 1e-9);
        assert!(s.total_cost > 0.0);
        // Mean cost should be near the planned cost (little tuning).
        assert!(
            (s.mean_cost_per_hour - plan.cost_per_hour).abs() / plan.cost_per_hour < 0.6,
            "mean {} vs plan {}",
            s.mean_cost_per_hour,
            plan.cost_per_hour
        );
    }

    #[test]
    fn coarse_run_produces_summary() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(80.0, 1.0, 30.0, 3);
        let live = gamma_trace(80.0, 1.0, 60.0, 4);
        let s = run_coarse(&spec, &profiles, &sample, &live, 0.3, CoarseTarget::Peak, true);
        assert!(s.p99 > 0.0);
        assert_eq!(s.system, "CG-Peak+AutoScale");
    }

    #[test]
    fn csv_num_is_nan_safe() {
        assert_eq!(csv_num(1.5), "1.5");
        assert_eq!(csv_num(0.0), "0");
        assert_eq!(csv_num(f64::NAN), "");
        assert_eq!(csv_num(f64::INFINITY), "");
    }

    #[test]
    fn ctx_quick_shrinks_durations() {
        let ctx = Ctx::new(true);
        assert!(ctx.secs(600.0) < 600.0);
        let full = Ctx::new(false);
        assert_eq!(full.secs(600.0), 600.0);
    }
}
