//! Parallel scenario sweep: plan a (λ, CV, SLO) grid across all four
//! paper pipeline topologies at once.
//!
//! Each grid point is an independent planning problem, so the sweep fans
//! scenarios out over a scoped thread pool (one scenario per task,
//! work-stolen off an atomic counter). Planner parallelism is adaptive:
//! when the grid has at least as many points as cores, each planner runs
//! serially (the outer fan-out already saturates the machine); when the
//! grid is smaller, the leftover cores are handed to each grid point's
//! planner as candidate-level parallelism instead of idling. Results are
//! deterministic either way — the parallel planner is bit-identical to
//! the serial one, and every scenario derives its trace seed from its
//! (pipeline, λ, CV) group.
//!
//! Grid points that differ only in SLO share a trace (same group seed)
//! and therefore a trace fingerprint, so the sweep hands every planner
//! one shared [`EstimatorCache`]: a full simulation at one SLO answers
//! feasibility queries at every other SLO of the group, and the cache's
//! segmented-LRU bound keeps very long sweeps from growing without limit.
//! The CLI sweep also persists that cache across processes (disable with
//! `--no-cache`): the grid warm-starts from `results/estimator_cache.json`
//! and writes it back, so a repeated invocation on the same traces
//! answers most feasibility queries without simulating — results are
//! bit-identical warm or cold.
//!
//! Determinism caveat: plans, costs, P99s and iteration counts are
//! bit-identical run to run. The `cache_hit_rate` column is *not* — it
//! depends on which sibling scenario populated the shared cache first,
//! i.e. on thread scheduling. Treat it as utilization telemetry, not a
//! comparable metric.
//!
//! Output: one row per scenario (cost, estimated P99, search iterations,
//! feasibility-cache hit rate) on stdout and in `results/sweep.csv`.

use std::sync::Arc;

use crate::config::pipelines;
use crate::planner::{EstimatorCache, Planner};
use crate::profiler::analytic::paper_profiles;
use crate::util::par::{default_workers, parallel_map_indexed};
use crate::workload::gamma_trace;

use super::common::{shard_planner_threads, Ctx};

/// One planned grid point.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub pipeline: String,
    pub lambda: f64,
    pub cv: f64,
    pub slo: f64,
    /// Planned cost and telemetry, or the infeasibility reason.
    pub outcome: Result<ScenarioPlan, String>,
}

/// The sweep's per-scenario plan summary.
#[derive(Debug, Clone)]
pub struct ScenarioPlan {
    pub cost_per_hour: f64,
    pub estimated_p99: f64,
    pub total_replicas: usize,
    pub iterations: usize,
    pub cache_hit_rate: f64,
}

/// Plan every (pipeline, λ, CV, SLO) combination in parallel and return
/// the results in grid order (deterministic regardless of thread count).
pub fn sweep_grid(
    lambdas: &[f64],
    cvs: &[f64],
    slos: &[f64],
    trace_secs: f64,
) -> Vec<ScenarioResult> {
    // One estimator cache for the whole sweep; scenarios that share a
    // trace fingerprint reuse each other's simulations across SLOs.
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    sweep_grid_with_cache(lambdas, cvs, slos, trace_secs, cache)
}

/// [`sweep_grid`] against a caller-supplied [`EstimatorCache`] — e.g. one
/// warm-started from a persisted cache file, or shared by several sweep
/// shards. Results are bit-identical to a cold cache: cached knowledge
/// answers feasibility queries exactly as a fresh computation would.
pub fn sweep_grid_with_cache(
    lambdas: &[f64],
    cvs: &[f64],
    slos: &[f64],
    trace_secs: f64,
    cache: Arc<EstimatorCache>,
) -> Vec<ScenarioResult> {
    let specs = pipelines::all();
    let profiles = paper_profiles();
    // Flatten the grid; index order is the output order.
    let mut scenarios = Vec::new();
    for spec in &specs {
        for &lambda in lambdas {
            for &cv in cvs {
                for &slo in slos {
                    scenarios.push((spec.clone(), lambda, cv, slo));
                }
            }
        }
    }
    let n_tasks = scenarios.len();
    let workers = default_workers();
    // Adaptive inner parallelism: cores the grid fan-out can't fill go to
    // each grid point's candidate search (bit-identical plans either way).
    let inner_threads = shard_planner_threads(n_tasks);
    let run_one = |idx: usize| -> ScenarioResult {
        let (spec, lambda, cv, slo) = &scenarios[idx];
        // Deterministic per-group seed (SLO is the innermost grid axis, so
        // `idx / slos.len()` indexes the (pipeline, λ, CV) group): results
        // do not depend on how scenarios land on threads, and SLO-only
        // variations share the trace — and thus the estimator cache.
        let group = idx / slos.len().max(1);
        let trace = gamma_trace(*lambda, *cv, trace_secs, 9000 + group as u64);
        let outcome = match Planner::new(spec, &profiles)
            .with_threads(inner_threads)
            .with_shared_cache(Arc::clone(&cache))
            .plan(&trace, *slo)
        {
            Ok(plan) => Ok(ScenarioPlan {
                cost_per_hour: plan.cost_per_hour,
                estimated_p99: plan.estimated_p99,
                total_replicas: plan.config.total_replicas(),
                iterations: plan.iterations,
                cache_hit_rate: plan.telemetry.hit_rate(),
            }),
            Err(e) => Err(e.to_string()),
        };
        ScenarioResult {
            pipeline: spec.name.clone(),
            lambda: *lambda,
            cv: *cv,
            slo: *slo,
            outcome,
        }
    };
    parallel_map_indexed(n_tasks, workers, run_one)
}

/// The CLI / bench entry point: sweep a standard grid, print a table,
/// write `sweep.csv`.
pub fn run_sweep(ctx: &Ctx) {
    crate::util::bench::figure_header(
        "Sweep",
        "planner across the (λ, CV, SLO) grid, all four pipelines",
    );
    let lambdas: &[f64] = if ctx.quick { &[50.0, 150.0] } else { &[50.0, 100.0, 200.0, 300.0] };
    let cvs: &[f64] = &[1.0, 4.0];
    let slos: &[f64] = if ctx.quick { &[0.15, 0.35] } else { &[0.1, 0.15, 0.25, 0.35, 0.5] };
    // Persistent estimator cache: a second identical invocation answers
    // most feasibility queries from the warm-started cache (results are
    // bit-identical either way).
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    super::common::warm_cache(ctx, &cache);
    let results = sweep_grid_with_cache(lambdas, cvs, slos, ctx.secs(45.0), Arc::clone(&cache));
    super::common::persist_cache(ctx, &cache);
    let mut rows = Vec::new();
    let mut feasible = 0usize;
    for r in &results {
        match &r.outcome {
            Ok(p) => {
                feasible += 1;
                println!(
                    "  {:<18} λ={:>3} cv={} slo={:<4}: ${:>6.2}/hr  {:>3} replicas  p99 {:>6.1}ms  \
                     {:>2} iters  cache {:>4.0}%",
                    r.pipeline,
                    r.lambda,
                    r.cv,
                    r.slo,
                    p.cost_per_hour,
                    p.total_replicas,
                    p.estimated_p99 * 1e3,
                    p.iterations,
                    p.cache_hit_rate * 100.0
                );
                rows.push(format!(
                    "{},{},{},{},{:.3},{},{:.4},{},{:.4}",
                    r.pipeline,
                    r.lambda,
                    r.cv,
                    r.slo,
                    p.cost_per_hour,
                    p.total_replicas,
                    p.estimated_p99,
                    p.iterations,
                    p.cache_hit_rate
                ));
            }
            Err(e) => {
                println!(
                    "  {:<18} λ={:>3} cv={} slo={:<4}: {e}",
                    r.pipeline, r.lambda, r.cv, r.slo
                );
                rows.push(format!(
                    "{},{},{},{},,,,,",
                    r.pipeline, r.lambda, r.cv, r.slo
                ));
            }
        }
    }
    println!("  {} / {} scenarios feasible", feasible, results.len());
    ctx.write_csv(
        "sweep.csv",
        "pipeline,lambda,cv,slo,cost_per_hour,total_replicas,est_p99,iterations,cache_hit_rate",
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_grid_in_order_and_is_deterministic() {
        let lambdas = [60.0, 120.0];
        let cvs = [1.0];
        let slos = [0.3];
        let a = sweep_grid(&lambdas, &cvs, &slos, 20.0);
        let b = sweep_grid(&lambdas, &cvs, &slos, 20.0);
        assert_eq!(a.len(), 4 * lambdas.len() * cvs.len() * slos.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pipeline, y.pipeline);
            assert_eq!(x.lambda, y.lambda);
            match (&x.outcome, &y.outcome) {
                (Ok(p), Ok(q)) => {
                    assert_eq!(p.cost_per_hour.to_bits(), q.cost_per_hour.to_bits());
                    assert_eq!(p.iterations, q.iterations);
                }
                (Err(e), Err(f)) => assert_eq!(e, f),
                _ => panic!("outcome mismatch for {}", x.pipeline),
            }
        }
        // Grid order: all scenarios of the first pipeline come first.
        assert_eq!(a[0].pipeline, a[1].pipeline);
        assert!(a.iter().filter(|r| r.outcome.is_ok()).count() >= 4);
    }

    #[test]
    fn sweep_cost_grows_with_lambda_per_pipeline() {
        let results = sweep_grid(&[50.0, 200.0], &[1.0], &[0.3], 25.0);
        // For each pipeline: λ=50 row precedes λ=200 row.
        for pair in results.chunks(2) {
            let (lo, hi) = (&pair[0], &pair[1]);
            assert_eq!(lo.pipeline, hi.pipeline);
            if let (Ok(a), Ok(b)) = (&lo.outcome, &hi.outcome) {
                assert!(
                    b.cost_per_hour >= a.cost_per_hour - 1e-9,
                    "{}: λ200 ${} < λ50 ${}",
                    lo.pipeline,
                    b.cost_per_hour,
                    a.cost_per_hour
                );
            }
        }
    }
}
