//! Closed-loop robustness harness: the Planner → Tuner loop under
//! adversarial arrival processes.
//!
//! The paper's claim (§5, §6.4) is that the combination of the
//! low-frequency Planner and the network-calculus Tuner holds tail-latency
//! SLOs *under changes in the query arrival process*. This harness
//! measures that claim directly: every cell of a scenario × pipeline grid
//! plans on a nominal Gamma sample (what the operator believed the
//! workload was), then serves a scenario trace from
//! [`crate::workload::scenarios`] — flash crowds, MMPP regime switching,
//! diurnal cycles, heavy-tailed renewals, CV shifts — with the Tuner in
//! the control loop ([`simulate_controlled`]).
//!
//! Mechanics:
//!
//! * the grid is sharded over [`parallel_map_indexed`] (one cell per
//!   task), with planner-internal parallelism adaptively set to the cores
//!   the fan-out cannot fill ([`shard_planner_threads`]);
//! * all cells share one planning sample per seed and one
//!   [`EstimatorCache`], so the four unique planning problems are solved
//!   once and every other cell's feasibility queries are cache hits; the
//!   CLI run persists that cache across processes (disable with
//!   `--no-cache`), so repeated invocations warm-start;
//! * every cell reports SLO miss rate, measured P99, the cost trajectory
//!   (mean $/hr, total $, downsampled replica timeline) and the Tuner's
//!   action counts ([`CountingController`]);
//! * the report is written as machine-readable JSON (`robustness.json`).
//!
//! Determinism: traces derive from the base seed via
//! [`scenarios::child_seed`], plans are bit-identical regardless of
//! thread count or cache state, and the JSON encoder orders keys
//! canonically — the same seed always produces a byte-identical report
//! (regression-tested below). Telemetry that depends on thread
//! scheduling (cache hit rates) is deliberately excluded.

use std::sync::Arc;

use crate::config::{pipelines, PipelineSpec};
use crate::planner::{EstimatorCache, Planner};
use crate::profiler::analytic::paper_profiles;
use crate::simulator::control::{simulate_controlled, CountingController};
use crate::simulator::{self, SimParams};
use crate::tuner::{Tuner, TunerInputs};
use crate::util::json::Json;
use crate::util::par::{default_workers, parallel_map_indexed};
use crate::util::stats;
use crate::workload::scenarios::{self, Scenario};
use crate::workload::{gamma_trace, Trace};

use super::common::{shard_planner_threads, Ctx};

/// SLO all cells are planned and judged against (loose enough that every
/// paper pipeline is feasible at the nominal λ = 100 QPS sample).
pub const DEFAULT_SLO: f64 = 0.35;

/// Nominal planning rate: every scenario family stresses deviations from
/// this assumed workload.
const NOMINAL_LAMBDA: f64 = 100.0;

/// The built-in scenario families, in report order.
pub const FAMILIES: &[&str] = &[
    "steady",
    "bursty-mmpp",
    "diurnal",
    "flash-crowd",
    "heavy-tail-pareto",
    "heavy-tail-lognormal",
    "cv-shift",
];

/// The declarative scenario for one family (`None` for unknown names).
/// Quick mode shrinks the served horizon so CI completes in seconds.
pub fn family_scenario(family: &str, quick: bool) -> Option<Scenario> {
    let dur = if quick { 120.0 } else { 600.0 };
    let s = match family {
        // The control: live traffic matches the planning assumption.
        "steady" => Scenario::Gamma { lambda: NOMINAL_LAMBDA, cv: 1.0, duration: dur },
        // Markov-modulated bursts: long calm regime, short hot regime,
        // same long-run mean as the nominal plan.
        "bursty-mmpp" => Scenario::Mmpp {
            rates: vec![60.0, 240.0],
            dwell: vec![40.0, 12.0],
            duration: dur,
        },
        // Two compressed diurnal cycles around the nominal rate.
        "diurnal" => Scenario::Diurnal {
            base: NOMINAL_LAMBDA,
            amplitude: 0.5,
            period: dur / 2.0,
            cv: 1.0,
            duration: dur,
        },
        // A 3.2x flash crowd: sharp ramp, sustained hold, linear decay.
        "flash-crowd" => Scenario::FlashCrowd {
            base: NOMINAL_LAMBDA,
            peak: 320.0,
            start: dur * 0.25,
            ramp: 5.0,
            hold: dur * 0.15,
            decay: dur * 0.10,
            cv: 1.0,
            duration: dur,
        },
        // Heavy-tailed renewals at the nominal mean rate.
        "heavy-tail-pareto" => {
            Scenario::Pareto { lambda: NOMINAL_LAMBDA, shape: 1.7, duration: dur }
        }
        "heavy-tail-lognormal" => {
            Scenario::Lognormal { lambda: NOMINAL_LAMBDA, sigma: 1.4, duration: dur }
        }
        // The Fig 11 class: same rate, burstiness jumps mid-trace.
        "cv-shift" => Scenario::Splice(vec![
            Scenario::Gamma { lambda: NOMINAL_LAMBDA, cv: 1.0, duration: dur / 2.0 },
            Scenario::Gamma { lambda: NOMINAL_LAMBDA, cv: 4.0, duration: dur / 2.0 },
        ]),
        _ => return None,
    };
    Some(s)
}

/// The (planning sample, live trace) pair for one family. The sample is
/// the *same* nominal Gamma trace for every family — the operator planned
/// for nominal traffic; the scenario is what actually arrived — which
/// also lets the whole grid share planning work through the estimator
/// cache. Seeds derive deterministically from `seed` and the family's
/// position in [`FAMILIES`].
pub fn family_traces(family: &str, seed: u64, quick: bool) -> Option<(Trace, Trace)> {
    let scenario = family_scenario(family, quick)?;
    let idx = FAMILIES.iter().position(|f| *f == family)? as u64;
    let sample_secs = if quick { 25.0 } else { 60.0 };
    let sample = gamma_trace(
        NOMINAL_LAMBDA,
        1.0,
        sample_secs,
        scenarios::child_seed(seed, 7),
    );
    let live = scenario.build(scenarios::child_seed(seed, 100 + idx)).ok()?;
    Some((sample, live))
}

/// Closed-loop metrics of one (scenario, pipeline) cell.
#[derive(Debug, Clone)]
pub struct CellMetrics {
    pub planned_cost_per_hour: f64,
    pub planned_replicas: usize,
    pub estimated_p99: f64,
    pub queries: usize,
    pub p99: f64,
    pub miss_rate: f64,
    pub mean_cost_per_hour: f64,
    pub total_cost: f64,
    pub scale_ups: usize,
    pub scale_downs: usize,
    pub max_replicas: usize,
    pub final_replicas: usize,
    /// Downsampled (time, total provisioned replicas) cost trajectory.
    pub replica_timeline: Vec<(f64, usize)>,
}

/// One grid cell: a scenario family served by a pipeline, or the reason
/// it could not run (e.g. the plan was infeasible).
#[derive(Debug, Clone)]
pub struct Cell {
    pub scenario: String,
    pub pipeline: String,
    pub outcome: Result<CellMetrics, String>,
}

/// Keep at most `max_points` timeline points, always retaining the first
/// and last (the plot-ready cost trajectory; full timelines can hold one
/// point per tuner action).
fn downsample(timeline: &[(f64, usize)], max_points: usize) -> Vec<(f64, usize)> {
    if timeline.len() <= max_points || max_points < 2 {
        return timeline.to_vec();
    }
    let mut out: Vec<(f64, usize)> = (0..max_points)
        .map(|i| timeline[i * (timeline.len() - 1) / (max_points - 1)])
        .collect();
    out.dedup();
    out
}

/// Run the scenario × pipeline grid closed-loop and return the cells in
/// grid order (scenario-major), deterministic for a fixed seed.
pub fn run_grid(
    families: &[&str],
    specs: &[PipelineSpec],
    seed: u64,
    slo: f64,
    quick: bool,
) -> Vec<Cell> {
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    run_grid_with_cache(families, specs, seed, slo, quick, cache)
}

/// [`run_grid`] against a caller-supplied [`EstimatorCache`] — e.g. one
/// warm-started from a persisted cache file. Reports are bit-identical to
/// a cold cache: cached knowledge answers feasibility queries exactly as
/// a fresh computation would.
pub fn run_grid_with_cache(
    families: &[&str],
    specs: &[PipelineSpec],
    seed: u64,
    slo: f64,
    quick: bool,
    cache: Arc<EstimatorCache>,
) -> Vec<Cell> {
    let profiles = paper_profiles();
    let mut grid: Vec<(&str, &PipelineSpec)> = Vec::new();
    for &family in families {
        for spec in specs {
            grid.push((family, spec));
        }
    }
    let n = grid.len();
    let inner = shard_planner_threads(n);
    parallel_map_indexed(n, default_workers(), |idx| {
        let (family, spec) = grid[idx];
        let Some((sample, live)) = family_traces(family, seed, quick) else {
            return Cell {
                scenario: family.to_string(),
                pipeline: spec.name.clone(),
                outcome: Err(format!("unknown scenario family {family:?}")),
            };
        };
        let outcome = run_cell(spec, &profiles, &sample, &live, slo, inner, &cache);
        Cell { scenario: family.to_string(), pipeline: spec.name.clone(), outcome }
    })
}

fn run_cell(
    spec: &PipelineSpec,
    profiles: &crate::profiler::ProfileSet,
    sample: &Trace,
    live: &Trace,
    slo: f64,
    planner_threads: usize,
    cache: &Arc<EstimatorCache>,
) -> Result<CellMetrics, String> {
    let plan = Planner::new(spec, profiles)
        .with_threads(planner_threads)
        .with_shared_cache(Arc::clone(cache))
        .plan(sample, slo)
        .map_err(|e| e.to_string())?;
    let st = simulator::service_time(spec, profiles, &plan.config);
    let inputs = TunerInputs::from_plan(spec, profiles, &plan.config, sample, st);
    let mut tuner = Tuner::new(inputs);
    let mut counting = CountingController::new(&mut tuner);
    let result = simulate_controlled(
        spec,
        profiles,
        &plan.config,
        live,
        &SimParams::default(),
        &mut counting,
    );
    let hours = (result.horizon / 3600.0).max(1e-12);
    Ok(CellMetrics {
        planned_cost_per_hour: plan.cost_per_hour,
        planned_replicas: plan.config.total_replicas(),
        estimated_p99: plan.estimated_p99,
        queries: result.latencies.len(),
        p99: stats::p99(&result.latencies),
        miss_rate: result.miss_rate(slo),
        mean_cost_per_hour: result.cost_dollars / hours,
        total_cost: result.cost_dollars,
        scale_ups: counting.scale_ups,
        scale_downs: counting.scale_downs,
        max_replicas: result.replica_timeline.iter().map(|&(_, r)| r).max().unwrap_or(0),
        final_replicas: result.replica_timeline.last().map_or(0, |&(_, r)| r),
        replica_timeline: downsample(&result.replica_timeline, 24),
    })
}

/// Encode the grid as the canonical machine-readable report. Key order
/// is canonical (`Json::Obj` is a `BTreeMap`) and every value is a
/// deterministic function of the seed, so the byte stream is too.
pub fn report_json(seed: u64, slo: f64, quick: bool, cells: &[Cell]) -> Json {
    let mut doc = Json::obj();
    doc.set("seed", seed as usize)
        .set("slo", slo)
        .set("quick", quick)
        .set(
            "scenarios",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| c.scenario.as_str())
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .map(Json::from)
                    .collect(),
            ),
        )
        .set(
            "pipelines",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| c.pipeline.as_str())
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .map(Json::from)
                    .collect(),
            ),
        );
    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("scenario", c.scenario.as_str()).set("pipeline", c.pipeline.as_str());
            match &c.outcome {
                Ok(m) => {
                    o.set("planned_cost_per_hour", m.planned_cost_per_hour)
                        .set("planned_replicas", m.planned_replicas)
                        .set("estimated_p99", m.estimated_p99)
                        .set("queries", m.queries)
                        .set("p99", m.p99)
                        .set("miss_rate", m.miss_rate)
                        .set("mean_cost_per_hour", m.mean_cost_per_hour)
                        .set("total_cost", m.total_cost)
                        .set("scale_ups", m.scale_ups)
                        .set("scale_downs", m.scale_downs)
                        .set("max_replicas", m.max_replicas)
                        .set("final_replicas", m.final_replicas)
                        .set(
                            "replica_timeline",
                            Json::Arr(
                                m.replica_timeline
                                    .iter()
                                    .map(|&(t, r)| {
                                        Json::Arr(vec![Json::Num(t), Json::Num(r as f64)])
                                    })
                                    .collect(),
                            ),
                        );
                }
                Err(e) => {
                    o.set("error", e.as_str());
                }
            }
            o
        })
        .collect();
    doc.set("cells", Json::Arr(rows));
    doc
}

/// CLI entry point: run the full grid, print a table, write
/// `robustness.json` into the results dir.
pub fn run(ctx: &Ctx, seed: u64) -> bool {
    crate::util::bench::figure_header(
        "Robustness",
        "Planner + Tuner closed loop across scenario families, all four pipelines",
    );
    let specs = pipelines::all();
    // Persistent estimator cache: the four planning problems warm-start
    // from a previous invocation's simulations (bit-identical reports
    // either way — the cache only memoizes deterministic knowledge).
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    super::common::warm_cache(ctx, &cache);
    let cells =
        run_grid_with_cache(FAMILIES, &specs, seed, DEFAULT_SLO, ctx.quick, Arc::clone(&cache));
    super::common::persist_cache(ctx, &cache);
    for c in &cells {
        match &c.outcome {
            Ok(m) => println!(
                "  {:<22} {:<18} p99 {:>7.1}ms  miss {:>6.2}%  ${:>6.2}/hr  \
                 up {:>3} down {:>3}  replicas {:>3}→{:<3} (max {})",
                c.scenario,
                c.pipeline,
                m.p99 * 1e3,
                m.miss_rate * 100.0,
                m.mean_cost_per_hour,
                m.scale_ups,
                m.scale_downs,
                m.planned_replicas,
                m.final_replicas,
                m.max_replicas,
            ),
            Err(e) => println!("  {:<22} {:<18} {e}", c.scenario, c.pipeline),
        }
    }
    let ok = cells.iter().filter(|c| c.outcome.is_ok()).count();
    println!(
        "  {} / {} cells completed (slo {:.0} ms, seed {seed})",
        ok,
        cells.len(),
        DEFAULT_SLO * 1e3
    );
    let doc = report_json(seed, DEFAULT_SLO, ctx.quick, &cells);
    let path = ctx.results_dir.join("robustness.json");
    match std::fs::write(&path, doc.to_string()) {
        Ok(()) => {
            println!("  wrote {}", path.display());
            true
        }
        Err(e) => {
            eprintln!("could not write {}: {e}", path.display());
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_yields_a_live_trace() {
        for family in FAMILIES {
            let (sample, live) = family_traces(family, 1, true).unwrap();
            assert!(!sample.is_empty(), "{family}: empty sample");
            assert!(!live.is_empty(), "{family}: empty live trace");
            assert!(live.duration() > 60.0, "{family}: live too short");
            // Deterministic in the seed.
            let (s2, l2) = family_traces(family, 1, true).unwrap();
            assert_eq!(sample, s2, "{family}");
            assert_eq!(live, l2, "{family}");
            assert_ne!(live, family_traces(family, 2, true).unwrap().1, "{family}");
        }
        assert!(family_traces("no-such-family", 1, true).is_none());
    }

    #[test]
    fn families_share_the_planning_sample() {
        let (a, _) = family_traces("steady", 5, true).unwrap();
        let (b, _) = family_traces("flash-crowd", 5, true).unwrap();
        assert_eq!(a, b, "one nominal sample across the grid");
    }

    #[test]
    fn grid_report_is_bit_reproducible() {
        let families = ["steady", "flash-crowd"];
        let specs = [pipelines::image_processing()];
        let a = run_grid(&families, &specs, 11, DEFAULT_SLO, true);
        let b = run_grid(&families, &specs, 11, DEFAULT_SLO, true);
        let ja = report_json(11, DEFAULT_SLO, true, &a).to_string();
        let jb = report_json(11, DEFAULT_SLO, true, &b).to_string();
        assert_eq!(ja, jb, "same seed must produce byte-identical reports");
        // Cells are in grid order and carry real metrics.
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].scenario, "steady");
        assert_eq!(a[1].scenario, "flash-crowd");
        for c in &a {
            let m = c.outcome.as_ref().expect("cell should plan and run");
            assert!(m.queries > 0);
            assert!(m.p99 > 0.0);
            assert!(m.total_cost > 0.0);
            assert!(m.planned_replicas > 0);
            assert!(!m.replica_timeline.is_empty());
        }
        // The flash crowd must actually exercise the tuner.
        let flash = a[1].outcome.as_ref().unwrap();
        assert!(flash.scale_ups > 0, "flash crowd never scaled up");
        assert!(flash.max_replicas > flash.planned_replicas);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let timeline: Vec<(f64, usize)> = (0..100).map(|i| (i as f64, i)).collect();
        let d = downsample(&timeline, 10);
        assert!(d.len() <= 10);
        assert_eq!(d.first().copied(), Some((0.0, 0)));
        assert_eq!(d.last().copied(), Some((99.0, 99)));
        assert_eq!(downsample(&timeline[..5], 10).len(), 5);
    }
}
