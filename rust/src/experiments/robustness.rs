//! Closed-loop robustness harness: the Planner → Tuner loop under
//! adversarial arrival processes.
//!
//! The paper's claim (§5, §6.4) is that the combination of the
//! low-frequency Planner and the network-calculus Tuner holds tail-latency
//! SLOs *under changes in the query arrival process*. This harness
//! measures that claim directly: every cell of a scenario × pipeline grid
//! plans on a nominal Gamma sample (what the operator believed the
//! workload was), then serves a scenario trace from
//! [`crate::workload::scenarios`] — flash crowds, MMPP regime switching,
//! diurnal cycles, heavy-tailed renewals, CV shifts — with the Tuner in
//! the control loop ([`simulate_controlled_probed`]). Chaos families
//! additionally
//! carry a fault spec ([`crate::simulator::faults`]): replica crash
//! storms, stage brownouts and correlated outages injected into the same
//! closed loop (and into the baselines — same failure schedule, fair
//! comparison), with per-cell crash/retry/shed telemetry in the report.
//!
//! Mechanics:
//!
//! * the scenario matrix is **data**: every family is a checked-in
//!   [`ScenarioSpec`] JSON document under `scenarios/` at the repository
//!   root, embedded at compile time ([`SCENARIO_FILES`]) and enumerated
//!   in [`FAMILIES`];
//! * the grid is sharded over [`parallel_map_indexed`] (one cell per
//!   task), with planner-internal parallelism adaptively set to the cores
//!   the fan-out cannot fill ([`shard_planner_threads`]);
//! * all cells share one planning sample per seed and one
//!   [`EstimatorCache`], so the four unique planning problems are solved
//!   once and every other cell's feasibility queries are cache hits; the
//!   CLI run persists that cache across processes (disable with
//!   `--no-cache`), so repeated invocations warm-start;
//! * every cell serves the **baselines through the same closed loop**:
//!   the coarse-grained CG-Mean / CG-Peak plans under the AutoScale
//!   reactive tuner ([`crate::baselines`]), reporting per-baseline cost
//!   ratio and miss-rate ratio vs InferLine — the paper's Fig 5/Fig 9
//!   comparative claims (up to 7.6x cost, 34.5x miss rate) as a tracked
//!   per-scenario artifact;
//! * every cell reports SLO miss rate, measured P99, the cost trajectory
//!   (mean $/hr, total $, downsampled replica timeline) and the Tuner's
//!   action counts ([`CountingController`]);
//! * every cell runs under a [`RecordingProbe`] and reports an SLO-miss
//!   **attribution** blame table ([`MissAttribution`]): the critical-path
//!   latency of every missed query split into per-stage queueing vs
//!   service time plus the RPC residual, so a regression in the matrix
//!   points at the stage (and the regime — queueing vs service) that
//!   caused it;
//! * the report is written as machine-readable JSON (`robustness.json`,
//!   format tag [`REPORT_FORMAT`]) plus flat CSVs
//!   (`robustness_baselines.csv` per-system,
//!   `robustness_attribution.csv` per-stage blame); `inferline budget
//!   check` ([`super::budgets`]) gates CI on it.
//!
//! Determinism: traces derive from the base seed via
//! [`scenarios::child_seed`], plans are bit-identical regardless of
//! thread count or cache state, baseline runs are closed-form functions
//! of (spec, sample, live), and the JSON encoder orders keys canonically
//! — the same seed always produces a byte-identical report
//! (regression-tested below). Telemetry that depends on thread
//! scheduling (cache hit rates) is deliberately excluded. Metrics that
//! can be undefined (miss-rate ratios with a zero denominator, P99 of an
//! empty run) are serialized as `null`, never NaN — the budget checker
//! treats them as "no data".

use std::sync::Arc;

use crate::baselines::coarse::CoarseTarget;
use crate::config::{pipelines, PipelineSpec};
use crate::planner::{EstimatorCache, Planner};
use crate::profiler::analytic::paper_profiles;
use crate::simulator::control::{simulate_controlled_probed, CountingController};
use crate::simulator::faults::FaultPlan;
use crate::simulator::probe::{MissAttribution, RecordingProbe};
use crate::simulator::{self, SimParams};
use crate::tuner::{Tuner, TunerInputs};
use crate::util::json::Json;
use crate::util::par::{default_workers, parallel_map_indexed};
use crate::util::stats;
use crate::workload::scenarios::{self, Scenario, ScenarioSpec};
use crate::workload::{gamma_trace, Trace};

use super::common::{csv_num, shard_planner_threads, Ctx};

/// SLO all cells are planned and judged against (loose enough that every
/// paper pipeline is feasible at the nominal λ = 100 QPS sample).
pub const DEFAULT_SLO: f64 = 0.35;

/// Format tag stamped into `robustness.json`; the budget checker
/// ([`super::budgets`]) refuses reports it does not recognize.
pub const REPORT_FORMAT: &str = "inferline-robustness-v4";

/// Nominal planning rate: every scenario family stresses deviations from
/// this assumed workload.
const NOMINAL_LAMBDA: f64 = 100.0;

/// The checked-in scenario matrix, embedded at compile time so the
/// binary needs no runtime data directory (`scenarios/` at the repo
/// root; see its README). `rust/tests/budget_ledger.rs` keeps the
/// directory, this table and [`FAMILIES`] in sync.
const SCENARIO_FILES: &[(&str, &str)] = &[
    ("steady", include_str!("../../../scenarios/steady.json")),
    ("bursty-mmpp", include_str!("../../../scenarios/bursty-mmpp.json")),
    ("diurnal", include_str!("../../../scenarios/diurnal.json")),
    ("flash-crowd", include_str!("../../../scenarios/flash-crowd.json")),
    ("heavy-tail-pareto", include_str!("../../../scenarios/heavy-tail-pareto.json")),
    ("heavy-tail-lognormal", include_str!("../../../scenarios/heavy-tail-lognormal.json")),
    ("cv-shift", include_str!("../../../scenarios/cv-shift.json")),
    ("flash-on-diurnal", include_str!("../../../scenarios/flash-on-diurnal.json")),
    ("regime-splice", include_str!("../../../scenarios/regime-splice.json")),
    ("thinned-autoscale", include_str!("../../../scenarios/thinned-autoscale.json")),
    ("heavy-tail-superpose", include_str!("../../../scenarios/heavy-tail-superpose.json")),
    ("surge-crossfade", include_str!("../../../scenarios/surge-crossfade.json")),
    ("replica-crash-storm", include_str!("../../../scenarios/replica-crash-storm.json")),
    ("slow-stage-brownout", include_str!("../../../scenarios/slow-stage-brownout.json")),
    (
        "outage-during-flash-crowd",
        include_str!("../../../scenarios/outage-during-flash-crowd.json"),
    ),
    ("production-replay", include_str!("../../../scenarios/production-replay.json")),
];

/// The scenario families, in report order. Position is part of the seed
/// derivation (`child_seed(seed, 100 + idx)`), so new families append.
pub const FAMILIES: &[&str] = &[
    "steady",
    "bursty-mmpp",
    "diurnal",
    "flash-crowd",
    "heavy-tail-pareto",
    "heavy-tail-lognormal",
    "cv-shift",
    "flash-on-diurnal",
    "regime-splice",
    "thinned-autoscale",
    "heavy-tail-superpose",
    "surge-crossfade",
    "replica-crash-storm",
    "slow-stage-brownout",
    "outage-during-flash-crowd",
    "production-replay",
];

/// The parsed spec of one checked-in family (`None` for unknown names).
/// Panics on a malformed embedded file — that is a build artifact error
/// a unit test catches, not a runtime condition.
pub fn family_spec(family: &str) -> Option<ScenarioSpec> {
    let (_, text) = SCENARIO_FILES.iter().find(|(name, _)| *name == family)?;
    match ScenarioSpec::parse_str(text) {
        Ok(spec) => Some(spec),
        Err(e) => panic!("embedded scenario {family:?} is malformed: {e}"),
    }
}

/// The declarative scenario for one family (`None` for unknown names).
/// Quick mode serves the spec's explicit quick node or its
/// schedule-compressed full node, so CI completes in seconds.
pub fn family_scenario(family: &str, quick: bool) -> Option<Scenario> {
    family_spec(family).map(|spec| spec.scenario_for(quick))
}

/// The (planning sample, live trace) pair for one family. The sample is
/// the *same* nominal Gamma trace for every family — the operator planned
/// for nominal traffic; the scenario is what actually arrived — which
/// also lets the whole grid share planning work through the estimator
/// cache. Seeds derive deterministically from `seed` and the family's
/// position in [`FAMILIES`].
pub fn family_traces(family: &str, seed: u64, quick: bool) -> Option<(Trace, Trace)> {
    let scenario = family_scenario(family, quick)?;
    let idx = FAMILIES.iter().position(|f| *f == family)? as u64;
    let sample_secs = if quick { 25.0 } else { 60.0 };
    let sample = gamma_trace(
        NOMINAL_LAMBDA,
        1.0,
        sample_secs,
        scenarios::child_seed(seed, 7),
    );
    let live = scenario.build(scenarios::child_seed(seed, 100 + idx)).ok()?;
    Some((sample, live))
}

/// The compiled fault plan of one family's chaos spec for a pipeline of
/// `n_stages` stages (`None` for fault-free families or unknown names).
/// Quick mode compresses the failure schedule alongside the arrival
/// schedule; the storm seed derives from `seed` and the family position
/// (`child_seed(seed, 200 + idx)` — disjoint from the trace stream).
pub fn family_fault_plan(
    family: &str,
    seed: u64,
    quick: bool,
    n_stages: usize,
) -> Option<FaultPlan> {
    let spec = family_spec(family)?;
    let idx = FAMILIES.iter().position(|f| *f == family)? as u64;
    let fault_spec = spec.faults_for(quick)?;
    Some(fault_spec.compile(n_stages, scenarios::child_seed(seed, 200 + idx)))
}

/// Closed-loop metrics of one baseline system serving the same
/// (scenario, pipeline) cell as InferLine, plus the two comparative
/// ratios the paper's headline claims are made of. Ratios with a zero
/// denominator are NaN in memory and `null` in the report ("no data").
#[derive(Debug, Clone)]
pub struct BaselineMetrics {
    /// System label (`CG-Mean+AutoScale`, `CG-Peak+AutoScale`).
    pub system: String,
    pub queries: usize,
    pub p99: f64,
    pub miss_rate: f64,
    pub mean_cost_per_hour: f64,
    pub total_cost: f64,
    /// Baseline mean $/hr divided by InferLine mean $/hr (> 1 means
    /// InferLine is cheaper — the paper's up-to-7.6x claim).
    pub cost_ratio: f64,
    /// Baseline miss rate divided by InferLine miss rate (> 1 means
    /// InferLine misses less — the paper's up-to-34.5x claim).
    pub miss_ratio: f64,
}

/// Closed-loop metrics of one (scenario, pipeline) cell.
#[derive(Debug, Clone)]
pub struct CellMetrics {
    pub planned_cost_per_hour: f64,
    pub planned_replicas: usize,
    pub estimated_p99: f64,
    pub queries: usize,
    pub p99: f64,
    pub miss_rate: f64,
    pub mean_cost_per_hour: f64,
    pub total_cost: f64,
    pub scale_ups: usize,
    pub scale_downs: usize,
    pub max_replicas: usize,
    pub final_replicas: usize,
    /// Replica crashes injected by the cell's fault plan (0 for
    /// fault-free families).
    pub crashes: u64,
    /// Queries requeued after their in-flight batch was crashed.
    pub retries: u64,
    /// Queries dropped by the deadline-shed policy (counted separately
    /// from SLO misses — a shed query completes no latency sample).
    pub shed: u64,
    /// Per-stage SLO-miss blame table from the telemetry probe: where
    /// the missed queries' latency went (critical-path queueing vs
    /// service per stage, RPC as the remainder). Deterministic per seed.
    pub attribution: MissAttribution,
    /// Downsampled (time, total provisioned replicas) cost trajectory.
    pub replica_timeline: Vec<(f64, usize)>,
    /// The baseline systems serving the same cell (same sample, same
    /// live trace, their own planners and reactive tuner).
    pub baselines: Vec<BaselineMetrics>,
}

impl CellMetrics {
    /// Serving cost relative to the planned configuration's cost (the
    /// tuner's cost overhead; 1.0 = the Tuner never left the plan).
    pub fn cost_overhead(&self) -> f64 {
        self.mean_cost_per_hour / self.planned_cost_per_hour
    }

    /// Fraction of arrived queries the shed policy dropped
    /// (`shed / (completed + shed)`; NaN when the cell served nothing).
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / (self.queries as f64 + self.shed as f64)
    }
}

/// One grid cell: a scenario family served by a pipeline, or the reason
/// it could not run (e.g. the plan was infeasible).
#[derive(Debug, Clone)]
pub struct Cell {
    pub scenario: String,
    pub pipeline: String,
    pub outcome: Result<CellMetrics, String>,
}

/// Keep at most `max_points` timeline points, always retaining the first
/// and last (the plot-ready cost trajectory; full timelines can hold one
/// point per tuner action).
fn downsample(timeline: &[(f64, usize)], max_points: usize) -> Vec<(f64, usize)> {
    if timeline.len() <= max_points || max_points < 2 {
        return timeline.to_vec();
    }
    let mut out: Vec<(f64, usize)> = (0..max_points)
        .map(|i| timeline[i * (timeline.len() - 1) / (max_points - 1)])
        .collect();
    out.dedup();
    out
}

/// Run the scenario × pipeline grid closed-loop and return the cells in
/// grid order (scenario-major), deterministic for a fixed seed.
pub fn run_grid(
    families: &[&str],
    specs: &[PipelineSpec],
    seed: u64,
    slo: f64,
    quick: bool,
) -> Vec<Cell> {
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    run_grid_with_cache(families, specs, seed, slo, quick, cache)
}

/// [`run_grid`] against a caller-supplied [`EstimatorCache`] — e.g. one
/// warm-started from a persisted cache file. Reports are bit-identical to
/// a cold cache: cached knowledge answers feasibility queries exactly as
/// a fresh computation would.
pub fn run_grid_with_cache(
    families: &[&str],
    specs: &[PipelineSpec],
    seed: u64,
    slo: f64,
    quick: bool,
    cache: Arc<EstimatorCache>,
) -> Vec<Cell> {
    let profiles = paper_profiles();
    let mut grid: Vec<(&str, &PipelineSpec)> = Vec::new();
    for &family in families {
        for spec in specs {
            grid.push((family, spec));
        }
    }
    let n = grid.len();
    let inner = shard_planner_threads(n);
    parallel_map_indexed(n, default_workers(), |idx| {
        let (family, spec) = grid[idx];
        let Some((sample, live)) = family_traces(family, seed, quick) else {
            return Cell {
                scenario: family.to_string(),
                pipeline: spec.name.clone(),
                outcome: Err(format!("unknown scenario family {family:?}")),
            };
        };
        let fault_plan = family_fault_plan(family, seed, quick, spec.stages.len());
        let outcome =
            run_cell(spec, &profiles, &sample, &live, slo, inner, &cache, fault_plan.as_ref());
        Cell { scenario: family.to_string(), pipeline: spec.name.clone(), outcome }
    })
}

#[allow(clippy::too_many_arguments)]
fn run_cell(
    spec: &PipelineSpec,
    profiles: &crate::profiler::ProfileSet,
    sample: &Trace,
    live: &Trace,
    slo: f64,
    planner_threads: usize,
    cache: &Arc<EstimatorCache>,
    fault_plan: Option<&FaultPlan>,
) -> Result<CellMetrics, String> {
    let plan = Planner::new(spec, profiles)
        .with_threads(planner_threads)
        .with_shared_cache(Arc::clone(cache))
        .plan(sample, slo)
        .map_err(|e| e.to_string())?;
    let st = simulator::service_time(spec, profiles, &plan.config);
    let inputs = TunerInputs::from_plan(spec, profiles, &plan.config, sample, st);
    let mut tuner = Tuner::new(inputs);
    let mut counting = CountingController::new(&mut tuner);
    let params = SimParams::default();
    // The recording probe observes every cell (fixed internal seed, so
    // the attribution table is as bit-reproducible as the run itself);
    // probes are read-only, so the metrics are identical to a probe-less
    // run's.
    let mut probe = RecordingProbe::new(slo);
    let result = simulate_controlled_probed(
        spec,
        profiles,
        &plan.config,
        live,
        &params,
        &mut counting,
        fault_plan,
        &mut probe,
    );
    let attribution = probe.finish().attribution;
    let hours = (result.horizon / 3600.0).max(1e-12);
    let il_miss = result.miss_rate(slo);
    let il_cost_per_hour = result.cost_dollars / hours;
    // The baselines serve the exact same cell: coarse-grained planning
    // on the nominal sample, the AutoScale reactive tuner in the loop —
    // and, in chaos families, the same compiled failure schedule.
    let baselines = [CoarseTarget::Mean, CoarseTarget::Peak]
        .into_iter()
        .map(|target| {
            let s = super::common::run_coarse_with_faults(
                spec, profiles, sample, live, slo, target, true, fault_plan,
            );
            BaselineMetrics {
                system: s.system.clone(),
                queries: s.result.latencies.len(),
                p99: s.p99,
                miss_rate: s.miss_rate,
                mean_cost_per_hour: s.mean_cost_per_hour,
                total_cost: s.total_cost,
                cost_ratio: s.mean_cost_per_hour / il_cost_per_hour,
                // 0/0 and x/0 are deliberate NaN/∞: "no data" downstream.
                miss_ratio: s.miss_rate / il_miss,
            }
        })
        .collect();
    Ok(CellMetrics {
        planned_cost_per_hour: plan.cost_per_hour,
        planned_replicas: plan.config.total_replicas(),
        estimated_p99: plan.estimated_p99,
        queries: result.latencies.len(),
        p99: stats::p99(&result.latencies),
        miss_rate: il_miss,
        mean_cost_per_hour: il_cost_per_hour,
        total_cost: result.cost_dollars,
        scale_ups: counting.scale_ups,
        scale_downs: counting.scale_downs,
        max_replicas: result.replica_timeline.iter().map(|&(_, r)| r).max().unwrap_or(0),
        final_replicas: result.replica_timeline.last().map_or(0, |&(_, r)| r),
        crashes: result.crashes,
        retries: result.retries,
        shed: result.shed,
        attribution,
        replica_timeline: downsample(&result.replica_timeline, 24),
        baselines,
    })
}

/// Encode the grid as the canonical machine-readable report. Key order
/// is canonical (`Json::Obj` is a `BTreeMap`) and every value is a
/// deterministic function of the seed, so the byte stream is too.
pub fn report_json(seed: u64, slo: f64, quick: bool, cells: &[Cell]) -> Json {
    let mut doc = Json::obj();
    doc.set("format", REPORT_FORMAT)
        .set("seed", seed as usize)
        .set("slo", slo)
        .set("quick", quick)
        .set(
            "scenarios",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| c.scenario.as_str())
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .map(Json::from)
                    .collect(),
            ),
        )
        .set(
            "pipelines",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| c.pipeline.as_str())
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .map(Json::from)
                    .collect(),
            ),
        );
    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("scenario", c.scenario.as_str()).set("pipeline", c.pipeline.as_str());
            match &c.outcome {
                Ok(m) => {
                    o.set("planned_cost_per_hour", m.planned_cost_per_hour)
                        .set("planned_replicas", m.planned_replicas)
                        .set("estimated_p99", m.estimated_p99)
                        .set("queries", m.queries)
                        .set("p99", Json::num_or_null(m.p99))
                        .set("miss_rate", Json::num_or_null(m.miss_rate))
                        .set("mean_cost_per_hour", m.mean_cost_per_hour)
                        .set("cost_overhead", Json::num_or_null(m.cost_overhead()))
                        .set("total_cost", m.total_cost)
                        .set("scale_ups", m.scale_ups)
                        .set("scale_downs", m.scale_downs)
                        .set("max_replicas", m.max_replicas)
                        .set("final_replicas", m.final_replicas)
                        .set("crashes", m.crashes as usize)
                        .set("retries", m.retries as usize)
                        .set("shed", m.shed as usize)
                        .set("shed_rate", Json::num_or_null(m.shed_rate()))
                        .set("attribution", m.attribution.to_json())
                        .set(
                            "replica_timeline",
                            Json::Arr(
                                m.replica_timeline
                                    .iter()
                                    .map(|&(t, r)| {
                                        Json::Arr(vec![Json::Num(t), Json::Num(r as f64)])
                                    })
                                    .collect(),
                            ),
                        )
                        .set(
                            "baselines",
                            Json::Arr(
                                m.baselines
                                    .iter()
                                    .map(|b| {
                                        let mut bo = Json::obj();
                                        bo.set("system", b.system.as_str())
                                            .set("queries", b.queries)
                                            .set("p99", Json::num_or_null(b.p99))
                                            .set("miss_rate", Json::num_or_null(b.miss_rate))
                                            .set("mean_cost_per_hour", b.mean_cost_per_hour)
                                            .set("total_cost", b.total_cost)
                                            .set("cost_ratio", Json::num_or_null(b.cost_ratio))
                                            .set("miss_ratio", Json::num_or_null(b.miss_ratio));
                                        bo
                                    })
                                    .collect(),
                            ),
                        );
                }
                Err(e) => {
                    o.set("error", e.as_str());
                }
            }
            o
        })
        .collect();
    doc.set("cells", Json::Arr(rows));
    doc
}

/// CLI entry point: run the full grid, print a table, write
/// `robustness.json` into the results dir.
pub fn run(ctx: &Ctx, seed: u64) -> bool {
    crate::util::bench::figure_header(
        "Robustness",
        "Planner + Tuner closed loop across scenario families, all four pipelines",
    );
    let specs = pipelines::all();
    // Persistent estimator cache: the four planning problems warm-start
    // from a previous invocation's simulations (bit-identical reports
    // either way — the cache only memoizes deterministic knowledge).
    let cache = EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY);
    super::common::warm_cache(ctx, &cache);
    let cells =
        run_grid_with_cache(FAMILIES, &specs, seed, DEFAULT_SLO, ctx.quick, Arc::clone(&cache));
    super::common::persist_cache(ctx, &cache);
    for c in &cells {
        match &c.outcome {
            Ok(m) => {
                println!(
                    "  {:<22} {:<18} p99 {:>7.1}ms  miss {:>6.2}%  ${:>6.2}/hr  \
                     up {:>3} down {:>3}  replicas {:>3}→{:<3} (max {})",
                    c.scenario,
                    c.pipeline,
                    m.p99 * 1e3,
                    m.miss_rate * 100.0,
                    m.mean_cost_per_hour,
                    m.scale_ups,
                    m.scale_downs,
                    m.planned_replicas,
                    m.final_replicas,
                    m.max_replicas,
                );
                if let Some(stage) = m.attribution.blame_stage() {
                    println!(
                        "  {:<22} {:<18} {} missed; blame stage {stage}: \
                         queueing {:>7.1}s service {:>7.1}s ({:.0}% of missed latency)",
                        "",
                        "(attribution)",
                        m.attribution.missed,
                        m.attribution.queueing[stage],
                        m.attribution.service[stage],
                        m.attribution.blame_share(stage) * 100.0,
                    );
                }
                if m.crashes > 0 || m.shed > 0 {
                    println!(
                        "  {:<22} {:<18} crashes {:>3}  retries {:>4}  shed {:>4} ({:.2}%)",
                        "",
                        "(faults)",
                        m.crashes,
                        m.retries,
                        m.shed,
                        m.shed_rate() * 100.0,
                    );
                }
                for b in &m.baselines {
                    println!(
                        "  {:<22} {:<18} p99 {:>7.1}ms  miss {:>6.2}%  ${:>6.2}/hr  \
                         cost {:>5.2}x  miss {}x vs InferLine",
                        "",
                        b.system,
                        b.p99 * 1e3,
                        b.miss_rate * 100.0,
                        b.mean_cost_per_hour,
                        b.cost_ratio,
                        if b.miss_ratio.is_finite() {
                            format!("{:.1}", b.miss_ratio)
                        } else {
                            "--".to_string()
                        },
                    );
                }
            }
            Err(e) => println!("  {:<22} {:<18} {e}", c.scenario, c.pipeline),
        }
    }
    let ok = cells.iter().filter(|c| c.outcome.is_ok()).count();
    println!(
        "  {} / {} cells completed (slo {:.0} ms, seed {seed})",
        ok,
        cells.len(),
        DEFAULT_SLO * 1e3
    );
    ctx.write_csv(
        "robustness_baselines.csv",
        "scenario,pipeline,system,queries,p99_ms,miss_rate,mean_cost_per_hour,\
         cost_ratio_vs_inferline,miss_ratio_vs_inferline",
        &baseline_rows(&cells),
    );
    println!("  wrote {}", ctx.results_dir.join("robustness_baselines.csv").display());
    ctx.write_csv(
        "robustness_attribution.csv",
        "scenario,pipeline,stage,missed,queueing_s,service_s,blame_share,\
         rpc_s_total,missed_latency_s_total",
        &attribution_rows(&cells),
    );
    println!("  wrote {}", ctx.results_dir.join("robustness_attribution.csv").display());
    let doc = report_json(seed, DEFAULT_SLO, ctx.quick, &cells);
    let path = ctx.results_dir.join("robustness.json");
    match std::fs::write(&path, doc.to_string()) {
        Ok(()) => {
            println!("  wrote {}", path.display());
            true
        }
        Err(e) => {
            crate::log_warn!("could not write {}: {e}", path.display());
            false
        }
    }
}

/// Flatten the grid into the Fig-9-style per-system comparison rows
/// (one row per completed cell and system, InferLine first with unit
/// ratios). Undefined ratios serialize as empty CSV fields, not NaN.
pub fn baseline_rows(cells: &[Cell]) -> Vec<String> {
    let mut rows = Vec::new();
    for c in cells {
        let Ok(m) = &c.outcome else { continue };
        rows.push(format!(
            "{},{},InferLine,{},{},{},{},{},{}",
            c.scenario,
            c.pipeline,
            m.queries,
            csv_num(m.p99 * 1e3),
            csv_num(m.miss_rate),
            csv_num(m.mean_cost_per_hour),
            csv_num(1.0),
            csv_num(1.0),
        ));
        for b in &m.baselines {
            rows.push(format!(
                "{},{},{},{},{},{},{},{},{}",
                c.scenario,
                c.pipeline,
                b.system,
                b.queries,
                csv_num(b.p99 * 1e3),
                csv_num(b.miss_rate),
                csv_num(b.mean_cost_per_hour),
                csv_num(b.cost_ratio),
                csv_num(b.miss_ratio),
            ));
        }
    }
    rows
}

/// Flatten the per-cell miss-attribution blame tables into CSV rows (one
/// row per completed cell and stage; the query-level RPC remainder and
/// the total missed latency repeat on every stage row of a cell).
/// Undefined shares (cells without misses) are empty fields, not NaN.
pub fn attribution_rows(cells: &[Cell]) -> Vec<String> {
    let mut rows = Vec::new();
    for c in cells {
        let Ok(m) = &c.outcome else { continue };
        let a = &m.attribution;
        for stage in 0..a.queueing.len() {
            rows.push(format!(
                "{},{},{},{},{},{},{},{},{}",
                c.scenario,
                c.pipeline,
                stage,
                a.missed,
                csv_num(a.queueing[stage]),
                csv_num(a.service[stage]),
                csv_num(a.blame_share(stage)),
                csv_num(a.rpc),
                csv_num(a.total_latency),
            ));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_yields_a_live_trace() {
        for family in FAMILIES {
            let (sample, live) = family_traces(family, 1, true).unwrap();
            assert!(!sample.is_empty(), "{family}: empty sample");
            assert!(!live.is_empty(), "{family}: empty live trace");
            assert!(live.duration() > 60.0, "{family}: live too short");
            // Deterministic in the seed.
            let (s2, l2) = family_traces(family, 1, true).unwrap();
            assert_eq!(sample, s2, "{family}");
            assert_eq!(live, l2, "{family}");
            assert_ne!(live, family_traces(family, 2, true).unwrap().1, "{family}");
        }
        assert!(family_traces("no-such-family", 1, true).is_none());
    }

    #[test]
    fn embedded_matrix_matches_families() {
        assert!(FAMILIES.len() >= 12, "matrix shrank to {}", FAMILIES.len());
        assert_eq!(SCENARIO_FILES.len(), FAMILIES.len());
        for (idx, family) in FAMILIES.iter().enumerate() {
            assert_eq!(SCENARIO_FILES[idx].0, *family, "order is part of seed derivation");
            let spec = family_spec(family).unwrap();
            assert_eq!(spec.name, *family, "{family}: spec name mismatch");
            // Quick mode serves a genuinely shorter schedule.
            let full = spec.scenario_for(false).build(1).unwrap();
            let quick = spec.scenario_for(true).build(1).unwrap();
            assert!(
                quick.duration() < 0.5 * full.duration(),
                "{family}: quick {} vs full {}",
                quick.duration(),
                full.duration()
            );
        }
        assert!(family_spec("no-such-family").is_none());
    }

    #[test]
    fn families_share_the_planning_sample() {
        let (a, _) = family_traces("steady", 5, true).unwrap();
        let (b, _) = family_traces("flash-crowd", 5, true).unwrap();
        assert_eq!(a, b, "one nominal sample across the grid");
    }

    #[test]
    fn grid_report_is_bit_reproducible() {
        let families = ["steady", "flash-crowd"];
        let specs = [pipelines::image_processing()];
        let a = run_grid(&families, &specs, 11, DEFAULT_SLO, true);
        let b = run_grid(&families, &specs, 11, DEFAULT_SLO, true);
        let ja = report_json(11, DEFAULT_SLO, true, &a).to_string();
        let jb = report_json(11, DEFAULT_SLO, true, &b).to_string();
        assert_eq!(ja, jb, "same seed must produce byte-identical reports");
        // Cells are in grid order and carry real metrics.
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].scenario, "steady");
        assert_eq!(a[1].scenario, "flash-crowd");
        for c in &a {
            let m = c.outcome.as_ref().expect("cell should plan and run");
            assert!(m.queries > 0);
            assert!(m.p99 > 0.0);
            assert!(m.total_cost > 0.0);
            assert!(m.planned_replicas > 0);
            assert!(!m.replica_timeline.is_empty());
            assert!(m.cost_overhead() > 0.0);
            // Both baselines served the same cell through the loop.
            assert_eq!(m.baselines.len(), 2, "{}", c.scenario);
            assert_eq!(m.baselines[0].system, "CG-Mean+AutoScale");
            assert_eq!(m.baselines[1].system, "CG-Peak+AutoScale");
            for b in &m.baselines {
                assert!(b.queries > 0, "{}: {}", c.scenario, b.system);
                assert!(b.mean_cost_per_hour > 0.0);
                assert!(b.cost_ratio > 0.0 && b.cost_ratio.is_finite());
                // miss_ratio may be NaN (0/0) — but never negative.
                assert!(b.miss_ratio.is_nan() || b.miss_ratio >= 0.0, "{}", b.miss_ratio);
            }
        }
        // The flash crowd must actually exercise the tuner.
        let flash = a[1].outcome.as_ref().unwrap();
        assert!(flash.scale_ups > 0, "flash crowd never scaled up");
        assert!(flash.max_replicas > flash.planned_replicas);
        // The report is valid JSON (NaN ratios become null, never bare
        // NaN bytes) and round-trips through the parser.
        let parsed = crate::util::json::Json::parse(&ja).expect("report must be valid JSON");
        assert_eq!(parsed.req("format").as_str(), Some(REPORT_FORMAT));
        let cells = parsed.req("cells").as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].req("baselines").as_arr().unwrap().len(), 2);
        // The CSV artifact has one InferLine + two baseline rows per cell
        // and no NaN tokens.
        let rows = baseline_rows(&a);
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| !r.contains("NaN")), "{rows:?}");
        assert!(rows[0].contains(",InferLine,"));
        // Every cell carries a per-stage miss-attribution blame table.
        for (cell, c) in cells.iter().zip(&a) {
            let attr = cell.req("attribution");
            assert!(attr.req("missed").as_usize().is_some(), "{}", c.scenario);
            let n_stages = c.outcome.as_ref().unwrap().attribution.queueing.len();
            assert_eq!(
                attr.req("stages").as_arr().unwrap().len(),
                n_stages,
                "{}: one blame row per stage",
                c.scenario
            );
        }
        // The attribution CSV has one row per (cell, stage), no NaN tokens.
        let attr_rows = attribution_rows(&a);
        let stages: usize =
            a.iter().map(|c| c.outcome.as_ref().unwrap().attribution.queueing.len()).sum();
        assert_eq!(attr_rows.len(), stages);
        assert!(attr_rows.iter().all(|r| !r.contains("NaN")), "{attr_rows:?}");
    }

    #[test]
    fn chaos_families_compile_fault_plans() {
        for family in
            ["replica-crash-storm", "slow-stage-brownout", "outage-during-flash-crowd"]
        {
            let plan = family_fault_plan(family, 1, true, 4).expect("chaos family has faults");
            assert!(!plan.is_empty(), "{family}: empty plan");
            assert_eq!(
                plan,
                family_fault_plan(family, 1, true, 4).unwrap(),
                "{family}: compile not deterministic"
            );
            // Quick mode compresses the failure schedule with the trace.
            let full = family_fault_plan(family, 1, false, 4).unwrap();
            let last = |p: &FaultPlan| p.entries.iter().map(|e| e.time).fold(0.0, f64::max);
            assert!(
                last(&plan) < last(&full),
                "{family}: quick schedule not compressed ({} vs {})",
                last(&plan),
                last(&full)
            );
        }
        assert!(family_fault_plan("steady", 1, true, 4).is_none(), "steady is fault-free");
        assert!(family_fault_plan("no-such-family", 1, true, 4).is_none());
    }

    #[test]
    fn chaos_cell_reports_fault_telemetry() {
        let families = ["replica-crash-storm"];
        let specs = [pipelines::image_processing()];
        let cells = run_grid(&families, &specs, 3, DEFAULT_SLO, true);
        let m = cells[0].outcome.as_ref().expect("chaos cell should plan and run");
        assert!(m.queries > 0);
        // Retries only exist downstream of crashes; sheds need a policy.
        if m.crashes == 0 {
            assert_eq!(m.retries, 0, "retries without any applied crash");
        }
        let doc = report_json(3, DEFAULT_SLO, true, &cells).to_string();
        let parsed = crate::util::json::Json::parse(&doc).unwrap();
        let cell = &parsed.req("cells").as_arr().unwrap()[0];
        for key in ["crashes", "retries", "shed"] {
            assert!(
                cell.req(key).as_f64().is_some_and(|v| v >= 0.0),
                "report cell missing {key}"
            );
        }
        assert!(cell.get("shed_rate").is_some(), "report cell missing shed_rate");
        // Attribution rides along even in chaos cells: completed + shed
        // counters are real, and the blame table covers every stage.
        let attr = cell.req("attribution");
        assert!(attr.req("completed").as_usize().is_some_and(|v| v > 0));
        assert!(!attr.req("stages").as_arr().unwrap().is_empty());
        // Same seed, same report — fault injection included.
        let again = run_grid(&families, &specs, 3, DEFAULT_SLO, true);
        assert_eq!(doc, report_json(3, DEFAULT_SLO, true, &again).to_string());
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let timeline: Vec<(f64, usize)> = (0..100).map(|i| (i as f64, i)).collect();
        let d = downsample(&timeline, 10);
        assert!(d.len() <= 10);
        assert_eq!(d.first().copied(), Some((0.0, 0)));
        assert_eq!(d.last().copied(), Some((99.0, 99)));
        assert_eq!(downsample(&timeline[..5], 10).len(), 5);
    }
}
