//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§7) — see DESIGN.md §5 for the experiment index.
//!
//! Each figure driver prints the same rows/series the paper plots and
//! writes CSVs under `results/` (override with `INFERLINE_RESULTS_DIR`).
//! `cargo bench` runs the quick variants; `inferline experiment <id>`
//! runs paper-scale parameters.

pub mod benchcheck;
pub mod budgets;
pub mod common;
pub mod estbench;
pub mod figures;
pub mod fleet;
pub mod robustness;
pub mod sweep;

pub use common::{Ctx, RunSummary};
pub use figures::{run_by_name, ALL_FIGURES};
pub use sweep::{run_sweep, sweep_grid, sweep_grid_with_cache};
