//! SLO budget ledger: per-scenario regression budgets over the
//! robustness matrix, gated in CI.
//!
//! The paper's headline claims are comparative — up to 7.6x lower cost
//! and 34.5x lower SLO miss rate than coarse-grained autoscaling — but a
//! robustness report alone has no memory: a change that doubles the
//! flash-crowd miss rate ships silently unless something in CI knows
//! what "good" looked like. This module is that memory. A checked-in
//! `BUDGETS.json` records, per scenario family, the worst acceptable
//! miss rate, serving-cost overhead, absolute cost, and
//! baseline-cost-ratio floor; `inferline budget check` compares a
//! `robustness.json` report against it and exits nonzero naming every
//! violated scenario, and `inferline budget update` re-baselines the
//! ledger intentionally after a reviewed change.
//!
//! ## `BUDGETS.json` format
//!
//! ```json
//! {
//!   "format": "inferline-budgets-v1",
//!   "quick": {
//!     "seed": 42,
//!     "slo": 0.35,
//!     "miss_slack": 0.02,
//!     "cost_slack": 1.25,
//!     "ratio_slack": 0.8,
//!     "scenarios": {
//!       "steady": {
//!         "max_miss_rate": 0.05,
//!         "max_cost_overhead": 2.5,
//!         "max_cost_per_hour": null,
//!         "min_peak_cost_ratio": 0.5,
//!         "max_shed_rate": 0.1
//!       }
//!     }
//!   },
//!   "full": { ... }
//! }
//! ```
//!
//! Quick-mode (CI) and full-mode budgets are **separate sections**: the
//! two modes serve different horizons, so their numbers are not
//! comparable. `budget check` picks the section matching the report's
//! own `quick` flag.
//!
//! ## Seed + tolerance semantics
//!
//! Robustness reports are bit-reproducible per seed, so every budget
//! section names the `seed` (and `slo`) it was measured at; `check`
//! refuses a report from a different seed rather than comparing
//! incomparable numbers. Because a re-run at the same seed reproduces
//! the baseline exactly, the slacks are *not* noise margins — they are
//! the drift a PR may introduce without an intentional re-baseline:
//!
//! * `miss_slack` — absolute headroom on miss rates
//!   (pass iff `observed <= max_miss_rate + miss_slack`);
//! * `cost_slack` — multiplicative headroom on cost ceilings
//!   (pass iff `observed <= ceiling * cost_slack`);
//! * `ratio_slack` — multiplicative forgiveness on the baseline
//!   cost-ratio floor (pass iff `observed >= floor * ratio_slack`).
//!
//! A scenario metric that is `null` in the report (an empty run, a
//! ratio with a zero denominator) is **no data** and fails the check —
//! it must never read as a pass. Budgeted scenarios missing from the
//! report, and report scenarios missing from the ledger, are violations
//! too: the ledger and the matrix move together.
//!
//! The ceilings are per-scenario worst cases *across pipelines*
//! (`max`/`min` over the scenario's cells), so a single regressed
//! pipeline trips its scenario. `max_cost_per_hour` may be `null` (no
//! absolute ceiling yet — the scale-free `max_cost_overhead` still
//! applies); `budget update` fills it from the measured run.
//!
//! ## Re-baselining workflow
//!
//! ```text
//! inferline experiment robustness --quick          # writes results/robustness.json
//! inferline budget check                           # compare vs BUDGETS.json
//! inferline budget update                          # intentional re-baseline
//! ```
//!
//! `update` sets the report's mode section to the observed values
//! exactly (slack is applied at check time), preserving the other
//! mode's section and the section's slack settings; review the
//! `BUDGETS.json` diff like any other regression-test change.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{opt_f64_at, req_f64_at as req_f64, Json};

/// Format tag of `BUDGETS.json`; files with any other tag are rejected
/// wholesale (same policy as the estimator cache file).
pub const FORMAT: &str = "inferline-budgets-v1";

/// The baseline system whose cost ratio the ledger floors.
pub const PEAK_BASELINE: &str = "CG-Peak+AutoScale";

/// Slacks used when `budget update` creates a section from scratch.
pub const DEFAULT_MISS_SLACK: f64 = 0.02;
pub const DEFAULT_COST_SLACK: f64 = 1.25;
pub const DEFAULT_RATIO_SLACK: f64 = 0.8;

/// The budget of one scenario family (worst case across pipelines).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioBudget {
    /// Ceiling on the InferLine miss rate.
    pub max_miss_rate: f64,
    /// Ceiling on serving cost relative to the planned cost.
    pub max_cost_overhead: f64,
    /// Absolute ceiling on mean $/hr (`None` = not yet baselined).
    pub max_cost_per_hour: Option<f64>,
    /// Floor on the CG-Peak-to-InferLine cost ratio (the headline
    /// "InferLine is cheaper" claim; > 1 means cheaper).
    pub min_peak_cost_ratio: f64,
    /// Ceiling on the deadline-shed rate of chaos families (`None` =
    /// unbudgeted — fault-free families and pre-fault ledgers). Checked
    /// with `miss_slack` (both are absolute rates).
    pub max_shed_rate: Option<f64>,
}

/// One mode section (quick or full) of the ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct ModeBudgets {
    pub seed: u64,
    pub slo: f64,
    pub miss_slack: f64,
    pub cost_slack: f64,
    pub ratio_slack: f64,
    pub scenarios: BTreeMap<String, ScenarioBudget>,
}

/// The parsed `BUDGETS.json` document.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BudgetFile {
    pub quick: Option<ModeBudgets>,
    pub full: Option<ModeBudgets>,
}

/// Seeds live in JSON as f64: accept only exact non-negative integers
/// below 2^53 (the CLI enforces the same bound when producing reports),
/// so the per-seed budget pin can never compare silently mangled values.
fn seed_from(x: f64, what: &str) -> Result<u64, String> {
    if x < 0.0 || x.fract() != 0.0 || x >= 9_007_199_254_740_992.0 {
        return Err(format!("{what}: seed must be an integer in [0, 2^53), got {x}"));
    }
    Ok(x as u64)
}

impl ScenarioBudget {
    fn parse(node: &Json, path: &str) -> Result<ScenarioBudget, String> {
        let max_cost_per_hour = opt_f64_at(node, "max_cost_per_hour", path)?;
        let max_shed_rate = opt_f64_at(node, "max_shed_rate", path)?;
        Ok(ScenarioBudget {
            max_miss_rate: req_f64(node, "max_miss_rate", path)?,
            max_cost_overhead: req_f64(node, "max_cost_overhead", path)?,
            max_cost_per_hour,
            min_peak_cost_ratio: req_f64(node, "min_peak_cost_ratio", path)?,
            max_shed_rate,
        })
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("max_miss_rate", self.max_miss_rate)
            .set("max_cost_overhead", self.max_cost_overhead)
            .set(
                "max_cost_per_hour",
                self.max_cost_per_hour.map_or(Json::Null, Json::Num),
            )
            .set("min_peak_cost_ratio", self.min_peak_cost_ratio);
        // Emitted only when budgeted, so pre-fault ledgers round-trip.
        if let Some(s) = self.max_shed_rate {
            o.set("max_shed_rate", s);
        }
        o
    }
}

impl ModeBudgets {
    fn parse(node: &Json, path: &str) -> Result<ModeBudgets, String> {
        let scenarios_node = node
            .get("scenarios")
            .and_then(Json::as_obj)
            .ok_or_else(|| format!("{path}: missing object field \"scenarios\""))?;
        let mut scenarios = BTreeMap::new();
        for (name, v) in scenarios_node {
            let budget = ScenarioBudget::parse(v, &format!("{path}.scenarios.{name}"))?;
            scenarios.insert(name.clone(), budget);
        }
        Ok(ModeBudgets {
            seed: seed_from(req_f64(node, "seed", path)?, path)?,
            slo: req_f64(node, "slo", path)?,
            miss_slack: req_f64(node, "miss_slack", path)?,
            cost_slack: req_f64(node, "cost_slack", path)?,
            ratio_slack: req_f64(node, "ratio_slack", path)?,
            scenarios,
        })
    }

    fn to_json(&self) -> Json {
        let mut scenarios = Json::obj();
        for (name, b) in &self.scenarios {
            scenarios.set(name, b.to_json());
        }
        let mut o = Json::obj();
        o.set("seed", self.seed as usize)
            .set("slo", self.slo)
            .set("miss_slack", self.miss_slack)
            .set("cost_slack", self.cost_slack)
            .set("ratio_slack", self.ratio_slack)
            .set("scenarios", scenarios);
        o
    }
}

impl BudgetFile {
    /// Parse the document; any malformed node rejects the whole file
    /// (a half-read ledger must not gate CI).
    pub fn parse(doc: &Json) -> Result<BudgetFile, String> {
        let format = doc.get("format").and_then(Json::as_str).unwrap_or("<missing>");
        if format != FORMAT {
            return Err(format!("budget file format {format:?} (expected {FORMAT:?})"));
        }
        let mut file = BudgetFile::default();
        if let Some(q) = doc.get("quick") {
            file.quick = Some(ModeBudgets::parse(q, "quick")?);
        }
        if let Some(f) = doc.get("full") {
            file.full = Some(ModeBudgets::parse(f, "full")?);
        }
        Ok(file)
    }

    pub fn parse_str(text: &str) -> Result<BudgetFile, String> {
        Self::parse(&Json::parse(text)?)
    }

    pub fn load(path: &Path) -> Result<BudgetFile, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse_str(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("format", FORMAT);
        if let Some(q) = &self.quick {
            doc.set("quick", q.to_json());
        }
        if let Some(f) = &self.full {
            doc.set("full", f.to_json());
        }
        doc
    }

    /// Write the ledger pretty-printed: re-baselines must produce
    /// reviewable line-level diffs, not one rewritten 2 KB line.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty_string())
            .map_err(|e| format!("{}: {e}", path.display()))
    }
}

// ---------------------------------------------------------------------------
// Report summarization
// ---------------------------------------------------------------------------

/// Worst-case observations for one scenario across its pipeline cells.
/// `None` means no cell produced that metric — "no data", which the
/// checker treats as a failure, never a pass.
#[derive(Debug, Clone, Default)]
pub struct ScenarioObserved {
    /// Cells without usable data, as "pipeline: reason" strings.
    pub no_data: Vec<String>,
    pub worst_miss_rate: Option<f64>,
    pub worst_cost_overhead: Option<f64>,
    pub worst_cost_per_hour: Option<f64>,
    pub min_peak_cost_ratio: Option<f64>,
    /// Worst deadline-shed rate across cells; `None` when no cell
    /// carries the metric (fault-free reports) — only a violation when
    /// the ledger actually budgets `max_shed_rate`.
    pub worst_shed_rate: Option<f64>,
}

/// A parsed robustness report, reduced to what the ledger compares.
#[derive(Debug, Clone)]
pub struct ReportSummary {
    pub quick: bool,
    pub seed: u64,
    pub slo: f64,
    pub scenarios: BTreeMap<String, ScenarioObserved>,
}

fn fold_max(slot: &mut Option<f64>, x: f64) {
    *slot = Some(slot.map_or(x, |cur| cur.max(x)));
}

fn fold_min(slot: &mut Option<f64>, x: f64) {
    *slot = Some(slot.map_or(x, |cur| cur.min(x)));
}

/// Reduce a `robustness.json` document to per-scenario worst cases.
/// `null` metrics (NaN-safe serialization of empty windows or
/// zero-denominator ratios) surface in `no_data`, not in the folds.
pub fn summarize_report(report: &Json) -> Result<ReportSummary, String> {
    let format = report.get("format").and_then(Json::as_str).unwrap_or("<missing>");
    if format != crate::experiments::robustness::REPORT_FORMAT {
        return Err(format!(
            "unrecognized robustness report format {format:?} (expected {:?}; \
             re-run `inferline experiment robustness`)",
            crate::experiments::robustness::REPORT_FORMAT
        ));
    }
    let quick = report
        .get("quick")
        .and_then(Json::as_bool)
        .ok_or("report missing boolean field \"quick\"")?;
    let seed = seed_from(
        report
            .get("seed")
            .and_then(Json::as_f64)
            .ok_or("report missing numeric field \"seed\"")?,
        "report",
    )?;
    let slo = report
        .get("slo")
        .and_then(Json::as_f64)
        .ok_or("report missing numeric field \"slo\"")?;
    let cells = report
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or("report missing array field \"cells\"")?;
    let mut scenarios: BTreeMap<String, ScenarioObserved> = BTreeMap::new();
    for cell in cells {
        let scenario = cell
            .get("scenario")
            .and_then(Json::as_str)
            .ok_or("report cell missing \"scenario\"")?
            .to_string();
        let pipeline = cell.get("pipeline").and_then(Json::as_str).unwrap_or("?").to_string();
        let obs = scenarios.entry(scenario).or_default();
        if let Some(err) = cell.get("error").and_then(Json::as_str) {
            obs.no_data.push(format!("{pipeline}: {err}"));
            continue;
        }
        match cell.get("miss_rate").and_then(Json::as_f64) {
            Some(x) => fold_max(&mut obs.worst_miss_rate, x),
            None => obs.no_data.push(format!("{pipeline}: miss_rate has no data")),
        }
        match cell.get("cost_overhead").and_then(Json::as_f64) {
            Some(x) => fold_max(&mut obs.worst_cost_overhead, x),
            None => obs.no_data.push(format!("{pipeline}: cost_overhead has no data")),
        }
        match cell.get("mean_cost_per_hour").and_then(Json::as_f64) {
            Some(x) => fold_max(&mut obs.worst_cost_per_hour, x),
            None => obs.no_data.push(format!("{pipeline}: mean_cost_per_hour has no data")),
        }
        // shed_rate is optional per cell: fault-free cells report 0.0,
        // but a missing key (older minimal reports) is simply no fold —
        // the check only demands data when the ledger budgets it.
        if let Some(x) = cell.get("shed_rate").and_then(Json::as_f64) {
            fold_max(&mut obs.worst_shed_rate, x);
        }
        let peak_ratio = cell
            .get("baselines")
            .and_then(Json::as_arr)
            .and_then(|bs| {
                bs.iter().find(|b| {
                    b.get("system").and_then(Json::as_str) == Some(PEAK_BASELINE)
                })
            })
            .and_then(|b| b.get("cost_ratio"))
            .and_then(Json::as_f64);
        match peak_ratio {
            Some(x) => fold_min(&mut obs.min_peak_cost_ratio, x),
            None => obs
                .no_data
                .push(format!("{pipeline}: {PEAK_BASELINE} cost_ratio has no data")),
        }
    }
    Ok(ReportSummary { quick, seed, slo, scenarios })
}

// ---------------------------------------------------------------------------
// Check
// ---------------------------------------------------------------------------

/// One budget violation; `scenario` is `"<ledger>"` for file-level
/// mismatches (missing section, seed/slo drift).
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub scenario: String,
    pub what: String,
}

/// Outcome of a check: human-readable per-scenario lines plus the
/// violations (empty = within budget).
#[derive(Debug, Clone)]
pub struct CheckReport {
    /// Which ledger section was compared ("quick" or "full").
    pub mode: &'static str,
    pub lines: Vec<String>,
    pub violations: Vec<Violation>,
}

fn fmt_opt(x: Option<f64>) -> String {
    x.map_or_else(|| "no-data".to_string(), |v| format!("{v:.4}"))
}

/// Compare a robustness report against the ledger. `Err` is reserved
/// for unreadable inputs; a readable report that breaks its budgets
/// yields `Ok` with violations.
pub fn check(report: &Json, budgets: &BudgetFile) -> Result<CheckReport, String> {
    let summary = summarize_report(report)?;
    let mode = if summary.quick { "quick" } else { "full" };
    let section = if summary.quick { budgets.quick.as_ref() } else { budgets.full.as_ref() };
    let Some(mb) = section else {
        return Ok(CheckReport {
            mode,
            lines: Vec::new(),
            violations: vec![Violation {
                scenario: "<ledger>".to_string(),
                what: format!(
                    "BUDGETS.json has no {mode}-mode section; baseline it with \
                     `inferline budget update`"
                ),
            }],
        });
    };
    let mut violations = Vec::new();
    let mut lines = Vec::new();
    if summary.seed != mb.seed {
        violations.push(Violation {
            scenario: "<ledger>".to_string(),
            what: format!(
                "report seed {} != budget seed {} (budgets are per-seed; re-run the \
                 harness with --seed {} or re-baseline)",
                summary.seed, mb.seed, mb.seed
            ),
        });
    }
    if (summary.slo - mb.slo).abs() > 1e-12 {
        violations.push(Violation {
            scenario: "<ledger>".to_string(),
            what: format!("report slo {} != budget slo {}", summary.slo, mb.slo),
        });
    }
    // A seed/SLO mismatch makes every number incomparable: refuse the
    // comparison outright instead of emitting per-scenario "violations"
    // computed against a baseline the report was never measured at.
    if !violations.is_empty() {
        return Ok(CheckReport { mode, lines, violations });
    }
    for (name, budget) in &mb.scenarios {
        let Some(obs) = summary.scenarios.get(name) else {
            violations.push(Violation {
                scenario: name.clone(),
                what: "budgeted scenario absent from report".to_string(),
            });
            continue;
        };
        let before = violations.len();
        for entry in &obs.no_data {
            violations.push(Violation {
                scenario: name.clone(),
                what: format!("no data: {entry}"),
            });
        }
        let miss_limit = budget.max_miss_rate + mb.miss_slack;
        if let Some(x) = obs.worst_miss_rate {
            if x > miss_limit {
                violations.push(Violation {
                    scenario: name.clone(),
                    what: format!(
                        "miss rate {x:.4} exceeds budget {:.4} + slack {:.4}",
                        budget.max_miss_rate, mb.miss_slack
                    ),
                });
            }
        }
        let overhead_limit = budget.max_cost_overhead * mb.cost_slack;
        if let Some(x) = obs.worst_cost_overhead {
            if x > overhead_limit {
                violations.push(Violation {
                    scenario: name.clone(),
                    what: format!(
                        "cost overhead {x:.3} exceeds budget {:.3} x slack {:.2}",
                        budget.max_cost_overhead, mb.cost_slack
                    ),
                });
            }
        }
        if let (Some(ceiling), Some(x)) = (budget.max_cost_per_hour, obs.worst_cost_per_hour) {
            if x > ceiling * mb.cost_slack {
                violations.push(Violation {
                    scenario: name.clone(),
                    what: format!(
                        "mean cost ${x:.2}/hr exceeds budget ${ceiling:.2}/hr x slack {:.2}",
                        mb.cost_slack
                    ),
                });
            }
        }
        let ratio_limit = budget.min_peak_cost_ratio * mb.ratio_slack;
        if let Some(x) = obs.min_peak_cost_ratio {
            if x < ratio_limit {
                violations.push(Violation {
                    scenario: name.clone(),
                    what: format!(
                        "{PEAK_BASELINE} cost ratio {x:.3} below floor {:.3} x slack {:.2}",
                        budget.min_peak_cost_ratio, mb.ratio_slack
                    ),
                });
            }
        }
        if let Some(ceiling) = budget.max_shed_rate {
            match obs.worst_shed_rate {
                Some(x) if x > ceiling + mb.miss_slack => violations.push(Violation {
                    scenario: name.clone(),
                    what: format!(
                        "shed rate {x:.4} exceeds budget {ceiling:.4} + slack {:.4}",
                        mb.miss_slack
                    ),
                }),
                Some(_) => {}
                None => violations.push(Violation {
                    scenario: name.clone(),
                    what: "shed rate budgeted but report carries no shed_rate data"
                        .to_string(),
                }),
            }
        }
        let verdict = if violations.len() == before { "ok" } else { "FAIL" };
        lines.push(format!(
            "  {name:<22} miss {} (<= {miss_limit:.4})  overhead {} (<= {overhead_limit:.3})  \
             peak-ratio {} (>= {ratio_limit:.3})  {verdict}",
            fmt_opt(obs.worst_miss_rate),
            fmt_opt(obs.worst_cost_overhead),
            fmt_opt(obs.min_peak_cost_ratio),
        ));
    }
    for name in summary.scenarios.keys() {
        if !mb.scenarios.contains_key(name) {
            violations.push(Violation {
                scenario: name.clone(),
                what: "unbudgeted scenario (add it with `inferline budget update`)".to_string(),
            });
        }
    }
    Ok(CheckReport { mode, lines, violations })
}

// ---------------------------------------------------------------------------
// Update (re-baseline)
// ---------------------------------------------------------------------------

/// Re-baseline the report's mode section to the observed values (slack
/// is applied at check time, so the ledger records the measured run
/// exactly). Preserves the other mode's section and this section's
/// slack settings. Refuses to baseline from a report with no-data
/// cells — a ledger must never be seeded from a broken run.
pub fn update(report: &Json, budgets: &mut BudgetFile) -> Result<&'static str, String> {
    let summary = summarize_report(report)?;
    let mode = if summary.quick { "quick" } else { "full" };
    let slot = if summary.quick { &mut budgets.quick } else { &mut budgets.full };
    let (miss_slack, cost_slack, ratio_slack) = slot.as_ref().map_or(
        (DEFAULT_MISS_SLACK, DEFAULT_COST_SLACK, DEFAULT_RATIO_SLACK),
        |mb| (mb.miss_slack, mb.cost_slack, mb.ratio_slack),
    );
    let mut scenarios = BTreeMap::new();
    for (name, obs) in &summary.scenarios {
        if !obs.no_data.is_empty() {
            return Err(format!(
                "cannot baseline {name:?}: {}",
                obs.no_data.join("; ")
            ));
        }
        scenarios.insert(
            name.clone(),
            ScenarioBudget {
                max_miss_rate: obs.worst_miss_rate.unwrap_or(0.0),
                max_cost_overhead: obs.worst_cost_overhead.unwrap_or(1.0),
                max_cost_per_hour: obs.worst_cost_per_hour,
                min_peak_cost_ratio: obs.min_peak_cost_ratio.unwrap_or(0.0),
                max_shed_rate: obs.worst_shed_rate,
            },
        );
    }
    *slot = Some(ModeBudgets {
        seed: summary.seed,
        slo: summary.slo,
        miss_slack,
        cost_slack,
        ratio_slack,
        scenarios,
    });
    Ok(mode)
}

// ---------------------------------------------------------------------------
// CLI entry points
// ---------------------------------------------------------------------------

fn load_report(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        format!("{}: {e} (run `inferline experiment robustness` first)", path.display())
    })?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// CLI `budget check`: true iff the report is within budget.
pub fn run_check(report_path: &Path, budgets_path: &Path) -> bool {
    crate::util::bench::figure_header(
        "Budget check",
        "robustness report vs the checked-in per-scenario SLO budget ledger",
    );
    let report = match load_report(report_path) {
        Ok(r) => r,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    let budgets = match BudgetFile::load(budgets_path) {
        Ok(b) => b,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    let outcome = match check(&report, &budgets) {
        Ok(o) => o,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    for line in &outcome.lines {
        println!("{line}");
    }
    if outcome.violations.is_empty() {
        println!(
            "  budget check OK: {} scenarios within {}-mode budgets ({})",
            outcome.lines.len(),
            outcome.mode,
            budgets_path.display()
        );
        true
    } else {
        for v in &outcome.violations {
            crate::log_error!("  BUDGET VIOLATION [{}] {}", v.scenario, v.what);
        }
        crate::log_error!(
            "  budget check FAILED: {} violation(s) against {}-mode budgets ({})",
            outcome.violations.len(),
            outcome.mode,
            budgets_path.display()
        );
        false
    }
}

/// CLI `budget update`: re-baseline the ledger from a report and write
/// it back (creating the file if absent).
pub fn run_update(report_path: &Path, budgets_path: &Path) -> bool {
    let report = match load_report(report_path) {
        Ok(r) => r,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    let mut budgets = if budgets_path.exists() {
        match BudgetFile::load(budgets_path) {
            Ok(b) => b,
            Err(e) => {
                crate::log_error!("{e}");
                return false;
            }
        }
    } else {
        BudgetFile::default()
    };
    let mode = match update(&report, &mut budgets) {
        Ok(m) => m,
        Err(e) => {
            crate::log_error!("{e}");
            return false;
        }
    };
    match budgets.save(budgets_path) {
        Ok(()) => {
            let n = match mode {
                "quick" => budgets.quick.as_ref().map_or(0, |m| m.scenarios.len()),
                _ => budgets.full.as_ref().map_or(0, |m| m.scenarios.len()),
            };
            println!(
                "re-baselined {n} {mode}-mode scenario budgets from {} into {}",
                report_path.display(),
                budgets_path.display()
            );
            true
        }
        Err(e) => {
            crate::log_error!("{e}");
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal well-formed report: two scenarios, one pipeline each.
    fn report(miss: f64, overhead: f64, cost: f64, ratio: f64) -> Json {
        let mut doc = Json::obj();
        doc.set("format", crate::experiments::robustness::REPORT_FORMAT)
            .set("seed", 42usize)
            .set("slo", 0.35)
            .set("quick", true);
        let mut cells = Vec::new();
        for scenario in ["steady", "flash-crowd"] {
            let mut peak = Json::obj();
            peak.set("system", PEAK_BASELINE)
                .set("cost_ratio", Json::num_or_null(ratio))
                .set("miss_ratio", Json::Null);
            let mut cell = Json::obj();
            cell.set("scenario", scenario)
                .set("pipeline", "image-processing")
                .set("miss_rate", Json::num_or_null(miss))
                .set("cost_overhead", Json::num_or_null(overhead))
                .set("mean_cost_per_hour", Json::num_or_null(cost))
                .set("baselines", Json::Arr(vec![peak]));
            cells.push(cell);
        }
        doc.set("cells", Json::Arr(cells));
        doc
    }

    fn budgets_for(report: &Json) -> BudgetFile {
        let mut b = BudgetFile::default();
        update(report, &mut b).unwrap();
        b
    }

    #[test]
    fn update_then_check_passes() {
        let r = report(0.02, 1.3, 25.0, 2.5);
        let b = budgets_for(&r);
        let mb = b.quick.as_ref().unwrap();
        assert_eq!(mb.seed, 42);
        assert_eq!(mb.scenarios.len(), 2);
        assert_eq!(mb.scenarios["steady"].max_miss_rate, 0.02);
        assert_eq!(mb.scenarios["steady"].max_cost_per_hour, Some(25.0));
        assert!(b.full.is_none(), "update must not invent a full section");
        let outcome = check(&r, &b).unwrap();
        assert_eq!(outcome.mode, "quick");
        assert!(outcome.violations.is_empty(), "{:?}", outcome.violations);
        assert_eq!(outcome.lines.len(), 2);
    }

    #[test]
    fn each_budget_dimension_trips_and_names_the_scenario() {
        let base = report(0.02, 1.3, 25.0, 2.5);
        let b = budgets_for(&base);
        // (worse report, expected substring in the violation)
        let cases = [
            (report(0.2, 1.3, 25.0, 2.5), "miss rate"),
            (report(0.02, 2.5, 25.0, 2.5), "cost overhead"),
            (report(0.02, 1.3, 60.0, 2.5), "mean cost"),
            (report(0.02, 1.3, 25.0, 0.9), "cost ratio"),
        ];
        for (bad, needle) in cases {
            let outcome = check(&bad, &b).unwrap();
            assert!(!outcome.violations.is_empty(), "{needle}: should have tripped");
            for v in &outcome.violations {
                assert!(v.what.contains(needle), "{needle}: got {:?}", v.what);
                assert!(
                    v.scenario == "steady" || v.scenario == "flash-crowd",
                    "violation must name the scenario, got {:?}",
                    v.scenario
                );
            }
        }
        // Small drift within slack passes without re-baselining.
        let drift = report(0.03, 1.4, 28.0, 2.2);
        assert!(check(&drift, &b).unwrap().violations.is_empty());
    }

    #[test]
    fn null_metrics_are_no_data_not_a_pass() {
        let base = report(0.02, 1.3, 25.0, 2.5);
        let b = budgets_for(&base);
        // NaN serializes to null; the checker must flag it, not skip it.
        let nan_miss = report(f64::NAN, 1.3, 25.0, 2.5);
        let outcome = check(&nan_miss, &b).unwrap();
        assert!(
            outcome.violations.iter().any(|v| v.what.contains("no data")),
            "{:?}",
            outcome.violations
        );
        // And update refuses to baseline from such a run.
        let mut fresh = BudgetFile::default();
        assert!(update(&nan_miss, &mut fresh).is_err());
        // An errored cell is no data too.
        let mut errored = report(0.02, 1.3, 25.0, 2.5);
        if let Json::Obj(m) = &mut errored {
            let cells = m.get_mut("cells").unwrap();
            if let Json::Arr(v) = cells {
                let mut cell = Json::obj();
                cell.set("scenario", "steady")
                    .set("pipeline", "tf-cascade")
                    .set("error", "no feasible configuration");
                v.push(cell);
            }
        }
        let outcome = check(&errored, &b).unwrap();
        assert!(
            outcome
                .violations
                .iter()
                .any(|v| v.scenario == "steady" && v.what.contains("no feasible")),
            "{:?}",
            outcome.violations
        );
    }

    #[test]
    fn ledger_level_mismatches_trip() {
        let r = report(0.02, 1.3, 25.0, 2.5);
        let b = budgets_for(&r);
        // Seed drift.
        let mut other_seed = r.clone();
        other_seed.set("seed", 43usize);
        let outcome = check(&other_seed, &b).unwrap();
        assert!(outcome.violations.iter().any(|v| v.what.contains("seed")));
        // Missing mode section.
        let mut full_report = r.clone();
        full_report.set("quick", false);
        let outcome = check(&full_report, &b).unwrap();
        assert_eq!(outcome.mode, "full");
        assert!(outcome.violations.iter().any(|v| v.what.contains("no full-mode")));
        // Budgeted scenario absent from the report.
        let mut extra = b.clone();
        extra.quick.as_mut().unwrap().scenarios.insert(
            "diurnal".to_string(),
            ScenarioBudget {
                max_miss_rate: 0.1,
                max_cost_overhead: 2.0,
                max_cost_per_hour: None,
                min_peak_cost_ratio: 0.5,
                max_shed_rate: None,
            },
        );
        let outcome = check(&r, &extra).unwrap();
        assert!(outcome
            .violations
            .iter()
            .any(|v| v.scenario == "diurnal" && v.what.contains("absent")));
        // Report scenario missing from the ledger.
        let mut pruned = b.clone();
        pruned.quick.as_mut().unwrap().scenarios.remove("flash-crowd");
        let outcome = check(&r, &pruned).unwrap();
        assert!(outcome
            .violations
            .iter()
            .any(|v| v.scenario == "flash-crowd" && v.what.contains("unbudgeted")));
        // Unknown report format is unreadable, not a pass.
        let mut alien = r.clone();
        alien.set("format", "robustness-v99");
        assert!(check(&alien, &b).is_err());
    }

    #[test]
    fn shed_budget_trips_and_tolerates_pre_fault_ledgers() {
        // A chaos-style report: cells carry shed_rate.
        let shed_report = |shed: f64| {
            let mut r = report(0.02, 1.3, 25.0, 2.5);
            if let Json::Obj(m) = &mut r {
                if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                    for cell in cells {
                        cell.set("shed_rate", Json::num_or_null(shed));
                    }
                }
            }
            r
        };
        let base = shed_report(0.05);
        let b = budgets_for(&base);
        let mb = b.quick.as_ref().unwrap();
        assert_eq!(mb.scenarios["steady"].max_shed_rate, Some(0.05));
        assert!(check(&base, &b).unwrap().violations.is_empty());
        // Regressed shed rate trips the ceiling (+ miss_slack).
        let worse = shed_report(0.2);
        let outcome = check(&worse, &b).unwrap();
        assert!(
            outcome.violations.iter().any(|v| v.what.contains("shed rate")),
            "{:?}",
            outcome.violations
        );
        // Budgeted shed with a report that lost the metric = no data.
        let stripped = report(0.02, 1.3, 25.0, 2.5);
        let outcome = check(&stripped, &b).unwrap();
        assert!(
            outcome.violations.iter().any(|v| v.what.contains("shed_rate")),
            "{:?}",
            outcome.violations
        );
        // A pre-fault ledger (no max_shed_rate) ignores shed data, and
        // the budget round-trips with the key present.
        let pre_fault = budgets_for(&report(0.02, 1.3, 25.0, 2.5));
        assert!(check(&base, &pre_fault).unwrap().violations.is_empty());
        let text = b.to_json().to_string();
        assert!(text.contains("max_shed_rate"));
        assert_eq!(BudgetFile::parse_str(&text).unwrap(), b);
    }

    #[test]
    fn budget_file_roundtrips_canonically() {
        let r = report(0.02, 1.3, 25.0, 2.5);
        let mut b = budgets_for(&r);
        // A null absolute ceiling survives the roundtrip.
        b.quick.as_mut().unwrap().scenarios.get_mut("steady").unwrap().max_cost_per_hour =
            None;
        let text = b.to_json().to_string();
        let back = BudgetFile::parse_str(&text).unwrap();
        assert_eq!(back, b);
        assert_eq!(back.to_json().to_string(), text, "canonical bytes");
        // The pretty form (what `save` writes) parses back identically
        // and is genuinely line-oriented for reviewable diffs.
        let pretty = b.to_json().to_pretty_string();
        assert_eq!(BudgetFile::parse_str(&pretty).unwrap(), b);
        assert!(pretty.lines().count() > 10, "{pretty}");
        // Seeds must be exact non-negative integers.
        for bad_seed in ["42.5", "-1"] {
            let doc = format!(
                r#"{{"format": "inferline-budgets-v1",
                    "quick": {{"seed": {bad_seed}, "slo": 0.35, "miss_slack": 0.02,
                              "cost_slack": 1.25, "ratio_slack": 0.8,
                              "scenarios": {{}}}}}}"#
            );
            let err = BudgetFile::parse_str(&doc).unwrap_err();
            assert!(err.contains("seed"), "{err}");
        }
        // Wholesale rejection of malformed documents.
        for bad in [
            r#"{"quick": {}}"#,
            r#"{"format": "inferline-budgets-v0", "quick": {}}"#,
            r#"{"format": "inferline-budgets-v1", "quick": {"seed": 1}}"#,
        ] {
            assert!(BudgetFile::parse_str(bad).is_err(), "{bad}");
        }
        let err = BudgetFile::parse_str(
            r#"{"format": "inferline-budgets-v1",
                "quick": {"seed": 1, "slo": 0.35, "miss_slack": 0.02,
                          "cost_slack": 1.25, "ratio_slack": 0.8,
                          "scenarios": {"steady": {"max_miss_rate": 0.1}}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("quick.scenarios.steady"), "{err}");
    }
}
