//! Figure-regeneration drivers: one public function per paper figure.
//! Each prints the rows/series the paper plots and writes a CSV under
//! `results/`. Quick mode (used by `cargo bench` and tests) shrinks trace
//! durations; full mode (`inferline experiment figN`) uses paper-scale
//! parameters.

use crate::baselines::autoscale::AutoScaleTuner;
use crate::baselines::coarse::{self, CoarseTarget};
use crate::baselines::ds2::Ds2Controller;
use crate::baselines::oracle;
use crate::config::{pipelines, Framework, PipelineConfig, StageConfig};
use crate::hardware::Hardware;
use crate::planner::Planner;
use crate::profiler::analytic::paper_profiles;
use crate::simulator::{self, control::simulate_controlled, SimParams};
use crate::tuner::{Tuner, TunerInputs};
use crate::util::stats;
use crate::workload::{autoscale as asw, gamma_trace, varying_trace, Phase};

use crate::util::par::{default_workers, parallel_map_indexed};

use super::common::{
    print_summary, run_coarse, run_inferline, run_inferline_static, run_with_controller,
    shard_planner_threads, Ctx, RunSummary,
};

/// Fig 3: per-model profiles on the K80 tier — throughput and batch
/// latency vs batch size for preprocess (flat), ResNet152 analog and
/// TF-NMT analog (batching helps, latency grows).
pub fn fig3(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 3", "model profiles on K80 (thru & latency vs batch)");
    let profiles = paper_profiles();
    let mut rows = Vec::new();
    for model in ["preprocess", "resnet_lite", "nmt_lite"] {
        let p = profiles.get(model).get(Hardware::GpuK80).unwrap();
        for &b in &[1usize, 2, 4, 8, 16, 32] {
            if b > p.max_batch() {
                continue;
            }
            let row = format!("{model},{b},{:.2},{:.4}", p.throughput(b), p.latency(b));
            println!(
                "  {model:<14} batch {b:>2}: {:>7.2} qps  {:>7.1} ms/batch",
                p.throughput(b),
                p.latency(b) * 1e3
            );
            rows.push(row);
        }
    }
    ctx.write_csv("fig03.csv", "model,batch,throughput_qps,batch_latency_s", &rows);
}

/// Fig 5: Planner vs CG-Mean / CG-Peak across λ and CV at a 150 ms SLO on
/// two pipelines — cost ($/hr) and SLO miss rate.
pub fn fig5(ctx: &Ctx) {
    crate::util::bench::figure_header(
        "Fig 5",
        "InferLine Planner vs coarse-grained baselines (150ms SLO)",
    );
    let profiles = paper_profiles();
    let slo = 0.15;
    let lambdas: &[f64] = if ctx.quick { &[100.0, 200.0] } else { &[100.0, 200.0, 300.0, 400.0] };
    let cvs = [1.0, 4.0];
    // Each (pipeline, cv, λ) point is an independent plan+serve trace
    // analysis — shard them across cores, leftover cores to each planner.
    let mut scenarios = Vec::new();
    for spec in [pipelines::image_processing(), pipelines::video_monitoring()] {
        for &cv in &cvs {
            for (i, &lambda) in lambdas.iter().enumerate() {
                scenarios.push((spec.clone(), cv, lambda, 100 + i as u64));
            }
        }
    }
    let inner = shard_planner_threads(scenarios.len());
    let evaluated = parallel_map_indexed(scenarios.len(), default_workers(), |idx| {
        let (spec, cv, lambda, seed) = &scenarios[idx];
        let sample = gamma_trace(*lambda, *cv, ctx.secs(60.0), *seed);
        let live = gamma_trace(*lambda, *cv, ctx.secs(120.0), *seed + 50);
        let mut errors: Vec<String> = Vec::new();
        let mut summaries: Vec<RunSummary> = Vec::new();
        match run_inferline_static(spec, &profiles, &sample, &live, slo, "InferLine", inner) {
            Ok((_, s)) => summaries.push(s),
            Err(e) => errors.push(format!("  {} λ={lambda} cv={cv}: InferLine {e}", spec.name)),
        }
        summaries.push(run_coarse(
            spec, &profiles, &sample, &live, slo, CoarseTarget::Mean, false,
        ));
        // Paper: CG-Peak not evaluated for λ > 300 (cluster capacity).
        if *lambda <= 300.0 {
            summaries.push(run_coarse(
                spec, &profiles, &sample, &live, slo, CoarseTarget::Peak, false,
            ));
        }
        (errors, summaries)
    });
    let mut rows = Vec::new();
    for (idx, (errors, summaries)) in evaluated.into_iter().enumerate() {
        let (spec, cv, lambda, _) = &scenarios[idx];
        for e in &errors {
            println!("{e}");
        }
        println!("  {} λ={lambda} cv={cv}:", spec.name);
        for s in &summaries {
            print_summary("    ", s);
            rows.push(format!(
                "{},{lambda},{cv},{},{:.3},{:.5}",
                spec.name, s.system, s.mean_cost_per_hour, s.miss_rate
            ));
        }
    }
    ctx.write_csv("fig05.csv", "pipeline,lambda,cv,system,cost_per_hour,miss_rate", &rows);
}

/// Fig 6: high-frequency tuning on the two AutoScale-derived real traces
/// (Social Media pipeline, 150 ms SLO): attainment and total cost,
/// InferLine (Planner+Tuner) vs CG (plan+AutoScale tuning).
pub fn fig6(ctx: &Ctx) {
    crate::util::bench::figure_header(
        "Fig 6",
        "tuning on real-derived traces (Social Media, 150ms SLO)",
    );
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let slo = 0.15;
    let max_qps = if ctx.quick { 120.0 } else { 300.0 };
    let mut rows = Vec::new();
    for (name, minutes) in [
        ("big_spike", asw::big_spike_minutes()),
        ("instant_spike", asw::instant_spike_minutes()),
    ] {
        let minutes = if ctx.quick { minutes[..15].to_vec() } else { minutes };
        let full = asw::synthesize(&minutes, max_qps, 61);
        // Paper: first 25% for planning, remaining 75% live.
        let (sample, live) = full.split_at_fraction(0.25);
        println!("  trace {name}: sample {} qs, live {} qs", sample.len(), live.len());
        let mut summaries = Vec::new();
        match run_inferline(&spec, &profiles, &sample, &live, slo, default_workers()) {
            Ok((plan, s)) => {
                println!("    plan: {}", plan.config.summary(&spec));
                summaries.push(s);
            }
            Err(e) => println!("    InferLine: {e}"),
        }
        // The deployable CG baseline provisions for the sample peak and
        // is re-scaled at runtime by the AutoScale mechanism of [12].
        summaries.push(run_coarse(&spec, &profiles, &sample, &live, slo, CoarseTarget::Peak, true));
        for s in &summaries {
            print_summary("    ", s);
            rows.push(format!(
                "{name},{},{:.4},{:.2},{:.5}",
                s.system, s.attainment, s.total_cost, s.miss_rate
            ));
        }
        if summaries.len() == 2 {
            let (il, cg) = (&summaries[0], &summaries[1]);
            if il.miss_rate > 0.0 {
                println!(
                    "    miss-rate ratio CG/IL = {:.1}x, cost ratio CG/IL = {:.1}x",
                    cg.miss_rate / il.miss_rate,
                    cg.total_cost / il.total_cost
                );
            }
        }
    }
    ctx.write_csv("fig06.csv", "trace,system,attainment,total_cost,miss_rate", &rows);
}

/// Fig 7: tuning under synthetically increasing arrival rates.
pub fn fig7(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 7", "tuning under increasing arrival rates");
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let slo = 0.15;
    let sample = gamma_trace(100.0, 1.0, ctx.secs(60.0), 71);
    let live = varying_trace(
        &[
            Phase { lambda: 100.0, cv: 1.0, duration: ctx.secs(60.0), ramp: false },
            Phase { lambda: 250.0, cv: 1.0, duration: ctx.secs(120.0), ramp: true },
            Phase { lambda: 250.0, cv: 1.0, duration: ctx.secs(120.0), ramp: false },
        ],
        73,
    );
    let mut rows = Vec::new();
    let mut series_rows = Vec::new();
    let mut summaries = Vec::new();
    if let Ok((_, s)) = run_inferline(&spec, &profiles, &sample, &live, slo, default_workers()) {
        summaries.push(s);
    }
    summaries.push(run_coarse(&spec, &profiles, &sample, &live, slo, CoarseTarget::Mean, true));
    summaries.push(run_coarse(&spec, &profiles, &sample, &live, slo, CoarseTarget::Peak, true));
    for s in &summaries {
        print_summary("  ", s);
        rows.push(format!("{},{:.3},{:.5}", s.system, s.mean_cost_per_hour, s.miss_rate));
        for (t, miss) in s.result.miss_rate_series(slo, 10.0) {
            // NaN = window with no completions: no data, skip the point.
            if miss.is_nan() {
                continue;
            }
            series_rows.push(format!("{},{t:.0},{miss:.4}", s.system));
        }
    }
    ctx.write_csv("fig07.csv", "system,cost_per_hour,miss_rate", &rows);
    ctx.write_csv("fig07_series.csv", "system,t,miss_rate", &series_rows);
}

/// Fig 8: Estimator fidelity — estimated vs measured (physical plane)
/// P99 latency at λ=150, CV=4 across the four pipelines.
pub fn fig8(ctx: &Ctx) {
    crate::util::bench::figure_header(
        "Fig 8",
        "estimated vs physically-measured P99 (λ=150, CV=4)",
    );
    let profiles = paper_profiles();
    let slo = 0.3;
    let lambda = if ctx.quick { 80.0 } else { 150.0 };
    // Phase 1 (parallel): planning and the Estimator side are pure CPU
    // simulation, so the four pipelines shard across cores.
    let specs = pipelines::all();
    let inner = shard_planner_threads(specs.len());
    let planned = parallel_map_indexed(specs.len(), default_workers(), |idx| {
        let spec = &specs[idx];
        let sample = gamma_trace(lambda, 4.0, ctx.secs(60.0), 81);
        let live = gamma_trace(lambda, 4.0, ctx.secs(30.0), 83);
        let plan = match Planner::new(spec, &profiles).with_threads(inner).plan(&sample, slo) {
            Ok(p) => p,
            Err(e) => return Err(format!("  {}: {e}", spec.name)),
        };
        // Estimator side.
        let est =
            simulator::estimate_p99(spec, &profiles, &plan.config, &live, &SimParams::default());
        Ok((plan, live, est))
    });
    // Phase 2 (serial, deliberately): the physical side measures
    // wall-clock latencies on real threads — running the engines
    // concurrently (or against other scenarios' planner threads) would
    // inflate the measured P99 with scheduler contention, the very number
    // this figure validates the Estimator against.
    let mut rows = Vec::new();
    for (idx, outcome) in planned.into_iter().enumerate() {
        let spec = &specs[idx];
        match outcome {
            Ok((plan, live, est)) => {
                // Same config served on the threaded engine with per-stage
                // calibrated backends (profile-faithful service times).
                let backends: Vec<crate::serving::Backend> = spec
                    .stages
                    .iter()
                    .zip(&plan.config.stages)
                    .map(|(s, c)| crate::serving::Backend::Calibrated {
                        profile: profiles.get(&s.model).get(c.hw).unwrap().clone(),
                    })
                    .collect();
                let engine =
                    crate::serving::ServingEngine::start(spec, &plan.config, backends).unwrap();
                let measured = engine.serve_trace(&live, 1.0, SimParams::default().routing_seed);
                let measured_p99 = stats::p99(&measured.latencies);
                println!(
                    "  {:<18} estimated P99 {:>6.1} ms | measured P99 {:>6.1} ms | SLO {:>5.0} ms",
                    spec.name,
                    est * 1e3,
                    measured_p99 * 1e3,
                    slo * 1e3
                );
                rows.push(format!("{},{est:.4},{measured_p99:.4},{slo}", spec.name));
            }
            Err(line) => println!("{line}"),
        }
    }
    ctx.write_csv("fig08.csv", "pipeline,estimated_p99,measured_p99,slo", &rows);
}

/// Fig 9: Planner sensitivity — configuration cost across SLOs, CVs and
/// arrival rates (Social Media pipeline).
pub fn fig9(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 9", "planner sensitivity (Social Media)");
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let slos: &[f64] = if ctx.quick {
        &[0.15, 0.3, 0.5]
    } else {
        &[0.1, 0.15, 0.2, 0.25, 0.3, 0.35, 0.4, 0.5]
    };
    let lambdas: &[f64] = if ctx.quick { &[100.0] } else { &[100.0, 200.0, 300.0] };
    // Shard the (λ, CV) scenarios; within one scenario a single planner
    // walks the SLO ladder so its cross-SLO estimator cache (exact P99
    // entries answer feasibility at every SLO) is reused end to end.
    let mut scenarios = Vec::new();
    for &lambda in lambdas {
        for &cv in &[1.0, 4.0] {
            scenarios.push((lambda, cv));
        }
    }
    let inner = shard_planner_threads(scenarios.len());
    let evaluated = parallel_map_indexed(scenarios.len(), default_workers(), |idx| {
        let (lambda, cv) = scenarios[idx];
        let sample = gamma_trace(lambda, cv, ctx.secs(60.0), 91);
        let planner = Planner::new(&spec, &profiles).with_threads(inner);
        let mut line = format!("  λ={lambda:>3} cv={cv}: ");
        let mut rows = Vec::new();
        for &slo in slos {
            match planner.plan(&sample, slo) {
                Ok(plan) => {
                    line.push_str(&format!("slo={slo}: ${:.2}  ", plan.cost_per_hour));
                    rows.push(format!("{lambda},{cv},{slo},{:.3}", plan.cost_per_hour));
                }
                Err(_) => {
                    line.push_str(&format!("slo={slo}: infeasible  "));
                    rows.push(format!("{lambda},{cv},{slo},"));
                }
            }
        }
        (line, rows)
    });
    let mut rows = Vec::new();
    for (line, scenario_rows) in evaluated {
        println!("{line}");
        rows.extend(scenario_rows);
    }
    ctx.write_csv("fig09.csv", "lambda,cv,slo,cost_per_hour", &rows);
}

/// Fig 10: sensitivity to arrival-rate changes (150→250 QPS over τ):
/// Tuner vs oracle Planner vs sample-only Planner.
pub fn fig10(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 10", "arrival rate change 150→250 (Social Media)");
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let slo = 0.3;
    let taus: &[f64] = if ctx.quick { &[30.0] } else { &[30.0, 60.0, 120.0] };
    let mut rows = Vec::new();
    for &tau in taus {
        let sample = gamma_trace(150.0, 1.0, ctx.secs(60.0), 101);
        let live = varying_trace(
            &[
                Phase { lambda: 150.0, cv: 1.0, duration: ctx.secs(60.0), ramp: false },
                Phase { lambda: 250.0, cv: 1.0, duration: tau, ramp: true },
                Phase { lambda: 250.0, cv: 1.0, duration: ctx.secs(90.0), ramp: false },
                Phase { lambda: 150.0, cv: 1.0, duration: ctx.secs(60.0), ramp: false },
            ],
            103,
        );
        println!("  τ = {tau}s:");
        let mut summaries = Vec::new();
        if let Ok((_, s)) = run_inferline(&spec, &profiles, &sample, &live, slo, default_workers())
        {
            summaries.push(s);
        }
        // Oracle planner: full live-trace knowledge, no tuner.
        if let Ok(config) = oracle::oracle_config(&spec, &profiles, &live, slo) {
            let mut null = crate::simulator::control::NullController;
            summaries.push(run_with_controller(
                &spec, &profiles, &config, &live, slo, "Planner(oracle)", &mut null,
            ));
        }
        if let Ok((_, s)) = run_inferline_static(
            &spec,
            &profiles,
            &sample,
            &live,
            slo,
            "Planner(sample)",
            default_workers(),
        ) {
            summaries.push(s);
        }
        for s in &summaries {
            print_summary("    ", s);
            rows.push(format!(
                "{tau},{},{:.3},{:.5}",
                s.system, s.mean_cost_per_hour, s.miss_rate
            ));
        }
    }
    ctx.write_csv("fig10.csv", "tau,system,cost_per_hour,miss_rate", &rows);
}

/// Fig 11: sensitivity to burstiness changes (CV 1→4 at fixed λ).
pub fn fig11(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 11", "burstiness change CV 1→4 (Social Media)");
    let profiles = paper_profiles();
    let spec = pipelines::social_media();
    let slo = 0.3;
    let lambda = 150.0;
    let sample = gamma_trace(lambda, 1.0, ctx.secs(60.0), 111);
    let live = varying_trace(
        &[
            Phase { lambda, cv: 1.0, duration: ctx.secs(90.0), ramp: false },
            Phase { lambda, cv: 4.0, duration: ctx.secs(180.0), ramp: false },
        ],
        113,
    );
    let mut rows = Vec::new();
    let mut summaries = Vec::new();
    if let Ok((_, s)) = run_inferline(&spec, &profiles, &sample, &live, slo, default_workers()) {
        summaries.push(s);
    }
    if let Ok((_, s)) = run_inferline_static(
        &spec,
        &profiles,
        &sample,
        &live,
        slo,
        "Planner(sample)",
        default_workers(),
    ) {
        summaries.push(s);
    }
    for s in &summaries {
        print_summary("  ", s);
        rows.push(format!("{},{:.3},{:.5}", s.system, s.mean_cost_per_hour, s.miss_rate));
        for (t, miss) in s.result.miss_rate_series(slo, 15.0) {
            // NaN = window with no completions: no data, skip the point.
            if miss.is_nan() {
                continue;
            }
            rows.push(format!("# series,{},{t:.0},{miss:.4}", s.system));
        }
    }
    ctx.write_csv("fig11.csv", "system,cost_per_hour,miss_rate", &rows);
}

/// Fig 12: attribution of benefit — {Baseline Plan, InferLine Plan,
/// IL Plan + Baseline Tune, IL Plan + IL Tune} on Image Processing.
pub fn fig12(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 12", "attribution of benefit (Image Processing)");
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let slo = 0.15;
    let sample = gamma_trace(100.0, 1.0, ctx.secs(60.0), 121);
    let live = varying_trace(
        &[
            Phase { lambda: 100.0, cv: 1.0, duration: ctx.secs(60.0), ramp: false },
            Phase { lambda: 200.0, cv: 1.0, duration: ctx.secs(60.0), ramp: true },
            Phase { lambda: 200.0, cv: 1.0, duration: ctx.secs(120.0), ramp: false },
        ],
        123,
    );
    let mut rows = Vec::new();
    let mut summaries = Vec::new();
    // 1. Baseline plan, no tuning.
    summaries.push(run_coarse(&spec, &profiles, &sample, &live, slo, CoarseTarget::Mean, false));
    // 2-4 share the InferLine plan.
    if let Ok(plan) = Planner::new(&spec, &profiles).plan(&sample, slo) {
        let mut null = crate::simulator::control::NullController;
        summaries.push(run_with_controller(
            &spec, &profiles, &plan.config, &live, slo, "InferLine Plan", &mut null,
        ));
        // 3. IL plan + baseline (AutoScale, proportional) tuning.
        let base: Vec<usize> = plan.config.stages.iter().map(|s| s.replicas).collect();
        let mut cg_tune = AutoScaleTuner::proportional(base, sample.mean_rate());
        summaries.push(run_with_controller(
            &spec, &profiles, &plan.config, &live, slo, "IL Plan + Baseline Tune", &mut cg_tune,
        ));
        // 4. IL plan + IL tuner.
        let st = simulator::service_time(&spec, &profiles, &plan.config);
        let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
        let mut tuner = Tuner::new(inputs);
        summaries.push(run_with_controller(
            &spec, &profiles, &plan.config, &live, slo, "IL Plan + IL Tune", &mut tuner,
        ));
    }
    for s in &summaries {
        print_summary("  ", s);
        rows.push(format!("{},{:.3},{:.5}", s.system, s.mean_cost_per_hour, s.miss_rate));
    }
    ctx.write_csv("fig12.csv", "system,cost_per_hour,miss_rate", &rows);
}

/// Fig 13: the Planner generalizes across serving frameworks — TF Cascade
/// on Clipper vs TensorFlow Serving (differing RPC overheads).
pub fn fig13(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 13", "Clipper vs TensorFlow-Serving (TF Cascade)");
    let profiles = paper_profiles();
    let slo = 0.15;
    let mut rows = Vec::new();
    for fw in [Framework::Clipper, Framework::TfServing] {
        let mut spec = pipelines::tf_cascade();
        spec.framework = fw;
        // High enough load that the frameworks' RPC-overhead difference
        // surfaces as a (small) cost difference, as the paper observes.
        let sample = gamma_trace(250.0, 1.0, ctx.secs(60.0), 131);
        let live = gamma_trace(250.0, 1.0, ctx.secs(120.0), 133);
        match run_inferline_static(
            &spec,
            &profiles,
            &sample,
            &live,
            slo,
            fw.id(),
            default_workers(),
        ) {
            Ok((plan, s)) => {
                println!("    plan: {}", plan.config.summary(&spec));
                print_summary("  ", &s);
                rows.push(format!(
                    "{},{:.3},{:.5},{:.4}",
                    fw.id(),
                    s.mean_cost_per_hour,
                    s.miss_rate,
                    s.attainment
                ));
            }
            Err(e) => println!("  {}: {e}", fw.id()),
        }
    }
    ctx.write_csv("fig13.csv", "framework,cost_per_hour,miss_rate,attainment", &rows);
}

/// Fig 14: DS2 under (a) increasing burstiness and (b) a rate ramp —
/// average-rate provisioning + halt-to-rescale miss SLOs.
pub fn fig14(ctx: &Ctx) {
    crate::util::bench::figure_header("Fig 14", "DS2 on bursty and non-stationary workloads");
    let profiles = paper_profiles();
    let spec = pipelines::image_processing();
    let slo = 0.3;
    // DS2 deployment: batch-less, best hardware, provisioned for 50 qps.
    let service_times: Vec<f64> = spec
        .stages
        .iter()
        .map(|s| {
            let mp = profiles.get(&s.model);
            mp.get(mp.best_hardware()).unwrap().latency(1)
        })
        .collect();
    let make_config = |rate: f64| PipelineConfig {
        stages: spec
            .stages
            .iter()
            .zip(&service_times)
            .map(|(s, &st)| StageConfig {
                hw: profiles.get(&s.model).best_hardware(),
                batch: 1,
                replicas: ((rate * s.scale_factor * st) / 0.9).ceil().max(1.0) as usize,
            })
            .collect(),
    };
    let mut rows = Vec::new();
    // (a) burstiness sweep at fixed λ=50: three independent DS2 baseline
    // trace analyses, sharded across cores.
    let panel_cvs = [1.0, 2.0, 4.0];
    let panel_a = parallel_map_indexed(panel_cvs.len(), default_workers(), |i| {
        let cv = panel_cvs[i];
        let live = gamma_trace(50.0, cv, ctx.secs(180.0), 141);
        let mut ds2 = Ds2Controller::new(&spec, &service_times);
        let result = simulate_controlled(
            &spec, &profiles, &make_config(50.0), &live, &SimParams::default(), &mut ds2,
        );
        RunSummary::from_result(&format!("DS2 cv={cv}"), result, slo)
    });
    for (cv, s) in panel_cvs.iter().zip(&panel_a) {
        print_summary("  (a) ", s);
        rows.push(format!("a,{cv},50,{:.5},{:.4}", s.miss_rate, s.p99));
    }
    // (b) rate ramp 50 → 100 over 60 s: P99-over-time for DS2 vs the
    // InferLine Tuner on the same workload.
    let live = varying_trace(
        &[
            Phase { lambda: 50.0, cv: 1.0, duration: ctx.secs(60.0), ramp: false },
            Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: true },
            Phase { lambda: 100.0, cv: 1.0, duration: ctx.secs(240.0), ramp: false },
        ],
        143,
    );
    let mut ds2 = Ds2Controller::new(&spec, &service_times);
    let ds2_result = simulate_controlled(
        &spec, &profiles, &make_config(50.0), &live, &SimParams::default(), &mut ds2,
    );
    let ds2_s = RunSummary::from_result("DS2 ramp", ds2_result, slo);
    print_summary("  (b) ", &ds2_s);
    rows.push(format!("b,1,50-100,{:.5},{:.4}", ds2_s.miss_rate, ds2_s.p99));
    let sample = gamma_trace(50.0, 1.0, ctx.secs(60.0), 145);
    if let Ok((_, il_s)) = run_inferline(&spec, &profiles, &sample, &live, slo, default_workers())
    {
        print_summary("  (b) ", &il_s);
        rows.push(format!("b-il,1,50-100,{:.5},{:.4}", il_s.miss_rate, il_s.p99));
    }
    // P99-over-time series for the plot (NaN windows carry no data).
    let mut series = Vec::new();
    for (t, miss) in ds2_s.result.miss_rate_series(slo, 15.0) {
        if miss.is_nan() {
            continue;
        }
        series.push(format!("DS2,{t:.0},{miss:.4}"));
    }
    ctx.write_csv("fig14.csv", "panel,cv,lambda,miss_rate,p99", &rows);
    ctx.write_csv("fig14_series.csv", "system,t,miss_rate", &series);
}

/// §7.1 headline numbers: max cost ratio (→ paper's "up to 7.6×") and
/// miss-rate ratio (→ "34.5× lower SLO miss rate").
pub fn headline(ctx: &Ctx) {
    crate::util::bench::figure_header("Headline", "cost and miss-rate ratios vs baselines");
    let profiles = paper_profiles();
    let slo = 0.15;
    let mut worst_cost_ratio: f64 = 0.0;
    for spec in [pipelines::image_processing(), pipelines::video_monitoring(), pipelines::social_media()] {
        for &(lambda, cv) in &[(150.0, 1.0), (150.0, 4.0), (250.0, 4.0)] {
            let sample = gamma_trace(lambda, cv, ctx.secs(60.0), 151);
            let il = match Planner::new(&spec, &profiles).plan(&sample, slo) {
                Ok(p) => p,
                Err(_) => continue,
            };
            let cg = coarse::plan(&spec, &profiles, &sample, slo, CoarseTarget::Peak);
            let ratio = cg.cost_per_hour / il.cost_per_hour;
            println!(
                "  {:<18} λ={lambda:>3} cv={cv}: IL ${:>6.2}/hr vs CG-Peak ${:>7.2}/hr → {ratio:>4.1}x",
                spec.name, il.cost_per_hour, cg.cost_per_hour
            );
            worst_cost_ratio = worst_cost_ratio.max(ratio);
        }
    }
    println!("  max cost ratio CG-Peak/InferLine: {worst_cost_ratio:.1}x (paper: up to 7.6x)");
    ctx.write_csv(
        "headline.csv",
        "metric,value",
        &[format!("max_cost_ratio,{worst_cost_ratio:.2}")],
    );
}

/// Registry for the CLI and benches.
pub fn run_by_name(name: &str, quick: bool) -> bool {
    let ctx = Ctx::new(quick);
    match name {
        "fig3" => fig3(&ctx),
        "fig5" => fig5(&ctx),
        "fig6" => fig6(&ctx),
        "fig7" => fig7(&ctx),
        "fig8" => fig8(&ctx),
        "fig9" => fig9(&ctx),
        "fig10" => fig10(&ctx),
        "fig11" => fig11(&ctx),
        "fig12" => fig12(&ctx),
        "fig13" => fig13(&ctx),
        "fig14" => fig14(&ctx),
        "headline" => headline(&ctx),
        "all" => {
            for f in ALL_FIGURES {
                run_by_name(f, quick);
            }
        }
        _ => return false,
    }
    true
}

/// Every figure id, in paper order. The scenario sweep and the
/// closed-loop robustness harness are dispatched directly by the CLI
/// (`experiment sweep` / `experiment robustness`, one dispatch site
/// each) because they take flags this registry doesn't thread (the
/// estimator-cache persistence path, the robustness seed) — see
/// `experiments::sweep::run_sweep` and `experiments::robustness::run`.
pub const ALL_FIGURES: &[&str] = &[
    "fig3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
    "headline",
];
