//! Synthetic tenant-population generator for fleet experiments.
//!
//! Real fleets are populations, not hand-written lists: many tenants
//! running a few pipeline archetypes at a few traffic classes. The
//! generator draws tenant i's (pipeline, traffic class, SLO, live
//! scenario family) from [`child_seed`]`(seed, TENANT_TAG + i)`, so a
//! population is fully determined by `(n, seed)` — the same pair always
//! yields the same fleet, bit for bit, and growing `n` only appends.
//!
//! Planning samples are shared per traffic class (one Gamma trace per
//! λ), which both mirrors how capacity classes are provisioned in
//! practice and lets [`super::FleetPlanner`] collapse the population to
//! at most `pipelines × λ-classes × SLO-classes` distinct planning
//! problems.

use crate::config::pipelines;
use crate::workload::scenarios::child_seed;
use crate::workload::{gamma_trace, Trace};

use super::Tenant;

/// Traffic classes (mean arrival rate, queries/s).
pub const LAMBDAS: [f64; 4] = [60.0, 100.0, 150.0, 220.0];

/// SLO classes (end-to-end P99, seconds).
pub const SLOS: [f64; 3] = [0.25, 0.35, 0.5];

/// Fault-free live scenario families a tenant's served traffic is drawn
/// from (names resolve via the robustness matrix at the experiment
/// layer; the generator only tags tenants).
pub const LIVE_FAMILIES: [&str; 6] =
    ["steady", "bursty-mmpp", "diurnal", "flash-crowd", "heavy-tail-pareto", "cv-shift"];

/// Seed-stream tags (disjoint from the robustness harness's 7/100+/200+
/// streams by construction — `child_seed` mixes the tag into the seed).
const TENANT_TAG: u64 = 1_000;
const SAMPLE_TAG: u64 = 900;

/// A generated tenant plus the draw metadata experiments report on.
#[derive(Debug, Clone)]
pub struct SynthTenant {
    pub tenant: Tenant,
    /// Traffic-class mean rate the tenant was provisioned for.
    pub lambda: f64,
    /// Live scenario family tag (member of [`LIVE_FAMILIES`]).
    pub family: &'static str,
}

/// Generate `n` tenants from `seed`. `sample_secs` is the planning
/// sample duration (quick runs use a short sample, exactly like the
/// robustness harness).
pub fn synth_tenants(n: usize, seed: u64, sample_secs: f64) -> Vec<SynthTenant> {
    let specs = pipelines::all();
    let samples: Vec<Trace> = LAMBDAS
        .iter()
        .enumerate()
        .map(|(i, &lambda)| {
            gamma_trace(lambda, 1.0, sample_secs, child_seed(seed, SAMPLE_TAG + i as u64))
        })
        .collect();
    (0..n)
        .map(|i| {
            let h = child_seed(seed, TENANT_TAG + i as u64);
            let spec = &specs[(h % specs.len() as u64) as usize];
            let lam_idx = ((h >> 16) % LAMBDAS.len() as u64) as usize;
            let slo = SLOS[((h >> 32) % SLOS.len() as u64) as usize];
            let family = LIVE_FAMILIES[((h >> 48) % LIVE_FAMILIES.len() as u64) as usize];
            SynthTenant {
                tenant: Tenant {
                    name: format!("t{i:04}-{}", spec.name),
                    spec: spec.clone(),
                    slo,
                    sample: samples[lam_idx].clone(),
                },
                lambda: LAMBDAS[lam_idx],
                family,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_is_deterministic_and_prefix_stable() {
        let a = synth_tenants(20, 42, 10.0);
        let b = synth_tenants(20, 42, 10.0);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tenant.name, y.tenant.name);
            assert_eq!(x.tenant.slo, y.tenant.slo);
            assert_eq!(x.tenant.sample, y.tenant.sample);
            assert_eq!(x.family, y.family);
        }
        // Growing n appends: the first 20 of 40 are the same tenants.
        let big = synth_tenants(40, 42, 10.0);
        for (x, y) in a.iter().zip(&big) {
            assert_eq!(x.tenant.name, y.tenant.name);
        }
    }

    #[test]
    fn classes_are_all_represented_at_scale() {
        let pop = synth_tenants(200, 7, 10.0);
        for &lambda in &LAMBDAS {
            assert!(pop.iter().any(|t| t.lambda == lambda), "no tenant in class λ={lambda}");
        }
        for &slo in &SLOS {
            assert!(pop.iter().any(|t| t.tenant.slo == slo), "no tenant with SLO {slo}");
        }
        for family in LIVE_FAMILIES {
            assert!(pop.iter().any(|t| t.family == family), "no tenant in family {family}");
        }
    }
}
