//! Fleet-scale multi-pipeline planning over a finite accelerator
//! inventory (ROADMAP open item 1).
//!
//! Everything below this module plans *one* pipeline against an
//! implicitly unbounded device pool; production means many tenant
//! pipelines competing for the same accelerators. A [`FleetSpec`] names
//! N tenants — each a pipeline, an SLO and a planning sample trace —
//! plus one shared [`Inventory`] of per-tier device counts, and
//! [`FleetPlanner::plan`] provisions them jointly in three deterministic
//! phases:
//!
//! 1. **Per-tenant planning under inventory tiers.** Each tenant runs
//!    the ordinary [`Planner`] (Algorithms 1+2) restricted to the tiers
//!    the inventory offers, in tenant order, sharing one
//!    [`EstimatorCache`]. Identical (pipeline, sample, SLO) tenants are
//!    memoized — planning is deterministic, so the memo returns exactly
//!    the plan a fresh search would.
//! 2. **Greedy bin-pack + local repair.** Per-tier device demand is the
//!    sum of every tenant's replicas on that tier. While some finite
//!    tier is oversubscribed, the *binding* tier (largest overflow, ties
//!    toward the cheaper tier) sheds its heaviest tenant (ties toward
//!    the lower tenant index): that tenant is re-planned with the tier
//!    excluded from its search ([`Inventory`] count 0). Each repair adds
//!    one (tenant, tier) exclusion, so the loop terminates after at most
//!    `tenants × tiers` re-plans; if the shed tenant cannot be planned
//!    on the remaining tiers, the fleet is infeasible and
//!    [`FleetError::Infeasible`] names the binding tier with its demand
//!    and capacity.
//! 3. **Prefix-stage deduplication.** Tenants whose pipelines *start*
//!    with the same model chain (scale factor exactly 1 along the
//!    chain — every query visits, so arrival rates add) and whose plans
//!    agree on (hardware, batch) for a chain position are served by one
//!    merged stage, as in Loki-style shared-pipeline serving. The merge
//!    is utilization-preserving: with per-tenant utilization
//!    `u_t = λ_t / (r_t · thpt(hw, batch))`, the merged stage keeps the
//!    *worst* tenant's utilization `u = max_t u_t` and provisions
//!    `max(max_t r_t, ⌈Σ_t λ_t / (thpt · u)⌉)` replicas — provably
//!    never more than `Σ_t r_t` (each tenant's traffic fits in its own
//!    share at utilization `u`) and never fewer than any single
//!    tenant's count, so savings are non-negative and a merged stage is
//!    no more loaded than the worst unmerged one was. The capacity
//!    check of phase 2 runs on *unmerged* demand, which deduplication
//!    only reduces tier-by-tier, so the deployed fleet always fits.
//!
//! **Routing credit:** a merged stage's cost is split between its
//! tenants in proportion to offered load (`λ_t / Σλ`), and each
//! tenant's [`TenantPlan::effective_cost_per_hour`] is its own
//! unshared cost plus its credits — summing effective costs recovers
//! the fleet total exactly.
//!
//! **Conformance invariant:** sharing requires ≥ 2 tenants in a group
//! and phase 2 only acts on oversubscribed finite tiers, so a 1-tenant
//! fleet on an unbounded inventory reproduces `Planner::plan`
//! bit-identically (`tests/fleet.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{PipelineConfig, PipelineSpec};
use crate::hardware::{Hardware, Inventory};
use crate::planner::{EstimatorCache, Plan, PlanError, Planner};
use crate::profiler::ProfileSet;
use crate::workload::Trace;

pub mod synth;

pub use synth::{synth_tenants, SynthTenant};

/// One tenant pipeline of the fleet.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Unique tenant name (reported in errors and artifacts).
    pub name: String,
    pub spec: PipelineSpec,
    /// End-to-end P99 latency objective (seconds).
    pub slo: f64,
    /// Planning sample trace (the nominal workload the tenant is
    /// provisioned for).
    pub sample: Trace,
}

/// N tenants sharing one device inventory.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub tenants: Vec<Tenant>,
    pub inventory: Inventory,
}

/// Errors fleet planning can report.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetError {
    /// Demand for one tier exceeds its capacity and local repair could
    /// not move any more tenants off it.
    Infeasible {
        /// The binding tier.
        tier: Hardware,
        /// Devices the per-tenant plans need on that tier.
        demand: usize,
        /// Devices the inventory offers on that tier.
        capacity: usize,
    },
    /// A tenant could not be planned at all (its own SLO is infeasible
    /// on the tiers the inventory offers it).
    Plan {
        tenant: String,
        error: PlanError,
    },
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Infeasible { tier, demand, capacity } => write!(
                f,
                "fleet infeasible: tier {tier} needs {demand} devices but the inventory has \
                 {capacity}"
            ),
            FleetError::Plan { tenant, error } => {
                write!(f, "tenant {tenant}: {error}")
            }
        }
    }
}

/// One tenant's slice of the fleet plan.
#[derive(Debug, Clone)]
pub struct TenantPlan {
    pub tenant: String,
    /// The per-pipeline plan (configuration, estimated P99, telemetry).
    pub plan: Plan,
    /// $/hr attributed to this tenant after prefix-sharing routing
    /// credit: unshared stages at inventory prices plus a
    /// load-proportional share of each merged stage.
    pub effective_cost_per_hour: f64,
    /// Tiers local repair excluded from this tenant's search.
    pub excluded: Vec<Hardware>,
}

/// A merged prefix stage serving several tenants.
#[derive(Debug, Clone)]
pub struct SharedStage {
    /// `/`-joined model chain from the root up to this stage — the
    /// group identity (tenants share a stage only when everything
    /// upstream of it is shared too).
    pub prefix: String,
    /// Position in the shared prefix chain (0 = root).
    pub depth: usize,
    pub hw: Hardware,
    pub batch: usize,
    /// Tenant indices served by this merged stage.
    pub tenants: Vec<usize>,
    /// Replicas of the merged stage (utilization-preserving rule).
    pub replicas: usize,
    /// Sum of the tenants' own per-plan replicas for this stage.
    pub replicas_unshared: usize,
}

impl SharedStage {
    /// Devices saved by the merge (always ≥ 0).
    pub fn saved_replicas(&self) -> usize {
        self.replicas_unshared - self.replicas
    }
}

/// The jointly provisioned fleet.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Per-tenant plans, in `FleetSpec::tenants` order.
    pub tenants: Vec<TenantPlan>,
    /// Merged prefix stages, in deterministic group order.
    pub shared: Vec<SharedStage>,
    /// Σ per-tenant configuration cost at inventory prices (no
    /// sharing).
    pub unshared_cost_per_hour: f64,
    /// Fleet cost after prefix-stage deduplication.
    pub total_cost_per_hour: f64,
    /// `unshared - total` (≥ 0 by the merge rule).
    pub savings_per_hour: f64,
    /// Deployed device count per tier after deduplication, in
    /// [`Hardware::ALL`] order.
    pub usage: [usize; 3],
    /// Tenant re-plans performed by local repair.
    pub repairs: usize,
}

/// Plans a [`FleetSpec`]: the per-tenant [`Planner`] under inventory
/// constraints, greedy packing with local repair, then prefix
/// deduplication. See the module docs for the algorithm and its
/// determinism/termination arguments.
pub struct FleetPlanner<'a> {
    profiles: &'a ProfileSet,
    threads: usize,
    cache: Arc<EstimatorCache>,
}

impl<'a> FleetPlanner<'a> {
    pub fn new(profiles: &'a ProfileSet) -> Self {
        FleetPlanner {
            profiles,
            threads: crate::util::par::default_workers(),
            cache: EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY),
        }
    }

    /// Worker threads for each tenant's candidate evaluation.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Share an [`EstimatorCache`] across fleets (and with the caller).
    pub fn with_shared_cache(mut self, cache: Arc<EstimatorCache>) -> Self {
        self.cache = cache;
        self
    }

    fn plan_tenant(&self, tenant: &Tenant, inventory: Inventory) -> Result<Plan, FleetError> {
        Planner::new(&tenant.spec, self.profiles)
            .with_threads(self.threads)
            .with_shared_cache(Arc::clone(&self.cache))
            .with_inventory(inventory)
            .plan(&tenant.sample, tenant.slo)
            .map_err(|error| FleetError::Plan { tenant: tenant.name.clone(), error })
    }

    /// Phase 1+2: per-tenant plans under the inventory, with local
    /// repair until every finite tier fits. Returns the plans and each
    /// tenant's exclusion list.
    #[allow(clippy::type_complexity)]
    fn plan_and_pack(
        &self,
        fleet: &FleetSpec,
    ) -> Result<(Vec<Plan>, Vec<Vec<Hardware>>, usize), FleetError> {
        let n = fleet.tenants.len();
        let mut excluded: Vec<Vec<Hardware>> = vec![Vec::new(); n];
        // Identical tenants (same pipeline, sample, SLO, exclusions)
        // resolve to one memoized search: planning is deterministic, so
        // this changes nothing but wall-clock at 1000-tenant scale.
        let mut memo: BTreeMap<(u64, Vec<u8>), Plan> = BTreeMap::new();
        let mut plans: Vec<Plan> = Vec::with_capacity(n);
        for (i, t) in fleet.tenants.iter().enumerate() {
            let plan = self.memoized_plan(&mut memo, t, &fleet.inventory, &excluded[i])?;
            plans.push(plan);
        }
        let mut repairs = 0usize;
        loop {
            let demand = tier_demand(&plans);
            // Binding tier: largest overflow, ties toward the cheaper
            // tier (ALL order).
            let mut binding: Option<(Hardware, usize, usize)> = None;
            for hw in Hardware::ALL {
                let Some(cap) = fleet.inventory.count(hw) else { continue };
                let d = demand[hw.index()];
                if d > cap {
                    let over = d - cap;
                    let best = binding.map_or(0, |(_, bd, bc)| bd - bc);
                    if over > best {
                        binding = Some((hw, d, cap));
                    }
                }
            }
            let Some((tier, demand_t, capacity)) = binding else { break };
            // Heaviest user of the binding tier; ties toward the lower
            // tenant index. A tenant excluded from the tier uses none
            // of it, so no (tenant, tier) pair repeats — termination.
            let mut victim: Option<(usize, usize)> = None;
            for (i, p) in plans.iter().enumerate() {
                let used: usize = p
                    .config
                    .stages
                    .iter()
                    .filter(|s| s.hw == tier)
                    .map(|s| s.replicas)
                    .sum();
                if used > 0 && victim.map_or(true, |(_, u)| used > u) {
                    victim = Some((i, used));
                }
            }
            let Some((vi, _)) = victim else {
                // Over capacity with no movable user should be
                // impossible (demand is the sum of users), but never
                // panic on inventory math: report the binding tier.
                return Err(FleetError::Infeasible { tier, demand: demand_t, capacity });
            };
            excluded[vi].push(tier);
            repairs += 1;
            match self.memoized_plan(&mut memo, &fleet.tenants[vi], &fleet.inventory, &excluded[vi])
            {
                Ok(p) => plans[vi] = p,
                // The shed tenant fits nowhere else: the binding tier
                // is genuinely oversubscribed.
                Err(_) => {
                    return Err(FleetError::Infeasible { tier, demand: demand_t, capacity })
                }
            }
        }
        Ok((plans, excluded, repairs))
    }

    fn memoized_plan(
        &self,
        memo: &mut BTreeMap<(u64, Vec<u8>), Plan>,
        tenant: &Tenant,
        inventory: &Inventory,
        excluded: &[Hardware],
    ) -> Result<Plan, FleetError> {
        let mut inv = inventory.clone();
        for &hw in excluded {
            inv = inv.with_count(hw, Some(0));
        }
        let key = (
            tenant_fingerprint(tenant),
            excluded.iter().map(|hw| hw.index() as u8).collect::<Vec<u8>>(),
        );
        if let Some(plan) = memo.get(&key) {
            return Ok(plan.clone());
        }
        let plan = self.plan_tenant(tenant, inv)?;
        memo.insert(key, plan.clone());
        Ok(plan)
    }

    /// Plan the whole fleet. Deterministic: the same spec produces the
    /// same plan, bit for bit.
    pub fn plan(&self, fleet: &FleetSpec) -> Result<FleetPlan, FleetError> {
        let (plans, excluded, repairs) = self.plan_and_pack(fleet)?;
        let inv = &fleet.inventory;

        // Phase 3: group shareable prefix stages. Key = (depth, model
        // chain, framework, hardware, batch); BTreeMap iteration makes
        // group order deterministic.
        let mut groups: BTreeMap<(usize, String, u8, u32), Vec<(usize, usize)>> = BTreeMap::new();
        for (ti, t) in fleet.tenants.iter().enumerate() {
            let chain = prefix_chain(&t.spec);
            let mut path = String::new();
            for (depth, &stage) in chain.iter().enumerate() {
                if depth > 0 {
                    path.push('/');
                }
                path.push_str(t.spec.framework.id());
                path.push(':');
                path.push_str(&t.spec.stages[stage].model);
                let sc = plans[ti].config.stages[stage];
                let key = (depth, path.clone(), sc.hw.index() as u8, sc.batch as u32);
                groups.entry(key).or_default().push((ti, stage));
            }
        }

        let mut shared = Vec::new();
        // Per-tenant cost delta from sharing: subtract own replicas,
        // add the load-proportional credit.
        let mut credit = vec![0.0f64; fleet.tenants.len()];
        let mut saved_per_tier = [0usize; 3];
        for ((depth, path, hw_idx, batch), members) in groups {
            if members.len() < 2 {
                continue;
            }
            let hw = Hardware::ALL[hw_idx as usize];
            let batch = batch as usize;
            let model = path.rsplit(':').next().unwrap_or(&path).to_string();
            let prof = self.profiles.get(&model).get(hw).expect("planned stage has a profile");
            let thpt = prof.throughput(batch);
            let mut sum_lam = 0.0f64;
            let mut sum_r = 0usize;
            let mut max_r = 0usize;
            let mut worst_u = 0.0f64;
            for &(ti, stage) in &members {
                let lam = fleet.tenants[ti].sample.mean_rate();
                let r = plans[ti].config.stages[stage].replicas;
                sum_lam += lam;
                sum_r += r;
                max_r = max_r.max(r);
                worst_u = worst_u.max(lam / (r as f64 * thpt));
            }
            // Utilization-preserving merge (module docs): keep the
            // worst member's utilization. A degenerate utilization
            // (zero-rate samples) falls back to the unmerged total.
            let merged = if worst_u > 0.0 && thpt > 0.0 {
                let u = worst_u.min(1.0);
                let raw = (sum_lam / (thpt * u) - 1e-9).ceil().max(1.0) as usize;
                raw.max(max_r).min(sum_r)
            } else {
                sum_r
            };
            saved_per_tier[hw.index()] += sum_r - merged;
            let device = inv.cost_per_hour(hw);
            let merged_cost = merged as f64 * device;
            for &(ti, stage) in &members {
                let lam = fleet.tenants[ti].sample.mean_rate();
                let own = plans[ti].config.stages[stage].replicas as f64 * device;
                let share = if sum_lam > 0.0 { lam / sum_lam } else { 1.0 / members.len() as f64 };
                credit[ti] += share * merged_cost - own;
            }
            shared.push(SharedStage {
                prefix: path,
                depth,
                hw,
                batch,
                tenants: members.iter().map(|&(ti, _)| ti).collect(),
                replicas: merged,
                replicas_unshared: sum_r,
            });
        }

        let mut usage = tier_demand(&plans);
        for (i, saved) in saved_per_tier.iter().enumerate() {
            usage[i] -= *saved;
        }
        let unshared_cost_per_hour: f64 =
            plans.iter().map(|p| config_cost(inv, &p.config)).sum();
        let savings_per_hour: f64 = shared
            .iter()
            .map(|g| g.saved_replicas() as f64 * inv.cost_per_hour(g.hw))
            .sum();
        let total_cost_per_hour = unshared_cost_per_hour - savings_per_hour;
        let tenants = fleet
            .tenants
            .iter()
            .zip(plans)
            .zip(excluded)
            .enumerate()
            .map(|(i, ((t, plan), excl))| TenantPlan {
                tenant: t.name.clone(),
                effective_cost_per_hour: config_cost(inv, &plan.config) + credit[i],
                plan,
                excluded: excl,
            })
            .collect();
        Ok(FleetPlan {
            tenants,
            shared,
            unshared_cost_per_hour,
            total_cost_per_hour,
            savings_per_hour,
            usage,
            repairs,
        })
    }
}

/// Stage indices of the shareable prefix: from the single root, every
/// stage on the unbranched scale-factor-1 spine (every query visits, so
/// tenant arrival rates add under sharing). Multi-root pipelines and
/// conditional stages share nothing.
fn prefix_chain(spec: &PipelineSpec) -> Vec<usize> {
    let mut chain = Vec::new();
    if spec.roots.len() != 1 {
        return chain;
    }
    let mut cur = spec.roots[0];
    loop {
        if (spec.stages[cur].scale_factor - 1.0).abs() > 1e-12 {
            break;
        }
        chain.push(cur);
        match spec.stages[cur].children.as_slice() {
            [only] => cur = *only,
            _ => break,
        }
    }
    chain
}

/// Per-tier device demand of unmerged per-tenant plans, in
/// [`Hardware::ALL`] order.
fn tier_demand(plans: &[Plan]) -> [usize; 3] {
    let mut demand = [0usize; 3];
    for p in plans {
        for s in &p.config.stages {
            demand[s.hw.index()] += s.replicas;
        }
    }
    demand
}

/// Configuration cost at *inventory* prices (identical to
/// `PipelineConfig::cost_per_hour` when the inventory keeps catalog
/// prices).
fn config_cost(inv: &Inventory, config: &PipelineConfig) -> f64 {
    config.stages.iter().map(|s| s.replicas as f64 * inv.cost_per_hour(s.hw)).sum()
}

/// Fingerprint identifying a tenant's planning problem: pipeline shape,
/// sample trace and SLO. Used only to memoize identical tenants within
/// one fleet plan.
fn tenant_fingerprint(t: &Tenant) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    let mut mix = |bits: u64| {
        h ^= bits;
        h = h.wrapping_mul(0x100_0000_01B3);
    };
    for b in t.spec.name.bytes() {
        mix(b as u64);
    }
    mix(t.spec.framework.rpc_overhead().to_bits());
    mix(t.spec.stages.len() as u64);
    for s in &t.spec.stages {
        for b in s.model.bytes() {
            mix(b as u64);
        }
        mix(s.scale_factor.to_bits());
        for &c in &s.children {
            mix(c as u64);
        }
    }
    mix(t.slo.to_bits());
    mix(t.sample.arrivals.len() as u64);
    for a in &t.sample.arrivals {
        mix(a.to_bits());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;

    #[test]
    fn prefix_chain_shapes() {
        // image-processing: unbranched s=1 spine — both stages share.
        let img = pipelines::image_processing();
        assert_eq!(prefix_chain(&img), vec![0, 1]);
        // video-monitoring: root fans out — only the root shares.
        let video = pipelines::video_monitoring();
        assert_eq!(prefix_chain(&video), vec![0]);
        // tf-cascade: the child is conditional (s < 1) — root only.
        let tf = pipelines::tf_cascade();
        assert_eq!(prefix_chain(&tf), vec![0]);
    }
}
