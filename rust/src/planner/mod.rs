//! The low-frequency Planner (paper §4.3): constrained greedy
//! cost-minimization over the combinatorial configuration space.
//!
//! Two phases:
//!
//! 1. **Initialize** (Algorithm 1): a latency-minimizing feasible starting
//!    point — batch 1, lowest-latency hardware per model, then replicate
//!    the throughput bottleneck until the Estimator deems the pipeline
//!    feasible on the sample trace.
//! 2. **MinimizeCost** (Algorithm 2): iteratively apply the single
//!    cost-reducing action — IncreaseBatch (×2), RemoveReplica, or
//!    DowngradeHW — that maximally decreases cost while remaining
//!    feasible. Terminates when no action reduces cost.
//!
//! Faithfulness note: the paper accepts an `IncreaseBatch` candidate even
//! though batching alone never changes cost, because it unlocks replica
//! removals in later iterations. To keep the greedy loop strictly
//! decreasing (and hence provably terminating), our `IncreaseBatch`
//! candidate composes the batch doubling with the replica removals it
//! enables, and is accepted only if the composition reduces cost. The
//! termination guarantees (§4.3) are preserved and property-tested in
//! `tests/planner_props.rs` (relative to the `rust/` crate root).
//!
//! ## Search performance
//!
//! Every greedy iteration evaluates 3×N candidate actions, each one a
//! discrete-event simulation — the dominant planning cost. Three
//! optimizations keep it fast without changing any result:
//!
//! * **Parallel candidate evaluation**: the 3×N candidates of an
//!   iteration fan out over a scoped thread pool. Selection then replays
//!   the serial fold over the gathered results in (stage, action) order,
//!   so the parallel planner returns a bit-identical [`Plan`].
//! * **Feasibility memo-cache**: results are memoized under a canonical
//!   (trace, SLO, configuration) key shared across `initialize` and
//!   `plan` — the downgrade path re-visits the same configurations many
//!   times per search.
//! * **Analytic pruning**: a cheap per-stage throughput lower bound
//!   rejects under-provisioned candidates before the expensive
//!   simulation (the same bound [`simulator::feasible`] applies).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::{PipelineConfig, PipelineSpec, StageConfig};
use crate::profiler::{ProfileSet, BATCH_CANDIDATES};
use crate::simulator::{self, SimParams};
use crate::workload::Trace;

/// Hard cap on per-stage replicas during search: beyond this the workload
/// is declared infeasible for the catalog (prevents unbounded growth).
pub const MAX_REPLICAS: usize = 256;

/// Telemetry of one search's feasibility evaluations.
#[derive(Debug, Clone, Default)]
pub struct SearchTelemetry {
    /// Feasibility queries answered from the memo-cache.
    pub cache_hits: usize,
    /// Feasibility queries that had to be computed.
    pub cache_misses: usize,
    /// Computed queries rejected by the analytic throughput bound before
    /// any simulation ran (subset of `cache_misses`).
    pub pruned: usize,
    /// Worker threads used for candidate evaluation (1 = serial).
    pub threads: usize,
}

impl SearchTelemetry {
    /// Fraction of feasibility queries served by the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Planner outcome.
#[derive(Debug, Clone)]
pub struct Plan {
    pub config: PipelineConfig,
    /// $/hr of the final configuration.
    pub cost_per_hour: f64,
    /// Estimator P99 on the planning trace.
    pub estimated_p99: f64,
    /// Search telemetry.
    pub iterations: usize,
    pub actions_taken: Vec<String>,
    /// Feasibility cache / pruning telemetry for this search.
    pub telemetry: SearchTelemetry,
}

/// Errors the planner can report.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    /// Even batch-1 / best-hardware / max-replica configs miss the SLO.
    Infeasible(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Infeasible(why) => write!(f, "infeasible: {why}"),
        }
    }
}

/// Canonical memo-cache key: a fingerprint of the planning trace and the
/// simulation parameters, the SLO bits, and the full per-stage
/// configuration. Feasibility is a pure function of exactly these inputs.
type CacheKey = (u64, u64, Vec<(u8, u32, u32)>);

/// FNV-1a over every arrival timestamp plus the `SimParams` fields.
/// Hashing the whole trace is O(N), so callers compute this once per
/// search entry point and reuse it for every feasibility query; the full
/// hash makes key collisions between different traces (or mutated
/// `params`) practically impossible. The exhaustive destructuring is a
/// guard: adding a field to `SimParams` fails compilation here instead
/// of silently serving stale cache entries.
fn trace_fingerprint(trace: &Trace, params: &SimParams) -> u64 {
    let SimParams { routing_seed, replica_activation_delay, control_interval } = params;
    let mut h: u64 = 0xCBF2_9CE4_8422_2325 ^ (trace.arrivals.len() as u64);
    let mut mix = |bits: u64| {
        h ^= bits;
        h = h.wrapping_mul(0x100_0000_01B3);
    };
    for t in &trace.arrivals {
        mix(t.to_bits());
    }
    mix(*routing_seed);
    mix(replica_activation_delay.to_bits());
    mix(control_interval.to_bits());
    h
}

fn cache_key(fp: u64, slo: f64, config: &PipelineConfig) -> CacheKey {
    let stages = config
        .stages
        .iter()
        .map(|s| {
            let hw = crate::hardware::Hardware::ALL
                .iter()
                .position(|&h| h == s.hw)
                .unwrap_or(0) as u8;
            (hw, s.batch as u32, s.replicas as u32)
        })
        .collect();
    (fp, slo.to_bits(), stages)
}

/// Shared, thread-safe feasibility memo-cache with counters.
#[derive(Default)]
struct FeasibilityCache {
    map: Mutex<HashMap<CacheKey, bool>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    pruned: AtomicUsize,
}

impl FeasibilityCache {
    fn snapshot(&self) -> (usize, usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.pruned.load(Ordering::Relaxed),
        )
    }
}

/// The three candidate actions of Algorithm 2, in the serial planner's
/// evaluation order. The order is load-bearing: tie-breaking (stage
/// index, then action kind) keeps parallel and serial plans identical.
const ACTIONS_PER_STAGE: usize = 3;

pub struct Planner<'a> {
    pub spec: &'a PipelineSpec,
    pub profiles: &'a ProfileSet,
    pub params: SimParams,
    /// Worker threads for candidate evaluation (1 = serial).
    pub threads: usize,
    cache: FeasibilityCache,
}

impl<'a> Planner<'a> {
    pub fn new(spec: &'a PipelineSpec, profiles: &'a ProfileSet) -> Self {
        let threads = crate::util::par::default_workers();
        Planner {
            spec,
            profiles,
            params: SimParams::default(),
            threads,
            cache: FeasibilityCache::default(),
        }
    }

    /// A planner that evaluates candidates serially (reference semantics).
    pub fn serial(spec: &'a PipelineSpec, profiles: &'a ProfileSet) -> Self {
        Self::new(spec, profiles).with_threads(1)
    }

    /// Override the candidate-evaluation worker count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// The (trace, params) fingerprint prefix of every cache key for one
    /// search. O(arrivals) — computed once per public entry point, never
    /// per feasibility query.
    fn fingerprint(&self, trace: &Trace) -> u64 {
        trace_fingerprint(trace, &self.params)
    }

    /// Cached feasibility predicate under a precomputed fingerprint:
    /// memo-cache lookup, then the analytic throughput lower bound, then
    /// (only if needed) the Estimator.
    fn feasible_fp(&self, fp: u64, config: &PipelineConfig, trace: &Trace, slo: f64) -> bool {
        let key = cache_key(fp, slo, config);
        if let Some(&v) = self.cache.map.lock().unwrap().get(&key) {
            self.cache.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        self.cache.misses.fetch_add(1, Ordering::Relaxed);
        let v = if !simulator::throughput_bound_ok(
            self.spec,
            self.profiles,
            config,
            trace.mean_rate(),
        ) {
            self.cache.pruned.fetch_add(1, Ordering::Relaxed);
            false
        } else {
            simulator::estimate_p99(self.spec, self.profiles, config, trace, &self.params) <= slo
        };
        self.cache.map.lock().unwrap().insert(key, v);
        v
    }

    /// Cached feasibility predicate (standalone-call convenience).
    fn feasible(&self, config: &PipelineConfig, trace: &Trace, slo: f64) -> bool {
        self.feasible_fp(self.fingerprint(trace), config, trace, slo)
    }

    /// Algorithm 1: find an initial feasible configuration (or fail).
    pub fn initialize(&self, trace: &Trace, slo: f64) -> Result<PipelineConfig, PlanError> {
        let fp = self.fingerprint(trace);
        // Lines 2-5: batch = 1, replicas = 1, lowest-latency hardware.
        let mut config = PipelineConfig {
            stages: self
                .spec
                .stages
                .iter()
                .map(|s| StageConfig {
                    hw: self.profiles.get(&s.model).best_hardware(),
                    batch: 1,
                    replicas: 1,
                })
                .collect(),
        };
        // Lines 6-7: if even the pure service time exceeds the SLO the
        // constraint is infeasible with the available hardware.
        let st = simulator::service_time(self.spec, self.profiles, &config);
        if st > slo {
            return Err(PlanError::Infeasible(format!(
                "service time {st:.3}s exceeds SLO {slo:.3}s at batch 1 on best hardware"
            )));
        }
        // Lines 9-11: replicate the throughput bottleneck until feasible.
        while !self.feasible_fp(fp, &config, trace, slo) {
            let bottleneck = self.find_min_throughput(&config);
            config.stages[bottleneck].replicas += 1;
            if config.stages[bottleneck].replicas > MAX_REPLICAS {
                return Err(PlanError::Infeasible(format!(
                    "stage {} exceeded {MAX_REPLICAS} replicas during initialization",
                    self.spec.stages[bottleneck].name
                )));
            }
        }
        Ok(config)
    }

    /// The stage with the least aggregate throughput headroom relative to
    /// the traffic share it must absorb (Algorithm 1 `FindMinThru`).
    fn find_min_throughput(&self, config: &PipelineConfig) -> usize {
        let mut worst = 0usize;
        let mut worst_headroom = f64::INFINITY;
        for (i, stage) in self.spec.stages.iter().enumerate() {
            let c = &config.stages[i];
            let prof = self.profiles.get(&stage.model).get(c.hw).expect("profile");
            // Normalize by scale factor: a stage seeing half the queries
            // needs half the capacity.
            let headroom =
                c.replicas as f64 * prof.throughput(c.batch) / stage.scale_factor;
            if headroom < worst_headroom {
                worst_headroom = headroom;
                worst = i;
            }
        }
        worst
    }

    /// Evaluate one candidate action by its flat index (stage-major, then
    /// action kind: batch ×2, replica −1, downgrade).
    fn eval_action(
        &self,
        fp: u64,
        idx: usize,
        config: &PipelineConfig,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let stage = idx / ACTIONS_PER_STAGE;
        match idx % ACTIONS_PER_STAGE {
            0 => self.try_increase_batch_fp(fp, config, stage, trace, slo),
            1 => self.try_remove_replica_fp(fp, config, stage, trace, slo),
            _ => self.try_downgrade_hw_fp(fp, config, stage, trace, slo),
        }
    }

    /// Evaluate all 3×N candidate actions, fanning out over a scoped
    /// thread pool when `threads > 1`. The result vector is indexed by
    /// flat action index regardless of evaluation order, which is what
    /// lets selection replay the serial fold deterministically.
    fn evaluate_candidates(
        &self,
        fp: u64,
        config: &PipelineConfig,
        trace: &Trace,
        slo: f64,
    ) -> Vec<Option<PipelineConfig>> {
        let n_tasks = self.spec.stages.len() * ACTIONS_PER_STAGE;
        crate::util::par::parallel_map_indexed(n_tasks, self.threads, |idx| {
            self.eval_action(fp, idx, config, trace, slo)
        })
    }

    fn action_label(&self, idx: usize) -> String {
        let name = &self.spec.stages[idx / ACTIONS_PER_STAGE].name;
        match idx % ACTIONS_PER_STAGE {
            0 => format!("batch x2 @ {name}"),
            1 => format!("replica -1 @ {name}"),
            _ => format!("downgrade @ {name}"),
        }
    }

    /// Algorithm 2: greedy cost minimization.
    pub fn plan(&self, trace: &Trace, slo: f64) -> Result<Plan, PlanError> {
        let t0 = self.cache.snapshot();
        let fp = self.fingerprint(trace);
        let mut config = self.initialize(trace, slo)?;
        let mut actions_taken = Vec::new();
        let mut iterations = 0usize;
        loop {
            iterations += 1;
            let current_cost = config.cost_per_hour();
            let candidates = self.evaluate_candidates(fp, &config, trace, slo);
            // Deterministic selection: replay the serial fold in flat
            // action order — first-best wins within a 1e-12 cost band, so
            // ties break by (stage index, action kind) exactly as the
            // serial planner's nested loops did.
            let mut best: Option<(usize, PipelineConfig, f64)> = None;
            for (idx, cand) in candidates.into_iter().enumerate() {
                let Some(cand) = cand else { continue };
                let cost = cand.cost_per_hour();
                if cost < current_cost - 1e-9
                    && best.as_ref().map_or(true, |(_, _, c)| cost < *c - 1e-12)
                {
                    best = Some((idx, cand, cost));
                }
            }
            match best {
                Some((idx, next, _)) => {
                    actions_taken.push(self.action_label(idx));
                    config = next;
                }
                None => break,
            }
        }
        let estimated_p99 = simulator::estimate_p99(
            self.spec, self.profiles, &config, trace, &self.params,
        );
        let t1 = self.cache.snapshot();
        Ok(Plan {
            cost_per_hour: config.cost_per_hour(),
            config,
            estimated_p99,
            iterations,
            actions_taken,
            telemetry: SearchTelemetry {
                cache_hits: t1.0 - t0.0,
                cache_misses: t1.1 - t0.1,
                pruned: t1.2 - t0.2,
                threads: self.threads,
            },
        })
    }

    /// Candidate: double the stage's batch size, then harvest the replica
    /// removals the higher per-replica throughput enables.
    pub fn try_increase_batch(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        self.try_increase_batch_fp(self.fingerprint(trace), config, stage, trace, slo)
    }

    fn try_increase_batch_fp(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let c = config.stages[stage];
        let prof = self
            .profiles
            .get(&self.spec.stages[stage].model)
            .get(c.hw)
            .expect("profile");
        let next_batch = BATCH_CANDIDATES.iter().copied().find(|&b| b > c.batch)?;
        if next_batch > prof.max_batch() {
            return None;
        }
        let mut cand = config.clone();
        cand.stages[stage].batch = next_batch;
        if !self.feasible_fp(fp, &cand, trace, slo) {
            return None;
        }
        // Harvest enabled removals (keeps the greedy loop strictly
        // decreasing; see module docs).
        while cand.stages[stage].replicas > 1 {
            let mut fewer = cand.clone();
            fewer.stages[stage].replicas -= 1;
            if self.feasible_fp(fp, &fewer, trace, slo) {
                cand = fewer;
            } else {
                break;
            }
        }
        Some(cand)
    }

    /// Candidate: remove one replica from the stage.
    pub fn try_remove_replica(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        self.try_remove_replica_fp(self.fingerprint(trace), config, stage, trace, slo)
    }

    fn try_remove_replica_fp(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        if config.stages[stage].replicas <= 1 {
            return None;
        }
        let mut cand = config.clone();
        cand.stages[stage].replicas -= 1;
        self.feasible_fp(fp, &cand, trace, slo).then_some(cand)
    }

    /// Candidate: move the stage to the next cheaper hardware tier,
    /// re-initializing its batch/replicas and locally re-minimizing
    /// (paper §4.3 "Downgrading hardware is more involved...").
    pub fn try_downgrade_hw(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        self.try_downgrade_hw_fp(self.fingerprint(trace), config, stage, trace, slo)
    }

    fn try_downgrade_hw_fp(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let c = config.stages[stage];
        let model = &self.spec.stages[stage].model;
        let mp = self.profiles.get(model);
        let current_cost = config.cost_per_hour();
        for lower in mp.downgrades_from(c.hw) {
            // Freeze other stages; re-initialize this stage on `lower`.
            let mut cand = config.clone();
            cand.stages[stage] = StageConfig { hw: lower, batch: 1, replicas: 1 };
            // Grow replicas until feasible (bounded).
            let prof = mp.get(lower).expect("profile");
            loop {
                // Only worth continuing while cheaper than current config.
                if cand.cost_per_hour() >= current_cost {
                    break;
                }
                if self.feasible_fp(fp, &cand, trace, slo) {
                    break;
                }
                cand.stages[stage].replicas += 1;
                if cand.stages[stage].replicas > MAX_REPLICAS {
                    break;
                }
            }
            if cand.cost_per_hour() >= current_cost || !self.feasible_fp(fp, &cand, trace, slo) {
                // Try batching on the lower tier to regain throughput.
                let mut batched = None;
                'batches: for &b in BATCH_CANDIDATES.iter().filter(|&&b| b <= prof.max_batch()) {
                    let mut alt = config.clone();
                    alt.stages[stage] = StageConfig { hw: lower, batch: b, replicas: 1 };
                    while alt.cost_per_hour() < current_cost {
                        if self.feasible_fp(fp, &alt, trace, slo) {
                            batched = Some(alt);
                            break 'batches;
                        }
                        alt.stages[stage].replicas += 1;
                        if alt.stages[stage].replicas > MAX_REPLICAS {
                            break;
                        }
                    }
                }
                match batched {
                    Some(alt) => return Some(alt),
                    None => continue,
                }
            }
            // Local minimization on the downgraded stage: try larger
            // batches that allow fewer replicas.
            let mut best = cand.clone();
            for &b in BATCH_CANDIDATES.iter().filter(|&&b| b <= prof.max_batch()) {
                let mut alt = best.clone();
                alt.stages[stage].batch = b;
                while alt.stages[stage].replicas > 1 {
                    let mut fewer = alt.clone();
                    fewer.stages[stage].replicas -= 1;
                    if self.feasible_fp(fp, &fewer, trace, slo) {
                        alt = fewer;
                    } else {
                        break;
                    }
                }
                if self.feasible_fp(fp, &alt, trace, slo)
                    && alt.cost_per_hour() < best.cost_per_hour()
                {
                    best = alt;
                }
            }
            if best.cost_per_hour() < current_cost {
                return Some(best);
            }
        }
        None
    }
}

/// Convenience: plan with default parameters.
pub fn plan(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    trace: &Trace,
    slo: f64,
) -> Result<Plan, PlanError> {
    Planner::new(spec, profiles).plan(trace, slo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::profiler::analytic::paper_profiles;
    use crate::workload::gamma_trace;

    fn quick_trace(lambda: f64) -> Trace {
        gamma_trace(lambda, 1.0, 30.0, 42)
    }

    #[test]
    fn initialize_returns_feasible_config() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(50.0);
        let config = planner.initialize(&trace, 0.3).unwrap();
        assert!(planner.feasible(&config, &trace, 0.3));
        assert!(config.stages.iter().all(|s| s.batch == 1));
    }

    #[test]
    fn initialize_rejects_impossible_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        // 1 ms SLO is below even the batch-1 GPU service time.
        let err = planner.initialize(&quick_trace(10.0), 0.001).unwrap_err();
        assert!(matches!(err, PlanError::Infeasible(_)));
    }

    #[test]
    fn plan_is_feasible_and_cheaper_than_init() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(100.0);
        let slo = 0.3;
        let init = planner.initialize(&trace, slo).unwrap();
        let plan = planner.plan(&trace, slo).unwrap();
        assert!(plan.cost_per_hour <= init.cost_per_hour() + 1e-9);
        assert!(plan.estimated_p99 <= slo);
        assert!(planner.feasible(&plan.config, &trace, slo));
    }

    #[test]
    fn plan_downgrades_cpu_friendly_models() {
        // langid profiles make the GPU marginally faster, so Algorithm 1
        // places it there; the cost minimizer should bring it back to CPU
        // (the §4.3 example).
        let spec = pipelines::social_media();
        let profiles = paper_profiles();
        let trace = quick_trace(50.0);
        let plan = plan(&spec, &profiles, &trace, 0.4).unwrap();
        let langid_idx = spec.stage_index("langid").unwrap();
        assert_eq!(
            plan.config.stages[langid_idx].hw,
            crate::hardware::Hardware::Cpu,
            "plan: {}",
            plan.config.summary(&spec)
        );
    }

    #[test]
    fn no_single_action_reduces_cost_at_termination() {
        let spec = pipelines::tf_cascade();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(80.0);
        let slo = 0.25;
        let plan = planner.plan(&trace, slo).unwrap();
        for stage in 0..spec.stages.len() {
            if let Some(c) = planner.try_remove_replica(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
            if let Some(c) = planner.try_increase_batch(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
            if let Some(c) = planner.try_downgrade_hw(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
        }
    }

    #[test]
    fn cost_decreases_with_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let trace = quick_trace(100.0);
        let tight = plan(&spec, &profiles, &trace, 0.15).unwrap();
        let loose = plan(&spec, &profiles, &trace, 0.5).unwrap();
        assert!(
            loose.cost_per_hour <= tight.cost_per_hour + 1e-9,
            "loose {} > tight {}",
            loose.cost_per_hour,
            tight.cost_per_hour
        );
    }

    #[test]
    fn cost_increases_with_lambda() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let low = plan(&spec, &profiles, &quick_trace(50.0), 0.3).unwrap();
        let high = plan(&spec, &profiles, &quick_trace(200.0), 0.3).unwrap();
        assert!(high.cost_per_hour >= low.cost_per_hour - 1e-9);
    }

    #[test]
    fn parallel_plan_is_bit_identical_to_serial() {
        let profiles = paper_profiles();
        for spec in pipelines::all() {
            let trace = quick_trace(120.0);
            let slo = 0.3;
            let serial = Planner::serial(&spec, &profiles).plan(&trace, slo).unwrap();
            let parallel = Planner::new(&spec, &profiles)
                .with_threads(4)
                .plan(&trace, slo)
                .unwrap();
            assert_eq!(serial.config, parallel.config, "{}", spec.name);
            assert_eq!(serial.actions_taken, parallel.actions_taken, "{}", spec.name);
            assert_eq!(serial.iterations, parallel.iterations, "{}", spec.name);
            assert_eq!(
                serial.cost_per_hour.to_bits(),
                parallel.cost_per_hour.to_bits(),
                "{}",
                spec.name
            );
            assert_eq!(
                serial.estimated_p99.to_bits(),
                parallel.estimated_p99.to_bits(),
                "{}",
                spec.name
            );
        }
    }

    #[test]
    fn feasibility_cache_reports_hits() {
        let spec = pipelines::social_media();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(100.0);
        let plan = planner.plan(&trace, 0.3).unwrap();
        let t = &plan.telemetry;
        assert!(t.cache_misses > 0, "no feasibility work recorded");
        assert!(
            t.cache_hits > 0,
            "downgrade search should revisit configs: {t:?}"
        );
        assert!(t.hit_rate() > 0.0 && t.hit_rate() < 1.0, "rate {}", t.hit_rate());
        // Re-planning the same problem on the same planner is ~all hits.
        let again = planner.plan(&trace, 0.3).unwrap();
        assert_eq!(again.config, plan.config);
        assert!(
            again.telemetry.hit_rate() > 0.9,
            "second pass rate {}",
            again.telemetry.hit_rate()
        );
    }

    #[test]
    fn cache_distinguishes_slos_and_traces() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        // Same planner instance, different SLOs and traces: results must
        // match fresh planners (no cross-contamination through the cache).
        for (lambda, slo) in [(100.0, 0.15), (100.0, 0.5), (200.0, 0.3)] {
            let trace = quick_trace(lambda);
            let shared = planner.plan(&trace, slo).unwrap();
            let fresh = Planner::new(&spec, &profiles).plan(&trace, slo).unwrap();
            assert_eq!(shared.config, fresh.config, "λ={lambda} slo={slo}");
        }
    }
}
