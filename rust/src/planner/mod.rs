//! The low-frequency Planner (paper §4.3): constrained greedy
//! cost-minimization over the combinatorial configuration space.
//!
//! Two phases:
//!
//! 1. **Initialize** (Algorithm 1): a latency-minimizing feasible starting
//!    point — batch 1, lowest-latency hardware per model, then replicate
//!    the throughput bottleneck until the Estimator deems the pipeline
//!    feasible on the sample trace.
//! 2. **MinimizeCost** (Algorithm 2): iteratively apply the single
//!    cost-reducing action — IncreaseBatch (×2), RemoveReplica, or
//!    DowngradeHW — that maximally decreases cost while remaining
//!    feasible. Terminates when no action reduces cost.
//!
//! Faithfulness note: the paper accepts an `IncreaseBatch` candidate even
//! though batching alone never changes cost, because it unlocks replica
//! removals in later iterations. To keep the greedy loop strictly
//! decreasing (and hence provably terminating), our `IncreaseBatch`
//! candidate composes the batch doubling with the replica removals it
//! enables, and is accepted only if the composition reduces cost. The
//! termination guarantees (§4.3) are preserved and property-tested in
//! `tests/planner_props.rs` (relative to the `rust/` crate root).
//!
//! ## Search performance
//!
//! Every greedy iteration evaluates 3×N candidate actions, each one a
//! discrete-event simulation — the dominant planning cost. Three
//! optimizations keep it fast without changing any result:
//!
//! * **Parallel candidate evaluation**: the 3×N candidates of an
//!   iteration fan out over a scoped thread pool. Selection then replays
//!   the serial fold over the gathered results in (stage, action) order,
//!   so the parallel planner returns a bit-identical [`Plan`]. Inside a
//!   downgrade candidate — the critical path for small pipelines — the
//!   independent per-batch replica-growth sub-searches are additionally
//!   evaluated speculatively in parallel, feeding the cache the serial
//!   selection logic reads (see `prewarm_downgrade_tier`).
//! * **Estimator memo-cache** ([`EstimatorCache`]): what the Estimator
//!   learned about each (trace, configuration) pair is memoized *across
//!   SLOs* — a full simulation records the exact P99 (answers feasibility
//!   at any SLO), an early-aborted one records the lower bound it proved
//!   (answers any SLO at or below it), and a fast-accepted one records
//!   the upper bound it proved (answers any SLO at or above it). The
//!   cache is shareable (`Arc`) across planners, e.g. across sweep grid
//!   points whose traces coincide, bounded by a segmented LRU so long
//!   sweeps don't grow without limit, and persistable across *processes*:
//!   exact and proven-bound entries serialize to a versioned JSON file
//!   (see the [`EstimatorCache`] docs for format and invalidation rules),
//!   so repeated CLI invocations on the same traces warm-start.
//! * **Estimator fast path** (see the [`simulator`](crate::simulator)
//!   module docs): one shared [`RoutingPlan`] per (trace, params) reused
//!   by every candidate simulation, early-abort budgeted feasibility, and
//!   O(n) P99 selection. `with_fast_path(false)` restores the reference
//!   full-simulation semantics; plans and feasibility decisions are
//!   bit-identical either way (`tests/estimator_fast_path.rs`).
//! * **Analytic pruning**: a cheap per-stage throughput lower bound
//!   rejects under-provisioned candidates before the expensive
//!   simulation (the same bound [`simulator::feasible`] applies).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::{PipelineConfig, PipelineSpec, StageConfig};
use crate::hardware::{Hardware, Inventory};
use crate::profiler::{ModelProfile, ProfileSet, BATCH_CANDIDATES};
use crate::simulator::{self, RoutingPlan, SimParams};
use crate::util::json::Json;
use crate::workload::Trace;

/// Hard cap on per-stage replicas during search: beyond this the workload
/// is declared infeasible for the catalog (prevents unbounded growth).
pub const MAX_REPLICAS: usize = 256;

/// Telemetry of one search's feasibility evaluations.
#[derive(Debug, Clone, Default)]
pub struct SearchTelemetry {
    /// Feasibility queries answered from the memo-cache.
    pub cache_hits: usize,
    /// Feasibility queries that had to be computed.
    pub cache_misses: usize,
    /// Computed queries rejected by the analytic throughput bound before
    /// any simulation ran (subset of `cache_misses`).
    pub pruned: usize,
    /// Simulations that early-aborted once P99 > SLO was proven (subset
    /// of `cache_misses`; fast path only).
    pub early_aborts: usize,
    /// Simulations that early-accepted once P99 <= SLO was proven (subset
    /// of `cache_misses`; fast path only).
    pub early_accepts: usize,
    /// Worker threads used for candidate evaluation (1 = serial).
    pub threads: usize,
}

impl SearchTelemetry {
    /// Fraction of feasibility queries served by the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Planner outcome.
#[derive(Debug, Clone)]
pub struct Plan {
    pub config: PipelineConfig,
    /// $/hr of the final configuration.
    pub cost_per_hour: f64,
    /// Estimator P99 on the planning trace.
    pub estimated_p99: f64,
    /// Search telemetry.
    pub iterations: usize,
    pub actions_taken: Vec<String>,
    /// Feasibility cache / pruning telemetry for this search.
    pub telemetry: SearchTelemetry,
}

/// Errors the planner can report.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    /// Even batch-1 / best-hardware / max-replica configs miss the SLO.
    Infeasible(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Infeasible(why) => write!(f, "infeasible: {why}"),
        }
    }
}

/// Canonical memo-cache key: a fingerprint of (planning trace, simulation
/// parameters, pipeline spec) plus the full per-stage configuration. The
/// SLO is deliberately *not* part of the key — the cached value is
/// knowledge about the configuration's P99, which answers feasibility at
/// any SLO it covers (see [`P99Knowledge`]).
type CacheKey = (u64, Vec<(u8, u32, u32)>);

/// FNV-1a accumulator shared by the fingerprint functions below — one
/// mechanism, so the offset basis and prime cannot silently diverge.
struct Fnv(u64);

impl Fnv {
    fn new(seed: u64) -> Self {
        Fnv(0xCBF2_9CE4_8422_2325 ^ seed)
    }

    fn mix(&mut self, bits: u64) {
        self.0 ^= bits;
        self.0 = self.0.wrapping_mul(0x100_0000_01B3);
    }

    fn mix_str(&mut self, s: &str) {
        for b in s.bytes() {
            self.mix(b as u64);
        }
    }

    fn finish(self) -> u64 {
        self.0
    }
}

/// Fingerprint of every arrival timestamp plus the `SimParams` fields.
/// Hashing the whole trace is O(N), so callers compute this once per
/// search entry point and reuse it for every feasibility query; the full
/// hash makes key collisions between different traces (or mutated
/// `params`) practically impossible. The exhaustive destructuring is a
/// guard: adding a field to `SimParams` fails compilation here instead
/// of silently serving stale cache entries.
fn trace_fingerprint(trace: &Trace, params: &SimParams) -> u64 {
    let SimParams { routing_seed, replica_activation_delay, control_interval } = params;
    let mut h = Fnv::new(trace.arrivals.len() as u64);
    for t in &trace.arrivals {
        h.mix(t.to_bits());
    }
    h.mix(*routing_seed);
    h.mix(replica_activation_delay.to_bits());
    h.mix(control_interval.to_bits());
    h.finish()
}

/// Fingerprint of the pipeline structure. Mixed into every cache key so
/// an [`EstimatorCache`] can be safely shared across planners for
/// *different* pipelines (e.g. the scenario sweep): identical stage
/// configurations mean different things under different DAGs.
fn spec_fingerprint(spec: &PipelineSpec) -> u64 {
    let mut h = Fnv::new(spec.stages.len() as u64);
    h.mix(spec.framework.rpc_overhead().to_bits());
    h.mix_str(&spec.name);
    for s in &spec.stages {
        h.mix_str(&s.model);
        h.mix(s.scale_factor.to_bits());
        h.mix(s.children.len() as u64);
        for &c in &s.children {
            h.mix(c as u64);
        }
    }
    for &r in &spec.roots {
        h.mix(r as u64);
    }
    h.finish()
}

/// Fingerprint of every (model, hardware, batch-latency point) of the
/// profile set. Simulated service times come from these profiles, so the
/// cache key must distinguish planners built over different sets (e.g.
/// the analytic paper profiles vs a measured/calibrated set) even when
/// spec, trace and params coincide. `ProfileSet` stores `BTreeMap`s, so
/// iteration — and hence the fingerprint — is canonical.
fn profiles_fingerprint(profiles: &ProfileSet) -> u64 {
    let mut h = Fnv::new(profiles.models.len() as u64);
    for (model, mp) in &profiles.models {
        h.mix_str(model);
        for (hw, prof) in &mp.per_hw {
            h.mix(hw.index() as u64);
            for &(batch, latency) in &prof.points {
                h.mix(batch as u64);
                h.mix(latency.to_bits());
            }
        }
    }
    h.finish()
}

fn cache_key(fp: u64, config: &PipelineConfig) -> CacheKey {
    let stages = config
        .stages
        .iter()
        .map(|s| (s.hw.index() as u8, s.batch as u32, s.replicas as u32))
        .collect();
    (fp, stages)
}

/// What the Estimator has learned about a configuration's P99 on a trace.
/// Either form answers feasibility queries exactly as a fresh computation
/// would, so cached and uncached planners make identical decisions.
#[derive(Debug, Clone, Copy, PartialEq)]
enum P99Knowledge {
    /// A full simulation ran: the exact Estimator P99.
    Exact(f64),
    /// P99 lies in the half-open interval `(above, at_most]`. `above`
    /// comes from budgeted simulations that early-aborted at that SLO —
    /// or is `f64::INFINITY` when the analytic throughput bound showed
    /// queues diverge, which is infeasible at every SLO. `at_most` comes
    /// from budgeted simulations that early-accepted at that SLO (it is
    /// `f64::INFINITY` while no accept has been proven). Both sides can
    /// be learned for the same configuration by checks at different SLOs;
    /// the merge keeps the tightest interval.
    Bounded { above: f64, at_most: f64 },
}

impl P99Knowledge {
    /// P99 is provably above `bound` (aborted run, or analytic prune for
    /// `bound = f64::INFINITY`), nothing known from the other side.
    fn above(bound: f64) -> Self {
        P99Knowledge::Bounded { above: bound, at_most: f64::INFINITY }
    }

    /// P99 is provably at or under `bound` (fast-accepted run).
    fn at_most(bound: f64) -> Self {
        P99Knowledge::Bounded { above: f64::NEG_INFINITY, at_most: bound }
    }

    /// Resolve feasibility at `slo` if this knowledge suffices.
    fn resolve(self, slo: f64) -> Option<bool> {
        match self {
            P99Knowledge::Exact(p99) => Some(p99 <= slo),
            P99Knowledge::Bounded { above, at_most } => {
                if slo <= above {
                    Some(false)
                } else if at_most <= slo {
                    Some(true)
                } else {
                    None
                }
            }
        }
    }
}

/// Maximum routing plans retained (each is ~5 bytes per trace query; a
/// planning run touches exactly one).
const MAX_ROUTING_PLANS: usize = 64;

/// Shared, thread-safe Estimator memo state: cross-SLO [`P99Knowledge`]
/// per (trace, spec, params, configuration) and the shared routing plans.
/// Bounded by a two-generation (segmented) LRU: when the current
/// generation fills half the capacity it becomes the previous generation
/// and the oldest entries are dropped — recently touched entries survive
/// because lookups promote them back into the current generation.
/// Hit/miss telemetry lives on each [`Planner`] (not here), so planners
/// sharing one cache still report accurate per-search numbers.
///
/// ## Persistence
///
/// The cache can outlive the process: [`save`](Self::save) serializes
/// every exact-P99 and finite proven-bound entry to a JSON file and
/// [`load_from`](Self::load_from) merges such a file back, so repeated
/// CLI invocations on the same traces warm-start (`--cache` on `plan`,
/// `experiment sweep`, `experiment robustness`). File format, one object:
///
/// ```json
/// {"format": "inferline-estimator-cache", "version": 1,
///  "entries": [{"fp": "<16-hex-digit fingerprint>",
///               "config": [[hw, batch, replicas], ...],
///               "exact": 0.123}, ...]}
/// ```
///
/// where each entry carries either `"exact"` (full simulation ran) or one
/// or both of `"above"` / `"at_most"` (proven bounds from early-aborted /
/// fast-accepted runs). Floats round-trip bit-exactly (Rust's shortest
/// `Display` form), so warm-started planners make bit-identical
/// decisions.
///
/// Invalidation rules — a file is rejected *wholesale* (`Err`, callers
/// log and start cold; never partially or silently trusted) when:
///
/// * the `format` marker or `version` does not match
///   [`PERSIST_FORMAT`](Self::PERSIST_FORMAT) /
///   [`PERSIST_VERSION`](Self::PERSIST_VERSION) — bump the version
///   whenever simulated outcomes can change (engine semantics, profile or
///   fingerprint definitions), which invalidates every older file;
/// * the JSON is unparsable, or any entry is malformed (bad fingerprint,
///   unknown hardware tier, zero batch/replicas, non-finite value).
///
/// Entries from a *different* planning context ("foreign fingerprints":
/// another trace, pipeline, profile set or `SimParams`) load fine but are
/// inert — every lookup key mixes the full context fingerprint, so they
/// can never answer this context's queries. Analytic-prune entries
/// (diverging queues, infeasible at every SLO) persist as
/// `"diverges": true` since JSON has no infinity literal.
pub struct EstimatorCache {
    feas: Mutex<Generations>,
    /// Read-mostly: every cache-miss feasibility query fetches the same
    /// per-search plan, so reads take a shared lock; only the first query
    /// of a new trace takes the write lock to build. Bounded by the same
    /// two-generation scheme as `feas` (capacity `MAX_ROUTING_PLANS`), so
    /// hot plans survive eviction instead of a wholesale clear.
    routing: RwLock<(HashMap<u64, Arc<RoutingPlan>>, HashMap<u64, Arc<RoutingPlan>>)>,
    capacity: usize,
}

#[derive(Default)]
struct Generations {
    current: HashMap<CacheKey, P99Knowledge>,
    previous: HashMap<CacheKey, P99Knowledge>,
}

impl Default for EstimatorCache {
    fn default() -> Self {
        Self::new(Self::DEFAULT_CAPACITY)
    }
}

impl EstimatorCache {
    /// Default entry bound: roomy for any single search, a few tens of MB
    /// at worst for sweep-length workloads.
    pub const DEFAULT_CAPACITY: usize = 1 << 18;

    /// Format marker of persisted cache files.
    pub const PERSIST_FORMAT: &'static str = "inferline-estimator-cache";

    /// Persisted-file version. Bump whenever simulated outcomes can
    /// change (engine semantics, fingerprint or profile-format
    /// definitions): every older file is then rejected at load time
    /// instead of being silently trusted.
    pub const PERSIST_VERSION: usize = 1;

    pub fn new(capacity: usize) -> Self {
        EstimatorCache {
            feas: Mutex::new(Generations::default()),
            routing: RwLock::new((HashMap::new(), HashMap::new())),
            capacity: capacity.max(2),
        }
    }

    /// An `Arc`-wrapped cache ready to share across planners (sweeps).
    pub fn shared(capacity: usize) -> Arc<Self> {
        Arc::new(Self::new(capacity))
    }

    /// Entries currently held across both LRU generations.
    pub fn len(&self) -> usize {
        let g = self.feas.lock().unwrap();
        g.current.len() + g.previous.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolve a feasibility query from cached knowledge, promoting the
    /// entry to the current generation on a hit.
    fn lookup(&self, key: &CacheKey, slo: f64) -> Option<bool> {
        let mut g = self.feas.lock().unwrap();
        if let Some(&k) = g.current.get(key) {
            return k.resolve(slo);
        }
        if let Some(&k) = g.previous.get(key) {
            let capacity = self.capacity;
            Self::insert_merged(&mut g, capacity, key.clone(), k);
            return k.resolve(slo);
        }
        None
    }

    /// Peek at the raw knowledge without telemetry or promotion.
    fn peek(&self, key: &CacheKey) -> Option<P99Knowledge> {
        let g = self.feas.lock().unwrap();
        g.current.get(key).copied().or_else(|| g.previous.get(key).copied())
    }

    fn store(&self, key: CacheKey, val: P99Knowledge) {
        let mut g = self.feas.lock().unwrap();
        let capacity = self.capacity;
        Self::insert_merged(&mut g, capacity, key, val);
    }

    /// Merge new knowledge with whatever either generation already holds
    /// (an exact P99 beats any interval; intervals keep their tightest
    /// sides), then insert into the current generation, rotating
    /// generations when it fills its half of the capacity budget.
    fn insert_merged(g: &mut Generations, capacity: usize, key: CacheKey, val: P99Knowledge) {
        let existing = g.current.get(&key).copied().or_else(|| g.previous.get(&key).copied());
        let merged = match (existing, val) {
            (Some(P99Knowledge::Exact(p)), _) | (_, P99Knowledge::Exact(p)) => {
                P99Knowledge::Exact(p)
            }
            (
                Some(P99Knowledge::Bounded { above: a1, at_most: m1 }),
                P99Knowledge::Bounded { above: a2, at_most: m2 },
            ) => P99Knowledge::Bounded { above: a1.max(a2), at_most: m1.min(m2) },
            (None, v) => v,
        };
        if g.current.len() >= (capacity / 2).max(1) && !g.current.contains_key(&key) {
            g.previous = std::mem::take(&mut g.current);
        }
        g.current.insert(key, merged);
    }

    /// The shared routing plan for a search fingerprint, building it on
    /// first use. Keyed by the full fingerprint — coarser than the plan's
    /// true inputs (routing ignores profiles and the non-seed params), so
    /// planners differing only in those rebuild an identical plan; that
    /// costs one O(trace) build per search, a deliberate trade against
    /// threading a second fingerprint through every call site.
    fn routing_plan(
        &self,
        fp: u64,
        spec: &PipelineSpec,
        trace: &Trace,
        routing_seed: u64,
    ) -> Arc<RoutingPlan> {
        {
            let maps = self.routing.read().unwrap();
            if let Some(plan) = maps.0.get(&fp) {
                return plan.clone();
            }
        }
        let mut maps = self.routing.write().unwrap();
        // Re-check current, then promote from the previous generation:
        // another thread may have built it while we upgraded the lock.
        if let Some(plan) = maps.0.get(&fp) {
            return plan.clone();
        }
        let plan = match maps.1.get(&fp) {
            Some(plan) => plan.clone(),
            None => Arc::new(RoutingPlan::build(spec, trace, routing_seed)),
        };
        if maps.0.len() >= MAX_ROUTING_PLANS / 2 {
            let retired = std::mem::take(&mut maps.0);
            maps.1 = retired;
        }
        maps.0.insert(fp, plan.clone());
        plan
    }

    /// Serialize every persistable entry (exact P99s and finite proven
    /// bounds) as a canonical JSON document: entries are sorted by cache
    /// key and objects use `BTreeMap`s, so the byte stream is a
    /// deterministic function of the cache contents.
    pub fn to_json(&self) -> Json {
        // Previous generation first so current-generation knowledge (the
        // freshest merge for any key present in both) wins.
        let mut entries: BTreeMap<CacheKey, P99Knowledge> = BTreeMap::new();
        {
            let g = self.feas.lock().unwrap();
            for (k, v) in g.previous.iter().chain(g.current.iter()) {
                entries.insert(k.clone(), *v);
            }
        }
        let mut arr = Vec::new();
        for ((fp, stages), val) in entries {
            let mut e = Json::obj();
            match val {
                P99Knowledge::Exact(p) if p.is_finite() => {
                    e.set("exact", p);
                }
                // Analytic prune: queues diverge, infeasible at every SLO.
                // JSON has no ∞, so the case is encoded explicitly.
                P99Knowledge::Bounded { above, .. } if above == f64::INFINITY => {
                    e.set("diverges", true);
                }
                P99Knowledge::Bounded { above, at_most }
                    if above.is_finite() || at_most.is_finite() =>
                {
                    if above.is_finite() {
                        e.set("above", above);
                    }
                    if at_most.is_finite() {
                        e.set("at_most", at_most);
                    }
                }
                // Degenerate values (NaN, empty intervals) carry no
                // knowledge worth persisting.
                _ => continue,
            }
            e.set("fp", format!("{fp:016x}"));
            e.set(
                "config",
                Json::Arr(
                    stages
                        .iter()
                        .map(|&(hw, batch, replicas)| {
                            Json::Arr(vec![
                                Json::Num(hw as f64),
                                Json::Num(batch as f64),
                                Json::Num(replicas as f64),
                            ])
                        })
                        .collect(),
                ),
            );
            arr.push(e);
        }
        let mut doc = Json::obj();
        doc.set("format", Self::PERSIST_FORMAT);
        doc.set("version", Self::PERSIST_VERSION);
        doc.set("entries", Json::Arr(arr));
        doc
    }

    /// Merge entries from a persisted document into this cache. Strict:
    /// a format or version mismatch, or any malformed entry, rejects the
    /// whole file (see the type docs for the invalidation rules). Returns
    /// the number of entries merged.
    pub fn merge_json(&self, doc: &Json) -> Result<usize, String> {
        let format = doc
            .get("format")
            .and_then(Json::as_str)
            .ok_or("cache file has no format marker")?;
        if format != Self::PERSIST_FORMAT {
            return Err(format!("not an estimator cache file (format {format:?})"));
        }
        let version = doc
            .get("version")
            .and_then(Json::as_f64)
            .ok_or("cache file has no version")?;
        if version != Self::PERSIST_VERSION as f64 {
            return Err(format!(
                "estimator cache version {version} is not the supported version {}",
                Self::PERSIST_VERSION
            ));
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("cache file has no entries array")?;
        // Two phases — validate everything, then store — so a file that
        // fails on its N-th entry is rejected wholesale, never partially
        // merged.
        let mut validated: Vec<(CacheKey, P99Knowledge)> = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let fail = |what: &str| format!("cache entry {i}: {what}");
            let fp_str =
                e.get("fp").and_then(Json::as_str).ok_or_else(|| fail("missing fingerprint"))?;
            if fp_str.len() != 16 {
                return Err(fail("fingerprint is not 16 hex digits"));
            }
            let fp = u64::from_str_radix(fp_str, 16).map_err(|_| fail("bad fingerprint"))?;
            let config =
                e.get("config").and_then(Json::as_arr).ok_or_else(|| fail("missing config"))?;
            let mut stages = Vec::with_capacity(config.len());
            for s in config {
                let triple = s
                    .as_arr()
                    .filter(|a| a.len() == 3)
                    .ok_or_else(|| fail("stage is not an [hw, batch, replicas] triple"))?;
                let mut nums = [0u32; 3];
                for (j, v) in triple.iter().enumerate() {
                    let x = v.as_f64().ok_or_else(|| fail("non-numeric stage field"))?;
                    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
                        return Err(fail("stage field out of range"));
                    }
                    nums[j] = x as u32;
                }
                if nums[0] as usize >= Hardware::ALL.len() {
                    return Err(fail("unknown hardware tier"));
                }
                if nums[1] == 0 || nums[2] == 0 {
                    return Err(fail("zero batch or replicas"));
                }
                stages.push((nums[0] as u8, nums[1], nums[2]));
            }
            let finite = |key: &str| -> Result<Option<f64>, String> {
                match e.get(key) {
                    None => Ok(None),
                    Some(v) => {
                        let x = v.as_f64().ok_or_else(|| fail("non-numeric value"))?;
                        if !x.is_finite() {
                            return Err(fail("non-finite value"));
                        }
                        Ok(Some(x))
                    }
                }
            };
            let diverges = match e.get("diverges") {
                None => false,
                Some(v) => v.as_bool().ok_or_else(|| fail("non-boolean diverges flag"))?,
            };
            let val = match (diverges, finite("exact")?, finite("above")?, finite("at_most")?) {
                (true, None, None, None) => P99Knowledge::above(f64::INFINITY),
                (false, Some(p), None, None) => P99Knowledge::Exact(p),
                (false, None, above, at_most) if above.is_some() || at_most.is_some() => {
                    P99Knowledge::Bounded {
                        above: above.unwrap_or(f64::NEG_INFINITY),
                        at_most: at_most.unwrap_or(f64::INFINITY),
                    }
                }
                _ => return Err(fail("entry carries no usable knowledge")),
            };
            validated.push(((fp, stages), val));
        }
        let n = validated.len();
        for (key, val) in validated {
            self.store(key, val);
        }
        Ok(n)
    }

    /// Write the persistable entries to `path` (creating parent
    /// directories as needed). Returns the number of entries written.
    pub fn save(&self, path: &Path) -> Result<usize, String> {
        let doc = self.to_json();
        let n = doc.get("entries").and_then(Json::as_arr).map_or(0, <[Json]>::len);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("create {}: {e}", parent.display()))?;
            }
        }
        // Atomic publish: write a per-process sibling temp file, then
        // rename over the target. A concurrent reader (or a writer killed
        // mid-save) must never see a torn file — `load_from` rejects
        // partial JSON wholesale, which would silently turn every
        // subsequent warm start cold.
        let tmp = path.with_file_name(format!(
            "{}.tmp.{}",
            path.file_name().and_then(|f| f.to_str()).unwrap_or("estimator_cache"),
            std::process::id()
        ));
        std::fs::write(&tmp, format!("{doc}\n"))
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
        Ok(n)
    }

    /// Merge a persisted cache file into this cache; see
    /// [`merge_json`](Self::merge_json) for the (strict) validation.
    /// Returns the number of entries merged.
    pub fn load_from(&self, path: &Path) -> Result<usize, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let doc =
            Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        self.merge_json(&doc)
    }
}

/// Per-planner feasibility counters behind `&self` (candidate evaluation
/// fans out over threads). Deliberately *not* on the shared cache: with a
/// sweep-wide cache, global counters would mix concurrently running
/// searches into every plan's telemetry.
#[derive(Default)]
struct SearchCounters {
    hits: AtomicUsize,
    misses: AtomicUsize,
    pruned: AtomicUsize,
    early_aborts: AtomicUsize,
    early_accepts: AtomicUsize,
}

impl SearchCounters {
    fn snapshot(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.pruned.load(Ordering::Relaxed),
            self.early_aborts.load(Ordering::Relaxed),
            self.early_accepts.load(Ordering::Relaxed),
        )
    }
}

/// The three candidate actions of Algorithm 2, in the serial planner's
/// evaluation order. The order is load-bearing: tie-breaking (stage
/// index, then action kind) keeps parallel and serial plans identical.
const ACTIONS_PER_STAGE: usize = 3;

pub struct Planner<'a> {
    pub spec: &'a PipelineSpec,
    pub profiles: &'a ProfileSet,
    pub params: SimParams,
    /// Worker threads for candidate evaluation (1 = serial).
    pub threads: usize,
    /// Estimator fast path: shared routing plans + early-abort budgeted
    /// feasibility. Decisions and plans are bit-identical with it off;
    /// disabling is for benchmarking and regression tests.
    pub fast_path: bool,
    /// Hardware tiers the search may place replicas on. The default
    /// [`Inventory::unbounded()`] reproduces the historical semantics
    /// bit-identically; the single-pipeline search consults tier
    /// *membership* only (`Some(0)` counts exclude a tier), while
    /// positive finite counts are enforced by the fleet packer
    /// ([`crate::fleet`]).
    inventory: Inventory,
    cache: Arc<EstimatorCache>,
    counters: SearchCounters,
    /// Fingerprint of everything that shapes simulated outcomes besides
    /// the trace and params: the pipeline spec and the profile set.
    context_fp: u64,
}

impl<'a> Planner<'a> {
    pub fn new(spec: &'a PipelineSpec, profiles: &'a ProfileSet) -> Self {
        let threads = crate::util::par::default_workers();
        Planner {
            spec,
            profiles,
            params: SimParams::default(),
            threads,
            fast_path: true,
            inventory: Inventory::unbounded(),
            cache: EstimatorCache::shared(EstimatorCache::DEFAULT_CAPACITY),
            counters: SearchCounters::default(),
            context_fp: spec_fingerprint(spec)
                ^ profiles_fingerprint(profiles).rotate_left(17),
        }
    }

    /// A planner that evaluates candidates serially (reference semantics).
    pub fn serial(spec: &'a PipelineSpec, profiles: &'a ProfileSet) -> Self {
        Self::new(spec, profiles).with_threads(1)
    }

    /// Override the candidate-evaluation worker count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Share an [`EstimatorCache`] with other planners — e.g. across
    /// scenario-sweep grid points whose trace fingerprints coincide (same
    /// pipeline, λ and CV at different SLOs), where one grid point's full
    /// simulations answer the others' feasibility queries.
    pub fn with_shared_cache(mut self, cache: Arc<EstimatorCache>) -> Self {
        self.cache = cache;
        self
    }

    /// Toggle the Estimator fast path (reference semantics when off).
    pub fn with_fast_path(mut self, fast_path: bool) -> Self {
        self.fast_path = fast_path;
        self
    }

    /// Restrict the search to the tiers present in `inventory`
    /// ([`Inventory::tiers()`]): Algorithm 1 picks the best *available*
    /// hardware and Algorithm 2 only downgrades onto available tiers.
    /// With the default unbounded inventory every tier is available and
    /// plans are bit-identical to the pre-inventory planner.
    pub fn with_inventory(mut self, inventory: Inventory) -> Self {
        self.inventory = inventory;
        self
    }

    /// The (trace, params, spec, profiles) fingerprint prefix of every
    /// cache key for one search. O(arrivals) — computed once per public
    /// entry point, never per feasibility query.
    fn fingerprint(&self, trace: &Trace) -> u64 {
        trace_fingerprint(trace, &self.params)
            ^ self.context_fp.wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Cached feasibility predicate under a precomputed fingerprint:
    /// memo-cache lookup (cross-SLO), then the analytic throughput lower
    /// bound, then (only if needed) the Estimator — budgeted with the
    /// shared routing plan on the fast path, a full simulation otherwise.
    /// Every path produces the same decision for the same inputs.
    fn feasible_fp(&self, fp: u64, config: &PipelineConfig, trace: &Trace, slo: f64) -> bool {
        let key = cache_key(fp, config);
        if let Some(verdict) = self.cache.lookup(&key, slo) {
            self.counters.hits.fetch_add(1, Ordering::Relaxed);
            return verdict;
        }
        self.counters.misses.fetch_add(1, Ordering::Relaxed);
        if !simulator::throughput_bound_ok(self.spec, self.profiles, config, trace.mean_rate()) {
            self.counters.pruned.fetch_add(1, Ordering::Relaxed);
            // Diverging queues miss any latency target.
            self.cache.store(key, P99Knowledge::above(f64::INFINITY));
            return false;
        }
        if self.fast_path {
            let routing =
                self.cache.routing_plan(fp, self.spec, trace, self.params.routing_seed);
            let check = simulator::check_feasible(
                self.spec,
                self.profiles,
                config,
                trace,
                slo,
                &self.params,
                Some(&routing),
            );
            match check.p99 {
                Some(p99) => self.cache.store(key, P99Knowledge::Exact(p99)),
                None if check.aborted => {
                    self.counters.early_aborts.fetch_add(1, Ordering::Relaxed);
                    self.cache.store(key, P99Knowledge::above(slo));
                }
                None => {
                    self.counters.early_accepts.fetch_add(1, Ordering::Relaxed);
                    self.cache.store(key, P99Knowledge::at_most(slo));
                }
            }
            check.feasible
        } else {
            let p99 =
                simulator::estimate_p99(self.spec, self.profiles, config, trace, &self.params);
            self.cache.store(key, P99Knowledge::Exact(p99));
            p99 <= slo
        }
    }

    /// The Estimator P99 of a configuration, answered from an exact cache
    /// entry when one exists (bounded entries — aborted or fast-accepted
    /// runs — know only an interval) and computed by a full simulation
    /// otherwise. Deterministic either way.
    fn estimated_p99_fp(&self, fp: u64, config: &PipelineConfig, trace: &Trace) -> f64 {
        let key = cache_key(fp, config);
        if let Some(P99Knowledge::Exact(p99)) = self.cache.peek(&key) {
            return p99;
        }
        let p99 = if self.fast_path {
            let routing =
                self.cache.routing_plan(fp, self.spec, trace, self.params.routing_seed);
            let mut result = simulator::simulate_with_routing(
                self.spec,
                self.profiles,
                config,
                trace,
                &self.params,
                Some(&routing),
            );
            crate::util::stats::p99_in_place(&mut result.latencies)
        } else {
            simulator::estimate_p99(self.spec, self.profiles, config, trace, &self.params)
        };
        self.cache.store(key, P99Knowledge::Exact(p99));
        p99
    }

    /// Cached feasibility predicate (standalone-call convenience).
    fn feasible(&self, config: &PipelineConfig, trace: &Trace, slo: f64) -> bool {
        self.feasible_fp(self.fingerprint(trace), config, trace, slo)
    }

    /// Algorithm 1's `BestHardware` restricted to the inventory: the
    /// lowest-latency *available* profiled tier (ties toward the cheaper
    /// one — the same ordering as [`ModelProfile::best_hardware`], so an
    /// unbounded inventory picks identically).
    fn best_available_hardware(
        &self,
        model: &str,
        mp: &ModelProfile,
    ) -> Result<Hardware, PlanError> {
        mp.per_hw
            .iter()
            .filter(|(hw, _)| self.inventory.has(**hw))
            .min_by(|(ha, pa), (hb, pb)| {
                pa.latency(1)
                    .partial_cmp(&pb.latency(1))
                    .unwrap()
                    .then(ha.cost_per_hour().partial_cmp(&hb.cost_per_hour()).unwrap())
            })
            .map(|(hw, _)| *hw)
            .ok_or_else(|| {
                PlanError::Infeasible(format!(
                    "no hardware tier in the inventory has a profile for model {model:?}"
                ))
            })
    }

    /// Algorithm 1: find an initial feasible configuration (or fail).
    pub fn initialize(&self, trace: &Trace, slo: f64) -> Result<PipelineConfig, PlanError> {
        let fp = self.fingerprint(trace);
        // Lines 2-5: batch = 1, replicas = 1, lowest-latency hardware
        // among the tiers the inventory offers.
        let mut stages = Vec::with_capacity(self.spec.stages.len());
        for s in &self.spec.stages {
            let hw = self.best_available_hardware(&s.model, self.profiles.get(&s.model))?;
            stages.push(StageConfig { hw, batch: 1, replicas: 1 });
        }
        let mut config = PipelineConfig { stages };
        // Lines 6-7: if even the pure service time exceeds the SLO the
        // constraint is infeasible with the available hardware.
        let st = simulator::service_time(self.spec, self.profiles, &config);
        if st > slo {
            return Err(PlanError::Infeasible(format!(
                "service time {st:.3}s exceeds SLO {slo:.3}s at batch 1 on best hardware"
            )));
        }
        // Lines 9-11: replicate the throughput bottleneck until feasible.
        while !self.feasible_fp(fp, &config, trace, slo) {
            let bottleneck = self.find_min_throughput(&config);
            config.stages[bottleneck].replicas += 1;
            if config.stages[bottleneck].replicas > MAX_REPLICAS {
                return Err(PlanError::Infeasible(format!(
                    "stage {} exceeded {MAX_REPLICAS} replicas during initialization",
                    self.spec.stages[bottleneck].name
                )));
            }
        }
        Ok(config)
    }

    /// The stage with the least aggregate throughput headroom relative to
    /// the traffic share it must absorb (Algorithm 1 `FindMinThru`).
    fn find_min_throughput(&self, config: &PipelineConfig) -> usize {
        let mut worst = 0usize;
        let mut worst_headroom = f64::INFINITY;
        for (i, stage) in self.spec.stages.iter().enumerate() {
            let c = &config.stages[i];
            let prof = self.profiles.get(&stage.model).get(c.hw).expect("profile");
            // Normalize by scale factor: a stage seeing half the queries
            // needs half the capacity.
            let headroom =
                c.replicas as f64 * prof.throughput(c.batch) / stage.scale_factor;
            if headroom < worst_headroom {
                worst_headroom = headroom;
                worst = i;
            }
        }
        worst
    }

    /// Evaluate one candidate action by its flat index (stage-major, then
    /// action kind: batch ×2, replica −1, downgrade).
    fn eval_action(
        &self,
        fp: u64,
        idx: usize,
        config: &PipelineConfig,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let stage = idx / ACTIONS_PER_STAGE;
        match idx % ACTIONS_PER_STAGE {
            0 => self.try_increase_batch_fp(fp, config, stage, trace, slo),
            1 => self.try_remove_replica_fp(fp, config, stage, trace, slo),
            _ => self.try_downgrade_hw_fp(fp, config, stage, trace, slo),
        }
    }

    /// Evaluate all 3×N candidate actions, fanning out over a scoped
    /// thread pool when `threads > 1`. The result vector is indexed by
    /// flat action index regardless of evaluation order, which is what
    /// lets selection replay the serial fold deterministically.
    fn evaluate_candidates(
        &self,
        fp: u64,
        config: &PipelineConfig,
        trace: &Trace,
        slo: f64,
    ) -> Vec<Option<PipelineConfig>> {
        let n_tasks = self.spec.stages.len() * ACTIONS_PER_STAGE;
        crate::util::par::parallel_map_indexed(n_tasks, self.threads, |idx| {
            self.eval_action(fp, idx, config, trace, slo)
        })
    }

    fn action_label(&self, idx: usize) -> String {
        let name = &self.spec.stages[idx / ACTIONS_PER_STAGE].name;
        match idx % ACTIONS_PER_STAGE {
            0 => format!("batch x2 @ {name}"),
            1 => format!("replica -1 @ {name}"),
            _ => format!("downgrade @ {name}"),
        }
    }

    /// Algorithm 2: greedy cost minimization.
    pub fn plan(&self, trace: &Trace, slo: f64) -> Result<Plan, PlanError> {
        let t0 = self.counters.snapshot();
        let fp = self.fingerprint(trace);
        let mut config = self.initialize(trace, slo)?;
        let mut actions_taken = Vec::new();
        let mut iterations = 0usize;
        loop {
            iterations += 1;
            let current_cost = config.cost_per_hour();
            let candidates = self.evaluate_candidates(fp, &config, trace, slo);
            // Deterministic selection: replay the serial fold in flat
            // action order — first-best wins within a 1e-12 cost band, so
            // ties break by (stage index, action kind) exactly as the
            // serial planner's nested loops did.
            let mut best: Option<(usize, PipelineConfig, f64)> = None;
            for (idx, cand) in candidates.into_iter().enumerate() {
                let Some(cand) = cand else { continue };
                let cost = cand.cost_per_hour();
                if cost < current_cost - 1e-9
                    && best.as_ref().map_or(true, |(_, _, c)| cost < *c - 1e-12)
                {
                    best = Some((idx, cand, cost));
                }
            }
            match best {
                Some((idx, next, _)) => {
                    actions_taken.push(self.action_label(idx));
                    config = next;
                }
                None => break,
            }
        }
        let estimated_p99 = self.estimated_p99_fp(fp, &config, trace);
        let t1 = self.counters.snapshot();
        Ok(Plan {
            cost_per_hour: config.cost_per_hour(),
            config,
            estimated_p99,
            iterations,
            actions_taken,
            telemetry: SearchTelemetry {
                cache_hits: t1.0 - t0.0,
                cache_misses: t1.1 - t0.1,
                pruned: t1.2 - t0.2,
                early_aborts: t1.3 - t0.3,
                early_accepts: t1.4 - t0.4,
                threads: self.threads,
            },
        })
    }

    /// Candidate: double the stage's batch size, then harvest the replica
    /// removals the higher per-replica throughput enables.
    pub fn try_increase_batch(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        self.try_increase_batch_fp(self.fingerprint(trace), config, stage, trace, slo)
    }

    fn try_increase_batch_fp(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let c = config.stages[stage];
        let prof = self
            .profiles
            .get(&self.spec.stages[stage].model)
            .get(c.hw)
            .expect("profile");
        let next_batch = BATCH_CANDIDATES.iter().copied().find(|&b| b > c.batch)?;
        if next_batch > prof.max_batch() {
            return None;
        }
        let mut cand = config.clone();
        cand.stages[stage].batch = next_batch;
        if !self.feasible_fp(fp, &cand, trace, slo) {
            return None;
        }
        // Harvest enabled removals (keeps the greedy loop strictly
        // decreasing; see module docs).
        while cand.stages[stage].replicas > 1 {
            let mut fewer = cand.clone();
            fewer.stages[stage].replicas -= 1;
            if self.feasible_fp(fp, &fewer, trace, slo) {
                cand = fewer;
            } else {
                break;
            }
        }
        Some(cand)
    }

    /// Candidate: remove one replica from the stage.
    pub fn try_remove_replica(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        self.try_remove_replica_fp(self.fingerprint(trace), config, stage, trace, slo)
    }

    fn try_remove_replica_fp(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        if config.stages[stage].replicas <= 1 {
            return None;
        }
        let mut cand = config.clone();
        cand.stages[stage].replicas -= 1;
        self.feasible_fp(fp, &cand, trace, slo).then_some(cand)
    }

    /// Candidate: move the stage to the next cheaper hardware tier,
    /// re-initializing its batch/replicas and locally re-minimizing
    /// (paper §4.3 "Downgrading hardware is more involved...").
    pub fn try_downgrade_hw(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        self.try_downgrade_hw_fp(self.fingerprint(trace), config, stage, trace, slo)
    }

    /// Speculatively evaluate one downgrade tier's independent per-batch
    /// replica-growth sub-searches in parallel, populating the estimator
    /// cache the serial selection logic then reads. Each sub-search grows
    /// the stage's replicas at a fixed batch size until the configuration
    /// is feasible (or no longer cheaper than `current_cost`) — exactly
    /// the query sequence the serial paths below issue — so this is pure
    /// prewarming: `feasible_fp` is a deterministic function of its
    /// arguments, cached or not, and the serial replay makes bit-identical
    /// decisions whether or not (and in whichever order) the speculative
    /// evaluations ran. This is what parallelizes *inside* a single
    /// downgrade candidate: for small pipelines the critical path of an
    /// iteration is one expensive `try_downgrade_hw`, whose batch
    /// sub-searches would otherwise run one after another.
    #[allow(clippy::too_many_arguments)]
    fn prewarm_downgrade_tier(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        lower: Hardware,
        current_cost: f64,
        trace: &Trace,
        slo: f64,
    ) {
        let prof = self
            .profiles
            .get(&self.spec.stages[stage].model)
            .get(lower)
            .expect("profile");
        let batches: Vec<usize> =
            BATCH_CANDIDATES.iter().copied().filter(|&b| b <= prof.max_batch()).collect();
        // Bound the nested width: up to `threads` downgrade candidates can
        // be inside this function concurrently (one per stage task of the
        // outer candidate fan-out), so an unbounded inner pool would spawn
        // ~threads² simulation threads. Budgeting 2×threads across the
        // stages keeps worst-case oversubscription mild while still giving
        // the critical-path case (one expensive downgrade, everything else
        // idle) a real speedup.
        let inner = (self.threads * 2 / self.spec.stages.len().max(1)).min(self.threads);
        if inner < 2 || batches.len() < 2 {
            return;
        }
        crate::util::par::parallel_map_indexed(batches.len(), inner, |i| {
            let mut cand = config.clone();
            cand.stages[stage] = StageConfig { hw: lower, batch: batches[i], replicas: 1 };
            while cand.cost_per_hour() < current_cost {
                if self.feasible_fp(fp, &cand, trace, slo) {
                    break;
                }
                cand.stages[stage].replicas += 1;
                if cand.stages[stage].replicas > MAX_REPLICAS {
                    break;
                }
            }
        });
    }

    fn try_downgrade_hw_fp(
        &self,
        fp: u64,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let c = config.stages[stage];
        let model = &self.spec.stages[stage].model;
        let mp = self.profiles.get(model);
        let current_cost = config.cost_per_hour();
        // Downgrade targets are the cheaper profiled tiers *present in the
        // inventory* — with the default unbounded inventory this is exactly
        // `downgrades_from`, so pre-fleet plans are bit-identical.
        for lower in mp.downgrades_from(c.hw).into_iter().filter(|hw| self.inventory.has(*hw)) {
            self.prewarm_downgrade_tier(fp, config, stage, lower, current_cost, trace, slo);
            // Freeze other stages; re-initialize this stage on `lower`.
            let mut cand = config.clone();
            cand.stages[stage] = StageConfig { hw: lower, batch: 1, replicas: 1 };
            // Grow replicas until feasible (bounded).
            let prof = mp.get(lower).expect("profile");
            loop {
                // Only worth continuing while cheaper than current config.
                if cand.cost_per_hour() >= current_cost {
                    break;
                }
                if self.feasible_fp(fp, &cand, trace, slo) {
                    break;
                }
                cand.stages[stage].replicas += 1;
                if cand.stages[stage].replicas > MAX_REPLICAS {
                    break;
                }
            }
            if cand.cost_per_hour() >= current_cost || !self.feasible_fp(fp, &cand, trace, slo) {
                // Try batching on the lower tier to regain throughput.
                let mut batched = None;
                'batches: for &b in BATCH_CANDIDATES.iter().filter(|&&b| b <= prof.max_batch()) {
                    let mut alt = config.clone();
                    alt.stages[stage] = StageConfig { hw: lower, batch: b, replicas: 1 };
                    while alt.cost_per_hour() < current_cost {
                        if self.feasible_fp(fp, &alt, trace, slo) {
                            batched = Some(alt);
                            break 'batches;
                        }
                        alt.stages[stage].replicas += 1;
                        if alt.stages[stage].replicas > MAX_REPLICAS {
                            break;
                        }
                    }
                }
                match batched {
                    Some(alt) => return Some(alt),
                    None => continue,
                }
            }
            // Local minimization on the downgraded stage: try larger
            // batches that allow fewer replicas.
            let mut best = cand.clone();
            for &b in BATCH_CANDIDATES.iter().filter(|&&b| b <= prof.max_batch()) {
                let mut alt = best.clone();
                alt.stages[stage].batch = b;
                while alt.stages[stage].replicas > 1 {
                    let mut fewer = alt.clone();
                    fewer.stages[stage].replicas -= 1;
                    if self.feasible_fp(fp, &fewer, trace, slo) {
                        alt = fewer;
                    } else {
                        break;
                    }
                }
                if self.feasible_fp(fp, &alt, trace, slo)
                    && alt.cost_per_hour() < best.cost_per_hour()
                {
                    best = alt;
                }
            }
            if best.cost_per_hour() < current_cost {
                return Some(best);
            }
        }
        None
    }
}

/// Convenience: plan with default parameters.
pub fn plan(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    trace: &Trace,
    slo: f64,
) -> Result<Plan, PlanError> {
    Planner::new(spec, profiles).plan(trace, slo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::profiler::analytic::paper_profiles;
    use crate::workload::gamma_trace;

    fn quick_trace(lambda: f64) -> Trace {
        gamma_trace(lambda, 1.0, 30.0, 42)
    }

    #[test]
    fn initialize_returns_feasible_config() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(50.0);
        let config = planner.initialize(&trace, 0.3).unwrap();
        assert!(planner.feasible(&config, &trace, 0.3));
        assert!(config.stages.iter().all(|s| s.batch == 1));
    }

    #[test]
    fn initialize_rejects_impossible_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        // 1 ms SLO is below even the batch-1 GPU service time.
        let err = planner.initialize(&quick_trace(10.0), 0.001).unwrap_err();
        assert!(matches!(err, PlanError::Infeasible(_)));
    }

    #[test]
    fn plan_is_feasible_and_cheaper_than_init() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(100.0);
        let slo = 0.3;
        let init = planner.initialize(&trace, slo).unwrap();
        let plan = planner.plan(&trace, slo).unwrap();
        assert!(plan.cost_per_hour <= init.cost_per_hour() + 1e-9);
        assert!(plan.estimated_p99 <= slo);
        assert!(planner.feasible(&plan.config, &trace, slo));
    }

    #[test]
    fn plan_downgrades_cpu_friendly_models() {
        // langid profiles make the GPU marginally faster, so Algorithm 1
        // places it there; the cost minimizer should bring it back to CPU
        // (the §4.3 example).
        let spec = pipelines::social_media();
        let profiles = paper_profiles();
        let trace = quick_trace(50.0);
        let plan = plan(&spec, &profiles, &trace, 0.4).unwrap();
        let langid_idx = spec.stage_index("langid").unwrap();
        assert_eq!(
            plan.config.stages[langid_idx].hw,
            crate::hardware::Hardware::Cpu,
            "plan: {}",
            plan.config.summary(&spec)
        );
    }

    #[test]
    fn no_single_action_reduces_cost_at_termination() {
        let spec = pipelines::tf_cascade();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(80.0);
        let slo = 0.25;
        let plan = planner.plan(&trace, slo).unwrap();
        for stage in 0..spec.stages.len() {
            if let Some(c) = planner.try_remove_replica(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
            if let Some(c) = planner.try_increase_batch(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
            if let Some(c) = planner.try_downgrade_hw(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
        }
    }

    #[test]
    fn cost_decreases_with_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let trace = quick_trace(100.0);
        let tight = plan(&spec, &profiles, &trace, 0.15).unwrap();
        let loose = plan(&spec, &profiles, &trace, 0.5).unwrap();
        assert!(
            loose.cost_per_hour <= tight.cost_per_hour + 1e-9,
            "loose {} > tight {}",
            loose.cost_per_hour,
            tight.cost_per_hour
        );
    }

    #[test]
    fn cost_increases_with_lambda() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let low = plan(&spec, &profiles, &quick_trace(50.0), 0.3).unwrap();
        let high = plan(&spec, &profiles, &quick_trace(200.0), 0.3).unwrap();
        assert!(high.cost_per_hour >= low.cost_per_hour - 1e-9);
    }

    #[test]
    fn parallel_plan_is_bit_identical_to_serial() {
        let profiles = paper_profiles();
        for spec in pipelines::all() {
            let trace = quick_trace(120.0);
            let slo = 0.3;
            let serial = Planner::serial(&spec, &profiles).plan(&trace, slo).unwrap();
            let parallel = Planner::new(&spec, &profiles)
                .with_threads(4)
                .plan(&trace, slo)
                .unwrap();
            assert_eq!(serial.config, parallel.config, "{}", spec.name);
            assert_eq!(serial.actions_taken, parallel.actions_taken, "{}", spec.name);
            assert_eq!(serial.iterations, parallel.iterations, "{}", spec.name);
            assert_eq!(
                serial.cost_per_hour.to_bits(),
                parallel.cost_per_hour.to_bits(),
                "{}",
                spec.name
            );
            assert_eq!(
                serial.estimated_p99.to_bits(),
                parallel.estimated_p99.to_bits(),
                "{}",
                spec.name
            );
        }
    }

    #[test]
    fn threaded_downgrade_search_is_bit_identical_to_serial() {
        // social_media at a loose SLO is the known downgrade-heavy case
        // (langid returns to CPU), exercising prewarm_downgrade_tier's
        // speculative per-batch sub-searches.
        let spec = pipelines::social_media();
        let profiles = paper_profiles();
        let trace = quick_trace(50.0);
        let slo = 0.4;
        let serial = Planner::serial(&spec, &profiles).plan(&trace, slo).unwrap();
        let threaded = Planner::new(&spec, &profiles)
            .with_threads(8)
            .plan(&trace, slo)
            .unwrap();
        assert_eq!(serial.config, threaded.config);
        assert_eq!(serial.actions_taken, threaded.actions_taken);
        assert_eq!(serial.cost_per_hour.to_bits(), threaded.cost_per_hour.to_bits());
        assert!(
            serial.actions_taken.iter().any(|a| a.starts_with("downgrade")),
            "scenario no longer exercises the downgrade path: {:?}",
            serial.actions_taken
        );
    }

    #[test]
    fn feasibility_cache_reports_hits() {
        let spec = pipelines::social_media();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(100.0);
        let plan = planner.plan(&trace, 0.3).unwrap();
        let t = &plan.telemetry;
        assert!(t.cache_misses > 0, "no feasibility work recorded");
        assert!(
            t.cache_hits > 0,
            "downgrade search should revisit configs: {t:?}"
        );
        assert!(t.hit_rate() > 0.0 && t.hit_rate() < 1.0, "rate {}", t.hit_rate());
        // Re-planning the same problem on the same planner is ~all hits.
        let again = planner.plan(&trace, 0.3).unwrap();
        assert_eq!(again.config, plan.config);
        assert!(
            again.telemetry.hit_rate() > 0.9,
            "second pass rate {}",
            again.telemetry.hit_rate()
        );
    }

    #[test]
    fn cache_distinguishes_slos_and_traces() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        // Same planner instance, different SLOs and traces: results must
        // match fresh planners (no cross-contamination through the cache).
        for (lambda, slo) in [(100.0, 0.15), (100.0, 0.5), (200.0, 0.3)] {
            let trace = quick_trace(lambda);
            let shared = planner.plan(&trace, slo).unwrap();
            let fresh = Planner::new(&spec, &profiles).plan(&trace, slo).unwrap();
            assert_eq!(shared.config, fresh.config, "λ={lambda} slo={slo}");
        }
    }
}
