//! The low-frequency Planner (paper §4.3): constrained greedy
//! cost-minimization over the combinatorial configuration space.
//!
//! Two phases:
//!
//! 1. **Initialize** (Algorithm 1): a latency-minimizing feasible starting
//!    point — batch 1, lowest-latency hardware per model, then replicate
//!    the throughput bottleneck until the Estimator deems the pipeline
//!    feasible on the sample trace.
//! 2. **MinimizeCost** (Algorithm 2): iteratively apply the single
//!    cost-reducing action — IncreaseBatch (×2), RemoveReplica, or
//!    DowngradeHW — that maximally decreases cost while remaining
//!    feasible. Terminates when no action reduces cost.
//!
//! Faithfulness note: the paper accepts an `IncreaseBatch` candidate even
//! though batching alone never changes cost, because it unlocks replica
//! removals in later iterations. To keep the greedy loop strictly
//! decreasing (and hence provably terminating), our `IncreaseBatch`
//! candidate composes the batch doubling with the replica removals it
//! enables, and is accepted only if the composition reduces cost. The
//! termination guarantees (§4.3) are preserved and property-tested in
//! `rust/tests/planner_props.rs`.

use crate::config::{PipelineConfig, PipelineSpec, StageConfig};
use crate::profiler::{ProfileSet, BATCH_CANDIDATES};
use crate::simulator::{self, SimParams};
use crate::workload::Trace;

/// Hard cap on per-stage replicas during search: beyond this the workload
/// is declared infeasible for the catalog (prevents unbounded growth).
pub const MAX_REPLICAS: usize = 256;

/// Planner outcome.
#[derive(Debug, Clone)]
pub struct Plan {
    pub config: PipelineConfig,
    /// $/hr of the final configuration.
    pub cost_per_hour: f64,
    /// Estimator P99 on the planning trace.
    pub estimated_p99: f64,
    /// Search telemetry.
    pub iterations: usize,
    pub actions_taken: Vec<String>,
}

/// Errors the planner can report.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    /// Even batch-1 / best-hardware / max-replica configs miss the SLO.
    Infeasible(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Infeasible(why) => write!(f, "infeasible: {why}"),
        }
    }
}

pub struct Planner<'a> {
    pub spec: &'a PipelineSpec,
    pub profiles: &'a ProfileSet,
    pub params: SimParams,
}

impl<'a> Planner<'a> {
    pub fn new(spec: &'a PipelineSpec, profiles: &'a ProfileSet) -> Self {
        Planner { spec, profiles, params: SimParams::default() }
    }

    fn feasible(&self, config: &PipelineConfig, trace: &Trace, slo: f64) -> bool {
        simulator::feasible(self.spec, self.profiles, config, trace, slo, &self.params)
    }

    /// Algorithm 1: find an initial feasible configuration (or fail).
    pub fn initialize(&self, trace: &Trace, slo: f64) -> Result<PipelineConfig, PlanError> {
        // Lines 2-5: batch = 1, replicas = 1, lowest-latency hardware.
        let mut config = PipelineConfig {
            stages: self
                .spec
                .stages
                .iter()
                .map(|s| StageConfig {
                    hw: self.profiles.get(&s.model).best_hardware(),
                    batch: 1,
                    replicas: 1,
                })
                .collect(),
        };
        // Lines 6-7: if even the pure service time exceeds the SLO the
        // constraint is infeasible with the available hardware.
        let st = simulator::service_time(self.spec, self.profiles, &config);
        if st > slo {
            return Err(PlanError::Infeasible(format!(
                "service time {st:.3}s exceeds SLO {slo:.3}s at batch 1 on best hardware"
            )));
        }
        // Lines 9-11: replicate the throughput bottleneck until feasible.
        while !self.feasible(&config, trace, slo) {
            let bottleneck = self.find_min_throughput(&config);
            config.stages[bottleneck].replicas += 1;
            if config.stages[bottleneck].replicas > MAX_REPLICAS {
                return Err(PlanError::Infeasible(format!(
                    "stage {} exceeded {MAX_REPLICAS} replicas during initialization",
                    self.spec.stages[bottleneck].name
                )));
            }
        }
        Ok(config)
    }

    /// The stage with the least aggregate throughput headroom relative to
    /// the traffic share it must absorb (Algorithm 1 `FindMinThru`).
    fn find_min_throughput(&self, config: &PipelineConfig) -> usize {
        let mut worst = 0usize;
        let mut worst_headroom = f64::INFINITY;
        for (i, stage) in self.spec.stages.iter().enumerate() {
            let c = &config.stages[i];
            let prof = self.profiles.get(&stage.model).get(c.hw).expect("profile");
            // Normalize by scale factor: a stage seeing half the queries
            // needs half the capacity.
            let headroom =
                c.replicas as f64 * prof.throughput(c.batch) / stage.scale_factor;
            if headroom < worst_headroom {
                worst_headroom = headroom;
                worst = i;
            }
        }
        worst
    }

    /// Algorithm 2: greedy cost minimization.
    pub fn plan(&self, trace: &Trace, slo: f64) -> Result<Plan, PlanError> {
        let mut config = self.initialize(trace, slo)?;
        let mut actions_taken = Vec::new();
        let mut iterations = 0usize;
        loop {
            iterations += 1;
            let current_cost = config.cost_per_hour();
            let mut best: Option<(PipelineConfig, f64, String)> = None;
            let consider = |cand: PipelineConfig, label: String, best: &mut Option<(PipelineConfig, f64, String)>| {
                let cost = cand.cost_per_hour();
                if cost < current_cost - 1e-9
                    && best.as_ref().map_or(true, |(_, c, _)| cost < *c - 1e-12)
                {
                    *best = Some((cand, cost, label));
                }
            };
            for stage in 0..self.spec.stages.len() {
                if let Some(cand) = self.try_increase_batch(&config, stage, trace, slo) {
                    consider(cand, format!("batch x2 @ {}", self.spec.stages[stage].name), &mut best);
                }
                if let Some(cand) = self.try_remove_replica(&config, stage, trace, slo) {
                    consider(cand, format!("replica -1 @ {}", self.spec.stages[stage].name), &mut best);
                }
                if let Some(cand) = self.try_downgrade_hw(&config, stage, trace, slo) {
                    consider(cand, format!("downgrade @ {}", self.spec.stages[stage].name), &mut best);
                }
            }
            match best {
                Some((next, _, label)) => {
                    actions_taken.push(label);
                    config = next;
                }
                None => break,
            }
        }
        let estimated_p99 = simulator::estimate_p99(
            self.spec, self.profiles, &config, trace, &self.params,
        );
        Ok(Plan {
            cost_per_hour: config.cost_per_hour(),
            config,
            estimated_p99,
            iterations,
            actions_taken,
        })
    }

    /// Candidate: double the stage's batch size, then harvest the replica
    /// removals the higher per-replica throughput enables.
    pub fn try_increase_batch(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let c = config.stages[stage];
        let prof = self
            .profiles
            .get(&self.spec.stages[stage].model)
            .get(c.hw)
            .expect("profile");
        let next_batch = BATCH_CANDIDATES.iter().copied().find(|&b| b > c.batch)?;
        if next_batch > prof.max_batch() {
            return None;
        }
        let mut cand = config.clone();
        cand.stages[stage].batch = next_batch;
        if !self.feasible(&cand, trace, slo) {
            return None;
        }
        // Harvest enabled removals (keeps the greedy loop strictly
        // decreasing; see module docs).
        while cand.stages[stage].replicas > 1 {
            let mut fewer = cand.clone();
            fewer.stages[stage].replicas -= 1;
            if self.feasible(&fewer, trace, slo) {
                cand = fewer;
            } else {
                break;
            }
        }
        Some(cand)
    }

    /// Candidate: remove one replica from the stage.
    pub fn try_remove_replica(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        if config.stages[stage].replicas <= 1 {
            return None;
        }
        let mut cand = config.clone();
        cand.stages[stage].replicas -= 1;
        self.feasible(&cand, trace, slo).then_some(cand)
    }

    /// Candidate: move the stage to the next cheaper hardware tier,
    /// re-initializing its batch/replicas and locally re-minimizing
    /// (paper §4.3 "Downgrading hardware is more involved...").
    pub fn try_downgrade_hw(
        &self,
        config: &PipelineConfig,
        stage: usize,
        trace: &Trace,
        slo: f64,
    ) -> Option<PipelineConfig> {
        let c = config.stages[stage];
        let model = &self.spec.stages[stage].model;
        let mp = self.profiles.get(model);
        let current_cost = config.cost_per_hour();
        for lower in mp.downgrades_from(c.hw) {
            // Freeze other stages; re-initialize this stage on `lower`.
            let mut cand = config.clone();
            cand.stages[stage] = StageConfig { hw: lower, batch: 1, replicas: 1 };
            // Grow replicas until feasible (bounded).
            let prof = mp.get(lower).expect("profile");
            loop {
                // Only worth continuing while cheaper than current config.
                if cand.cost_per_hour() >= current_cost {
                    break;
                }
                if self.feasible(&cand, trace, slo) {
                    break;
                }
                cand.stages[stage].replicas += 1;
                if cand.stages[stage].replicas > MAX_REPLICAS {
                    break;
                }
            }
            if cand.cost_per_hour() >= current_cost || !self.feasible(&cand, trace, slo) {
                // Try batching on the lower tier to regain throughput.
                let mut batched = None;
                'batches: for &b in BATCH_CANDIDATES.iter().filter(|&&b| b <= prof.max_batch()) {
                    let mut alt = config.clone();
                    alt.stages[stage] = StageConfig { hw: lower, batch: b, replicas: 1 };
                    while alt.cost_per_hour() < current_cost {
                        if self.feasible(&alt, trace, slo) {
                            batched = Some(alt);
                            break 'batches;
                        }
                        alt.stages[stage].replicas += 1;
                        if alt.stages[stage].replicas > MAX_REPLICAS {
                            break;
                        }
                    }
                }
                match batched {
                    Some(alt) => return Some(alt),
                    None => continue,
                }
            }
            // Local minimization on the downgraded stage: try larger
            // batches that allow fewer replicas.
            let mut best = cand.clone();
            for &b in BATCH_CANDIDATES.iter().filter(|&&b| b <= prof.max_batch()) {
                let mut alt = best.clone();
                alt.stages[stage].batch = b;
                while alt.stages[stage].replicas > 1 {
                    let mut fewer = alt.clone();
                    fewer.stages[stage].replicas -= 1;
                    if self.feasible(&fewer, trace, slo) {
                        alt = fewer;
                    } else {
                        break;
                    }
                }
                if self.feasible(&alt, trace, slo)
                    && alt.cost_per_hour() < best.cost_per_hour()
                {
                    best = alt;
                }
            }
            if best.cost_per_hour() < current_cost {
                return Some(best);
            }
        }
        None
    }
}

/// Convenience: plan with default parameters.
pub fn plan(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    trace: &Trace,
    slo: f64,
) -> Result<Plan, PlanError> {
    Planner::new(spec, profiles).plan(trace, slo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::profiler::analytic::paper_profiles;
    use crate::workload::gamma_trace;

    fn quick_trace(lambda: f64) -> Trace {
        gamma_trace(lambda, 1.0, 30.0, 42)
    }

    #[test]
    fn initialize_returns_feasible_config() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(50.0);
        let config = planner.initialize(&trace, 0.3).unwrap();
        assert!(planner.feasible(&config, &trace, 0.3));
        assert!(config.stages.iter().all(|s| s.batch == 1));
    }

    #[test]
    fn initialize_rejects_impossible_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        // 1 ms SLO is below even the batch-1 GPU service time.
        let err = planner.initialize(&quick_trace(10.0), 0.001).unwrap_err();
        assert!(matches!(err, PlanError::Infeasible(_)));
    }

    #[test]
    fn plan_is_feasible_and_cheaper_than_init() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(100.0);
        let slo = 0.3;
        let init = planner.initialize(&trace, slo).unwrap();
        let plan = planner.plan(&trace, slo).unwrap();
        assert!(plan.cost_per_hour <= init.cost_per_hour() + 1e-9);
        assert!(plan.estimated_p99 <= slo);
        assert!(planner.feasible(&plan.config, &trace, slo));
    }

    #[test]
    fn plan_downgrades_cpu_friendly_models() {
        // langid profiles make the GPU marginally faster, so Algorithm 1
        // places it there; the cost minimizer should bring it back to CPU
        // (the §4.3 example).
        let spec = pipelines::social_media();
        let profiles = paper_profiles();
        let trace = quick_trace(50.0);
        let plan = plan(&spec, &profiles, &trace, 0.4).unwrap();
        let langid_idx = spec.stage_index("langid").unwrap();
        assert_eq!(
            plan.config.stages[langid_idx].hw,
            crate::hardware::Hardware::Cpu,
            "plan: {}",
            plan.config.summary(&spec)
        );
    }

    #[test]
    fn no_single_action_reduces_cost_at_termination() {
        let spec = pipelines::tf_cascade();
        let profiles = paper_profiles();
        let planner = Planner::new(&spec, &profiles);
        let trace = quick_trace(80.0);
        let slo = 0.25;
        let plan = planner.plan(&trace, slo).unwrap();
        for stage in 0..spec.stages.len() {
            if let Some(c) = planner.try_remove_replica(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
            if let Some(c) = planner.try_increase_batch(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
            if let Some(c) = planner.try_downgrade_hw(&plan.config, stage, &trace, slo) {
                assert!(c.cost_per_hour() >= plan.cost_per_hour - 1e-9);
            }
        }
    }

    #[test]
    fn cost_decreases_with_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let trace = quick_trace(100.0);
        let tight = plan(&spec, &profiles, &trace, 0.15).unwrap();
        let loose = plan(&spec, &profiles, &trace, 0.5).unwrap();
        assert!(
            loose.cost_per_hour <= tight.cost_per_hour + 1e-9,
            "loose {} > tight {}",
            loose.cost_per_hour,
            tight.cost_per_hour
        );
    }

    #[test]
    fn cost_increases_with_lambda() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let low = plan(&spec, &profiles, &quick_trace(50.0), 0.3).unwrap();
        let high = plan(&spec, &profiles, &quick_trace(200.0), 0.3).unwrap();
        assert!(high.cost_per_hour >= low.cost_per_hour - 1e-9);
    }
}
