//! The physical serving plane: a Clipper-like engine executing pipeline
//! DAGs over real PJRT-compiled models with centralized batched queues
//! (paper §3's underlying-framework requirements: replica scaling at
//! runtime, configurable max batch size, centralized batched queueing).
//!
//! Python is never involved: workers execute the AOT HLO artifacts through
//! [`crate::runtime::ReplicaExecutor`], each worker thread owning its own
//! PJRT client (the wrapper types are not `Send`).

use std::sync::atomic::{AtomicIsize, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{PipelineConfig, PipelineSpec};
use crate::profiler::BatchProfile;
use crate::runtime::Manifest;
use crate::util::rng::Rng;
use crate::workload::Trace;

use super::queue::CentralQueue;

/// How a replica worker "computes" a batch.
#[derive(Clone)]
pub enum Backend {
    /// Real execution: compile and run the model's HLO artifacts on the
    /// worker thread's own PJRT CPU client.
    Pjrt { manifest: Arc<Manifest> },
    /// Calibrated stand-in: sleep for the profile's batch latency. Used
    /// to emulate accelerator tiers that this machine does not have.
    Calibrated { profile: BatchProfile },
}

/// One in-flight query.
#[derive(Clone)]
struct Query {
    core: Arc<QueryCore>,
    /// Bitmask of stages this query visits (sampled at ingest).
    visited: u32,
}

struct QueryCore {
    id: u32,
    arrival: Instant,
    /// Stage visits still outstanding.
    remaining: AtomicUsize,
}

struct StageShared {
    queue: CentralQueue<Query>,
    /// Workers decrement-and-retire when positive.
    retire: AtomicIsize,
    /// Live worker count (telemetry).
    workers: AtomicUsize,
    /// Workers that finished backend construction (PJRT compilation can
    /// take seconds; ingest must not race it).
    ready: AtomicUsize,
    batch: usize,
}

struct EngineShared {
    stages: Vec<StageShared>,
    children: Vec<Vec<usize>>,
    completions: mpsc::Sender<(u32, Duration)>,
}

impl EngineShared {
    /// Called by workers when a stage finishes a query's batch.
    fn complete_visit(&self, q: &Query, stage: usize) {
        for &c in &self.children[stage] {
            if q.visited & (1 << c) != 0 {
                self.stages[c].queue.push(q.clone());
            }
        }
        if q.core.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _ = self
                .completions
                .send((q.core.id, q.core.arrival.elapsed()));
        }
    }
}

/// The serving engine: spawn with a pipeline spec + configuration, feed it
/// a trace, collect per-query latencies.
pub struct ServingEngine {
    spec: PipelineSpec,
    shared: Arc<EngineShared>,
    workers: Vec<JoinHandle<()>>,
    completions_rx: mpsc::Receiver<(u32, Duration)>,
    backends: Vec<Backend>,
}

/// Result of serving a trace on the physical plane.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Per-query end-to-end latency (seconds), completion order.
    pub latencies: Vec<f64>,
    /// Wall-clock makespan (seconds) from first ingest to last completion.
    pub makespan: f64,
    /// Offered load actually achieved (QPS).
    pub achieved_qps: f64,
}

impl ServingEngine {
    /// Build the engine: one backend per stage, `replicas` workers each.
    pub fn start(
        spec: &PipelineSpec,
        config: &PipelineConfig,
        backends: Vec<Backend>,
    ) -> Result<ServingEngine> {
        assert_eq!(spec.stages.len(), config.stages.len());
        assert_eq!(spec.stages.len(), backends.len());
        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(EngineShared {
            stages: spec
                .stages
                .iter()
                .zip(&config.stages)
                .map(|(_, c)| StageShared {
                    queue: CentralQueue::new(),
                    retire: AtomicIsize::new(0),
                    workers: AtomicUsize::new(0),
                    ready: AtomicUsize::new(0),
                    batch: c.batch,
                })
                .collect(),
            children: spec.stages.iter().map(|s| s.children.clone()).collect(),
            completions: tx,
        });
        let mut engine = ServingEngine {
            spec: spec.clone(),
            shared,
            workers: Vec::new(),
            completions_rx: rx,
            backends,
        };
        for (i, c) in config.stages.iter().enumerate() {
            for _ in 0..c.replicas {
                engine.spawn_worker(i)?;
            }
        }
        Ok(engine)
    }

    /// Add one replica to a stage at runtime (paper §3 requirement 1).
    pub fn spawn_worker(&mut self, stage: usize) -> Result<()> {
        let shared = self.shared.clone();
        let backend = self.backends[stage].clone();
        let model = self.spec.stages[stage].model.clone();
        let batch = self.shared.stages[stage].batch;
        let handle = std::thread::Builder::new()
            .name(format!("worker-{}", self.spec.stages[stage].name))
            .spawn(move || worker_loop(shared, stage, model, batch, backend))?;
        self.shared.stages[stage].workers.fetch_add(1, Ordering::AcqRel);
        self.workers.push(handle);
        Ok(())
    }

    /// Retire one replica of a stage at runtime.
    pub fn retire_worker(&self, stage: usize) {
        self.shared.stages[stage].retire.fetch_add(1, Ordering::AcqRel);
    }

    /// Live worker count per stage.
    pub fn worker_counts(&self) -> Vec<usize> {
        self.shared
            .stages
            .iter()
            .map(|s| s.workers.load(Ordering::Acquire))
            .collect()
    }

    /// Queue depths (telemetry).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared.stages.iter().map(|s| s.queue.len()).collect()
    }

    /// Block until every spawned worker finished constructing its backend
    /// (PJRT compilation). Returns false on timeout.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        loop {
            let ready: usize = self
                .shared
                .stages
                .iter()
                .map(|s| s.ready.load(Ordering::Acquire))
                .sum();
            if ready >= self.workers.len() {
                return true;
            }
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Serve a trace: ingest queries at the trace's (scaled) timestamps,
    /// wait for every completion, shut down, and report latencies.
    /// `time_scale` stretches (>1) or compresses (<1) trace time.
    pub fn serve_trace(mut self, trace: &Trace, time_scale: f64, routing_seed: u64) -> ServeResult {
        // Never race worker startup/compilation.
        self.wait_ready(Duration::from_secs(120));
        let n = trace.len();
        let mut rng = Rng::new(routing_seed);
        // Pre-sample routing (same scheme as the Estimator).
        let plans: Vec<(u32, usize)> = (0..n)
            .map(|i| {
                let mut q_rng = rng.fork(i as u64);
                let mut visited = 0u32;
                let mut count = 0usize;
                let mut stack = self.spec.roots.clone();
                while let Some(s) = stack.pop() {
                    visited |= 1 << s;
                    count += 1;
                    for &c in &self.spec.stages[s].children {
                        let p = self.spec.edge_probability(s, c);
                        if p >= 1.0 || q_rng.bool(p) {
                            stack.push(c);
                        }
                    }
                }
                (visited, count)
            })
            .collect();

        let t0 = Instant::now();
        let shared = self.shared.clone();
        let arrivals = trace.arrivals.clone();
        let roots = self.spec.roots.clone();
        let ingest = std::thread::spawn(move || {
            for (i, &t) in arrivals.iter().enumerate() {
                let due = Duration::from_secs_f64((t - arrivals[0]) * time_scale);
                let elapsed = t0.elapsed();
                if due > elapsed {
                    std::thread::sleep(due - elapsed);
                }
                let (visited, count) = plans[i];
                let q = Query {
                    core: Arc::new(QueryCore {
                        id: i as u32,
                        arrival: Instant::now(),
                        remaining: AtomicUsize::new(count),
                    }),
                    visited,
                };
                for &r in &roots {
                    shared.stages[r].queue.push(q.clone());
                }
            }
        });

        let mut latencies = Vec::with_capacity(n);
        for _ in 0..n {
            match self.completions_rx.recv_timeout(Duration::from_secs(300)) {
                Ok((_, d)) => latencies.push(d.as_secs_f64()),
                Err(_) => break, // deadlock guard: report what we have
            }
        }
        ingest.join().expect("ingest thread");
        let makespan = t0.elapsed().as_secs_f64();
        self.shutdown();
        ServeResult {
            achieved_qps: latencies.len() as f64 / makespan.max(1e-9),
            latencies,
            makespan,
        }
    }

    /// Close all queues and join all workers.
    pub fn shutdown(&mut self) {
        for s in &self.shared.stages {
            s.queue.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    shared: Arc<EngineShared>,
    stage: usize,
    model: String,
    batch: usize,
    backend: Backend,
) {
    // PJRT executors must be constructed on the worker thread (not Send).
    let executor = match &backend {
        Backend::Pjrt { manifest } => {
            match crate::runtime::ReplicaExecutor::new(manifest, &model, batch) {
                Ok(e) => {
                    // Warm the executables once: first-run page faults and
                    // lazy allocations otherwise land on the first query.
                    let _ = e.run(1);
                    let _ = e.run(batch);
                    Some(e)
                }
                Err(err) => {
                    crate::log_error!("worker {model}: executor init failed: {err:#}");
                    shared.stages[stage].workers.fetch_sub(1, Ordering::AcqRel);
                    shared.stages[stage].ready.fetch_add(1, Ordering::AcqRel);
                    return;
                }
            }
        }
        Backend::Calibrated { .. } => None,
    };
    shared.stages[stage].ready.fetch_add(1, Ordering::AcqRel);
    let st = &shared.stages[stage];
    loop {
        // Honor retirement requests between batches.
        let r = st.retire.load(Ordering::Acquire);
        if r > 0
            && st
                .retire
                .compare_exchange(r, r - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            break;
        }
        let Some(queries) = st.queue.pop_batch(batch, Duration::from_millis(20)) else {
            break; // queue closed
        };
        if queries.is_empty() {
            continue; // poll timeout: re-check retirement
        }
        match (&backend, &executor) {
            (Backend::Pjrt { .. }, Some(exec)) => {
                if let Err(e) = exec.run(queries.len()) {
                    crate::log_error!("worker {model}: execute failed: {e:#}");
                }
            }
            (Backend::Calibrated { profile }, _) => {
                let latency = profile.latency(queries.len());
                std::thread::sleep(Duration::from_secs_f64(latency));
            }
            _ => unreachable!(),
        }
        for q in &queries {
            shared.complete_visit(q, stage);
        }
    }
    shared.stages[stage].workers.fetch_sub(1, Ordering::AcqRel);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::config::StageConfig;
    use crate::hardware::Hardware;
    use crate::util::stats;
    use crate::workload::gamma_trace;

    fn calibrated_engine(
        spec: &PipelineSpec,
        batch: usize,
        replicas: usize,
        alpha: f64,
        beta: f64,
    ) -> (ServingEngine, PipelineConfig) {
        let config = PipelineConfig::uniform(spec.n_stages(), Hardware::Cpu, batch, replicas);
        let backends = spec
            .stages
            .iter()
            .map(|_| Backend::Calibrated { profile: BatchProfile::affine(alpha, beta, 64) })
            .collect();
        let engine = ServingEngine::start(spec, &config, backends).unwrap();
        let _ = StageConfig { hw: Hardware::Cpu, batch, replicas };
        (engine, config)
    }

    #[test]
    fn serves_all_queries_linear_pipeline() {
        let spec = pipelines::image_processing();
        let (engine, _) = calibrated_engine(&spec, 4, 2, 0.002, 0.001);
        let trace = gamma_trace(100.0, 1.0, 3.0, 5);
        let n = trace.len();
        let result = engine.serve_trace(&trace, 1.0, 7);
        assert_eq!(result.latencies.len(), n);
        assert!(result.latencies.iter().all(|&l| l > 0.0));
        // 2 stages x (2ms + batching) << 100ms at this light load.
        assert!(stats::p99(&result.latencies) < 0.15, "p99 {}", stats::p99(&result.latencies));
    }

    #[test]
    fn conditional_pipeline_completes_every_query() {
        let spec = pipelines::video_monitoring();
        let (engine, _) = calibrated_engine(&spec, 2, 2, 0.001, 0.0005);
        let trace = gamma_trace(150.0, 1.0, 2.0, 9);
        let n = trace.len();
        let result = engine.serve_trace(&trace, 1.0, 11);
        assert_eq!(result.latencies.len(), n, "lost queries in conditional DAG");
    }

    #[test]
    fn underprovisioned_stage_shows_queueing() {
        let spec = pipelines::image_processing();
        // Service 10ms/batch1, 1 replica each, 150 qps offered => saturated.
        let (engine, _) = calibrated_engine(&spec, 1, 1, 0.010, 0.0);
        let trace = gamma_trace(150.0, 1.0, 2.0, 13);
        let result = engine.serve_trace(&trace, 1.0, 15);
        // ~100 qps capacity vs 150 offered: tail latencies blow past the
        // service time.
        assert!(
            stats::p99(&result.latencies) > 0.05,
            "expected queueing, p99 {}",
            stats::p99(&result.latencies)
        );
    }

    #[test]
    fn runtime_scaling_changes_worker_counts() {
        let spec = pipelines::image_processing();
        let (mut engine, _) = calibrated_engine(&spec, 1, 2, 0.001, 0.0);
        assert_eq!(engine.worker_counts(), vec![2, 2]);
        engine.spawn_worker(0).unwrap();
        // allow the thread to start
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(engine.worker_counts()[0], 3);
        engine.retire_worker(0);
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(engine.worker_counts()[0], 2);
        engine.shutdown();
    }

    #[test]
    fn batching_improves_throughput_under_load() {
        // With affine service (alpha-dominated), batch 8 sustains much
        // more load than batch 1 at equal replicas.
        let spec = pipelines::image_processing();
        let trace = gamma_trace(300.0, 1.0, 2.0, 17);

        let (engine_b1, _) = calibrated_engine(&spec, 1, 1, 0.008, 0.0002);
        let r1 = engine_b1.serve_trace(&trace, 1.0, 19);
        let (engine_b8, _) = calibrated_engine(&spec, 8, 1, 0.008, 0.0002);
        let r8 = engine_b8.serve_trace(&trace, 1.0, 19);
        assert!(
            stats::p99(&r8.latencies) < stats::p99(&r1.latencies),
            "batch8 p99 {} !< batch1 p99 {}",
            stats::p99(&r8.latencies),
            stats::p99(&r1.latencies)
        );
    }
}
