//! Centralized batched queue: the queueing discipline InferLine requires
//! of the underlying serving framework (paper §3, requirement 3).
//!
//! One FIFO per stage; replica workers block on it and take up to their
//! maximum batch size the moment they are free (batch-at-a-time). This is
//! the same policy the Estimator simulates, which is what makes the
//! simulation faithful (paper §4.2: "deterministic behavior of queries
//! flowing through a centralized batched queueing system").

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A blocking MPMC batched FIFO.
pub struct CentralQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cv: Condvar,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Default for CentralQueue<T> {
    fn default() -> Self {
        CentralQueue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }
}

impl<T> CentralQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue one item. Returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut q = self.inner.lock().unwrap();
        if q.closed {
            return false;
        }
        q.items.push_back(item);
        drop(q);
        self.cv.notify_one();
        true
    }

    /// Blocking batched pop: waits until at least one item is available
    /// (or the queue closes) and returns up to `max_batch` items.
    /// `poll` bounds the wait per iteration so workers can observe
    /// retirement requests.
    pub fn pop_batch(&self, max_batch: usize, poll: Duration) -> Option<Vec<T>> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if !q.items.is_empty() {
                let n = max_batch.max(1).min(q.items.len());
                return Some(q.items.drain(..n).collect());
            }
            if q.closed {
                return None;
            }
            let (guard, timeout) = self.cv.wait_timeout(q, poll).unwrap();
            q = guard;
            if timeout.timed_out() && q.items.is_empty() && !q.closed {
                // Let the worker check for retirement, then come back.
                return Some(Vec::new());
            }
        }
    }

    /// Instantaneous depth.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: wake all waiters; subsequent pushes fail, pops drain then
    /// return None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn batched_pop_takes_up_to_max() {
        let q = CentralQueue::new();
        for i in 0..10 {
            assert!(q.push(i));
        }
        let batch = q.pop_batch(4, Duration::from_millis(10)).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let batch = q.pop_batch(100, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 6);
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(CentralQueue::new());
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            loop {
                let b = q2.pop_batch(1, Duration::from_millis(50)).unwrap();
                if !b.is_empty() {
                    return b[0];
                }
            }
        });
        std::thread::sleep(Duration::from_millis(20));
        q.push(42);
        assert_eq!(t.join().unwrap(), 42);
    }

    #[test]
    fn close_wakes_and_drains() {
        let q: CentralQueue<u32> = CentralQueue::new();
        q.push(1);
        q.close();
        assert!(!q.push(2));
        // Drain remaining then None.
        assert_eq!(q.pop_batch(8, Duration::from_millis(5)).unwrap(), vec![1]);
        assert!(q.pop_batch(8, Duration::from_millis(5)).is_none());
    }

    #[test]
    fn concurrent_producers_consumers_conserve_items() {
        let q = Arc::new(CentralQueue::new());
        let mut producers = Vec::new();
        for p in 0..4 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..500 {
                    q.push(p * 1000 + i);
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(batch) = q.pop_batch(7, Duration::from_millis(20)) {
                    got.extend(batch);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        // Give consumers a moment to drain, then close.
        while !q.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort();
        assert_eq!(all.len(), 2000);
        all.dedup();
        assert_eq!(all.len(), 2000, "duplicates detected");
    }
}
