//! Physical Profiler (paper §4.1): measure each model *in isolation* as a
//! function of batch size by executing its real HLO artifacts through
//! PJRT on this machine's CPU.
//!
//! "Profiling only needs to be performed once for each hardware and batch
//! size pair and is re-used in subsequent runs of the Planner" — the
//! results are persisted as a [`ProfileSet`] JSON (hardware tier `cpu`).

use std::time::Instant;

use anyhow::Result;

use crate::hardware::Hardware;
use crate::profiler::{BatchProfile, ProfileSet};
use crate::runtime::{Manifest, ReplicaExecutor};

/// Measurement controls.
#[derive(Debug, Clone)]
pub struct ProfileOptions {
    pub warmup_runs: usize,
    pub measure_runs: usize,
    /// Cap on batch sizes to profile (None = all artifact sizes).
    pub max_batch: Option<usize>,
}

impl Default for ProfileOptions {
    fn default() -> Self {
        ProfileOptions { warmup_runs: 3, measure_runs: 9, max_batch: None }
    }
}

/// Profile one model across its artifact batch sizes. Returns measured
/// (batch, median latency seconds) points.
pub fn profile_model(
    manifest: &Manifest,
    model: &str,
    opts: &ProfileOptions,
) -> Result<BatchProfile> {
    let sizes = manifest.batch_sizes(model)?;
    let cap = opts.max_batch.unwrap_or(usize::MAX);
    let executor = ReplicaExecutor::new(manifest, model, sizes.iter().copied().max().unwrap_or(1))?;
    let mut points = Vec::new();
    for &b in sizes.iter().filter(|&&b| b <= cap) {
        for _ in 0..opts.warmup_runs {
            executor.run(b)?;
        }
        let mut times = Vec::with_capacity(opts.measure_runs);
        for _ in 0..opts.measure_runs {
            let t0 = Instant::now();
            executor.run(b)?;
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        points.push((b, median.max(1e-7)));
    }
    Ok(BatchProfile::new(points))
}

/// Profile every model in the manifest into a CPU-tier [`ProfileSet`].
pub fn profile_all(manifest: &Manifest, opts: &ProfileOptions) -> Result<ProfileSet> {
    let mut set = ProfileSet::default();
    for model in manifest.models.keys() {
        let profile = profile_model(manifest, model, opts)?;
        set.insert(model, Hardware::Cpu, profile);
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn profiles_have_positive_increasing_latency() {
        let Some(m) = manifest() else { return };
        let opts = ProfileOptions { warmup_runs: 1, measure_runs: 3, max_batch: Some(8) };
        let p = profile_model(&m, "tf_fast", &opts).unwrap();
        assert!(p.points.len() >= 3);
        assert!(p.points.iter().all(|&(_, l)| l > 0.0));
        // Throughput at batch 8 should beat batch 1 for a GEMM model.
        assert!(p.throughput(8) > p.throughput(1), "{:?}", p.points);
    }

    #[test]
    fn profile_all_covers_manifest() {
        let Some(m) = manifest() else { return };
        let opts = ProfileOptions { warmup_runs: 0, measure_runs: 1, max_batch: Some(2) };
        let set = profile_all(&m, &opts).unwrap();
        assert_eq!(set.models.len(), m.models.len());
    }
}
