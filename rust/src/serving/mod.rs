//! Physical serving plane (Clipper-like substrate, paper §3).
//!
//! * [`queue`] — centralized batched FIFO per stage;
//! * [`engine`] — pipeline DAG execution over replica worker threads with
//!   real PJRT model execution ([`Backend::Pjrt`]) or calibrated
//!   stand-ins for absent accelerator tiers ([`Backend::Calibrated`]);
//! * [`profile`] — the paper's Profiler measuring real per-model
//!   (batch → latency) curves through PJRT.
//!
//! The physical plane validates the Estimator's fidelity (Fig 8) and
//! powers the end-to-end examples; hour-long 300-QPS experiments run on
//! the virtual plane (`crate::simulator`) exactly as the paper's own
//! evaluation methodology prescribes (its Estimator is trusted after
//! validation, DESIGN.md §3).

pub mod engine;
pub mod profile;
pub mod queue;

pub use engine::{Backend, ServeResult, ServingEngine};
