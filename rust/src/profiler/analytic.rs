//! Paper-calibrated analytic profiles for the model zoo (DESIGN.md §3).
//!
//! Each (model, hardware) pair gets an affine batch-latency family
//! `L(b) = α + β·b` whose parameters are chosen to reproduce the *shapes*
//! the paper reports (Fig 3) and its headline ratios:
//!
//!  * `preprocess` has no internal parallelism: identical profile on every
//!    tier, flat throughput in batch size — the planner should park it on
//!    CPU (Fig 3 left; §2.1).
//!  * `resnet_lite` mirrors ResNet152: ~0.6 QPS on one CPU vs ~50.6 QPS on
//!    one K80 at batch 32 — the 84× CPU↔GPU gap of §2.1.
//!  * `nmt_lite` mirrors TF-NMT: benefits from GPU batching but at a steep
//!    latency cost (Fig 3 right).
//!  * `langid`/`tf_fast` are CPU-friendly models where a GPU barely helps —
//!    these give the planner real downgrade opportunities (§4.3 notes a
//!    language-id model downgrading from GPU to CPU drives the Fig 9 cost
//!    cliff).
//!
//! V100 numbers extend the catalog so the downgrade chain is 3 deep.

use super::{BatchProfile, ProfileSet};
use crate::hardware::Hardware;

/// (model, cpu(α,β), k80(α,β), v100(α,β)), batch caps per tier.
struct Family {
    model: &'static str,
    cpu: (f64, f64, usize),
    k80: (f64, f64, usize),
    v100: (f64, f64, usize),
}

const FAMILIES: &[Family] = &[
    Family {
        // No internal parallelism: same on every tier, flat throughput.
        model: "preprocess",
        cpu: (0.002, 0.006, 32),
        k80: (0.002, 0.006, 32),
        v100: (0.002, 0.006, 32),
    },
    Family {
        // ResNet152 analog: CPU 1 replica ≈ 0.62 QPS; K80 b=32 ≈ 51 QPS.
        model: "resnet_lite",
        cpu: (0.100, 1.500, 8),
        k80: (0.045, 0.018, 64),
        v100: (0.030, 0.0075, 64),
    },
    Family {
        // CPU-friendly small classifier; GPU offers little.
        model: "langid",
        cpu: (0.003, 0.0012, 32),
        k80: (0.0025, 0.0009, 32),
        v100: (0.002, 0.0008, 32),
    },
    Family {
        // TF-NMT analog: GPU batching helps but costs latency (Fig 3).
        model: "nmt_lite",
        cpu: (0.060, 0.250, 8),
        k80: (0.060, 0.018, 64),
        v100: (0.040, 0.008, 64),
    },
    Family {
        // Object detector root of Video Monitoring.
        model: "yolo_lite",
        cpu: (0.080, 0.600, 8),
        k80: (0.025, 0.012, 64),
        v100: (0.018, 0.005, 64),
    },
    Family {
        model: "idmodel_lite",
        cpu: (0.020, 0.120, 16),
        k80: (0.012, 0.006, 64),
        v100: (0.009, 0.003, 64),
    },
    Family {
        model: "alpr_lite",
        cpu: (0.030, 0.180, 16),
        k80: (0.015, 0.008, 64),
        v100: (0.011, 0.0035, 64),
    },
    Family {
        // Cascade fast stage: cheap, CPU-friendly (GPU never wins, like
        // preprocess — keeps the §9 total-ordering assumption intact).
        model: "tf_fast",
        cpu: (0.002, 0.0004, 32),
        k80: (0.003, 0.0005, 32),
        v100: (0.0025, 0.00045, 32),
    },
    Family {
        // Cascade slow stage: heavy, GPU-hungry.
        model: "tf_slow",
        cpu: (0.150, 0.900, 8),
        k80: (0.030, 0.010, 64),
        v100: (0.020, 0.004, 64),
    },
];

/// The full paper-calibrated profile set for the zoo.
pub fn paper_profiles() -> ProfileSet {
    let mut set = ProfileSet::default();
    for f in FAMILIES {
        let (a, b, cap) = f.cpu;
        set.insert(f.model, Hardware::Cpu, BatchProfile::affine(a, b, cap));
        let (a, b, cap) = f.k80;
        set.insert(f.model, Hardware::GpuK80, BatchProfile::affine(a, b, cap));
        let (a, b, cap) = f.v100;
        set.insert(f.model, Hardware::GpuV100, BatchProfile::affine(a, b, cap));
    }
    set
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_whole_zoo() {
        let set = paper_profiles();
        for model in [
            "preprocess", "resnet_lite", "langid", "nmt_lite", "yolo_lite",
            "idmodel_lite", "alpr_lite", "tf_fast", "tf_slow",
        ] {
            let mp = set.get(model);
            for hw in Hardware::ALL {
                assert!(mp.get(hw).is_some(), "{model} missing {hw}");
            }
        }
    }

    #[test]
    fn resnet_matches_paper_headline_gap() {
        let set = paper_profiles();
        let mp = set.get("resnet_lite");
        let cpu_thru = mp.get(Hardware::Cpu).unwrap().throughput(1);
        let k80_thru = mp.get(Hardware::GpuK80).unwrap().throughput(32);
        // Paper §2.1: 0.6 QPS CPU vs 50.6 QPS K80 — an 84x gap.
        assert!((cpu_thru - 0.6).abs() < 0.1, "cpu {cpu_thru}");
        assert!((k80_thru - 50.6).abs() < 3.0, "k80 {k80_thru}");
        let gap = k80_thru / cpu_thru;
        assert!(gap > 60.0 && gap < 110.0, "gap {gap}");
    }

    #[test]
    fn resnet_needs_batch_32_for_peak_k80_throughput() {
        // Paper §2.1: "ResNet152 required a batch size of 32 to maximize
        // throughput on the K80" (with diminishing returns beyond).
        let set = paper_profiles();
        let p = set.get("resnet_lite").get(Hardware::GpuK80).unwrap();
        let t32 = p.throughput(32);
        let t4 = p.throughput(4);
        assert!(t32 > 1.5 * t4, "batching should matter: {t4} -> {t32}");
    }

    #[test]
    fn preprocess_gets_no_gpu_benefit() {
        let set = paper_profiles();
        let mp = set.get("preprocess");
        let cpu = mp.get(Hardware::Cpu).unwrap();
        let k80 = mp.get(Hardware::GpuK80).unwrap();
        assert_eq!(cpu, k80);
        // Flat throughput: batching gains < 35% from b=1 to b=32
        // (alpha amortization only).
        assert!(cpu.throughput(32) < 1.35 * cpu.throughput(1));
        // Best hardware for it is the CPU (tie broken by cost).
        assert_eq!(mp.best_hardware(), Hardware::Cpu);
    }

    #[test]
    fn gpu_models_prefer_gpu() {
        let set = paper_profiles();
        for model in ["resnet_lite", "nmt_lite", "yolo_lite", "tf_slow"] {
            assert_ne!(
                set.get(model).best_hardware(),
                Hardware::Cpu,
                "{model} should prefer an accelerator"
            );
        }
    }

    #[test]
    fn total_latency_ordering_assumption_holds() {
        // Paper §9 limitation: the planner assumes a total ordering of
        // hardware latency across batch sizes. Our catalog satisfies it.
        let set = paper_profiles();
        for (name, mp) in &set.models {
            let mut tiers: Vec<_> = mp.per_hw.iter().collect();
            tiers.sort_by(|a, b| a.1.latency(1).partial_cmp(&b.1.latency(1)).unwrap());
            for pair in tiers.windows(2) {
                let (fast, slow) = (pair[0].1, pair[1].1);
                let cap = fast.max_batch().min(slow.max_batch());
                for b in super::super::BATCH_CANDIDATES.iter().filter(|&&b| b <= cap) {
                    assert!(
                        fast.latency(*b) <= slow.latency(*b) + 1e-9,
                        "{name}: ordering flips at batch {b}"
                    );
                }
            }
        }
    }
}
