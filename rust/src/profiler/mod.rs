//! Model performance profiles (paper §4.1).
//!
//! A profile captures, per (model, hardware) pair, batch-processing
//! latency as a function of batch size; throughput follows as b / L(b).
//! The Profiler measures each model *in isolation* — this is sound because
//! models are compute-intensive and side-effect free, so stage profiles
//! compose through the Estimator's queueing simulation (paper §8, last ¶).
//!
//! Two sources feed profiles:
//!  * [`analytic`] — the paper-calibrated profile families for the zoo on
//!    CPU / K80 / V100 tiers (DESIGN.md §3 substitution);
//!  * the empirical PJRT profiler in `crate::serving::profiler_physical`
//!    which measures the real HLO executables on this machine's CPU.

pub mod analytic;

use std::collections::BTreeMap;

use crate::hardware::Hardware;
use crate::util::json::Json;

/// Batch sizes the planner may assign (powers of two, paper §4.3:
/// "the batch size is increased by factors of two").
pub const BATCH_CANDIDATES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Latency-vs-batch profile of one model on one hardware tier.
///
/// Stored as measured points `(batch, seconds)`; queries interpolate
/// linearly between points (batch latency curves are near-affine — Fig 3)
/// and extrapolate the final slope beyond the largest point.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchProfile {
    /// Sorted by batch size; non-empty; latencies strictly positive.
    pub points: Vec<(usize, f64)>,
}

impl BatchProfile {
    pub fn new(mut points: Vec<(usize, f64)>) -> Self {
        assert!(!points.is_empty(), "empty profile");
        points.sort_by_key(|p| p.0);
        points.dedup_by_key(|p| p.0);
        assert!(points.iter().all(|&(b, l)| b > 0 && l > 0.0), "bad profile point");
        BatchProfile { points }
    }

    /// Affine profile L(b) = alpha + beta * b sampled at the candidate
    /// batch sizes up to `max_batch`.
    pub fn affine(alpha: f64, beta: f64, max_batch: usize) -> Self {
        let points = BATCH_CANDIDATES
            .iter()
            .copied()
            .filter(|&b| b <= max_batch)
            .map(|b| (b, alpha + beta * b as f64))
            .collect();
        BatchProfile::new(points)
    }

    /// Largest profiled batch size (the planner will not exceed it).
    pub fn max_batch(&self) -> usize {
        self.points.last().unwrap().0
    }

    /// Batch-processing latency in seconds for a batch of `b` queries.
    pub fn latency(&self, b: usize) -> f64 {
        assert!(b > 0);
        let pts = &self.points;
        if b <= pts[0].0 {
            // Profiles always include batch 1 in practice; for a smaller
            // batch than the smallest point, the point's latency is a
            // conservative (safe) upper bound.
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let ((b0, l0), (b1, l1)) = (w[0], w[1]);
            if b <= b1 {
                let frac = (b - b0) as f64 / (b1 - b0) as f64;
                return l0 + frac * (l1 - l0);
            }
        }
        // Extrapolate using the last segment's slope.
        let n = pts.len();
        let (b0, l0) = pts[n - 2];
        let (b1, l1) = pts[n - 1];
        let slope = (l1 - l0) / (b1 - b0) as f64;
        l1 + slope * (b - b1) as f64
    }

    /// Steady-state throughput (queries/sec) of one replica at batch `b`.
    pub fn throughput(&self, b: usize) -> f64 {
        b as f64 / self.latency(b)
    }

    /// Max throughput over candidate batch sizes (the μ_m the Tuner uses).
    pub fn max_throughput(&self) -> f64 {
        BATCH_CANDIDATES
            .iter()
            .copied()
            .filter(|&b| b <= self.max_batch())
            .map(|b| self.throughput(b))
            .fold(0.0, f64::max)
    }
}

/// Profiles of one model across hardware tiers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelProfile {
    pub per_hw: BTreeMap<Hardware, BatchProfile>,
}

impl ModelProfile {
    pub fn get(&self, hw: Hardware) -> Option<&BatchProfile> {
        self.per_hw.get(&hw)
    }

    /// Lowest-latency hardware at batch size 1 (Algorithm 1's
    /// `BestHardware`). Ties break toward the cheaper tier.
    pub fn best_hardware(&self) -> Hardware {
        *self
            .per_hw
            .iter()
            .min_by(|(ha, pa), (hb, pb)| {
                pa.latency(1)
                    .partial_cmp(&pb.latency(1))
                    .unwrap()
                    .then(ha.cost_per_hour().partial_cmp(&hb.cost_per_hour()).unwrap())
            })
            .expect("model has no profiles")
            .0
    }

    /// Hardware tiers cheaper than `hw` that have a profile, costliest
    /// first (the downgrade search order).
    pub fn downgrades_from(&self, hw: Hardware) -> Vec<Hardware> {
        let mut out = Vec::new();
        let mut cur = hw;
        while let Some(next) = cur.downgrade() {
            if self.per_hw.contains_key(&next) {
                out.push(next);
            }
            cur = next;
        }
        out
    }
}

/// Profiles for every model referenced by a pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProfileSet {
    pub models: BTreeMap<String, ModelProfile>,
}

impl ProfileSet {
    pub fn get(&self, model: &str) -> &ModelProfile {
        self.models
            .get(model)
            .unwrap_or_else(|| panic!("no profile for model {model:?}"))
    }

    pub fn insert(&mut self, model: &str, hw: Hardware, profile: BatchProfile) {
        self.models
            .entry(model.to_string())
            .or_default()
            .per_hw
            .insert(hw, profile);
    }

    pub fn to_json(&self) -> Json {
        let mut models = Json::obj();
        for (name, mp) in &self.models {
            let mut hw_obj = Json::obj();
            for (hw, bp) in &mp.per_hw {
                let pts: Vec<Json> = bp
                    .points
                    .iter()
                    .map(|&(b, l)| Json::Arr(vec![Json::Num(b as f64), Json::Num(l)]))
                    .collect();
                hw_obj.set(hw.id(), Json::Arr(pts));
            }
            models.set(name, hw_obj);
        }
        let mut root = Json::obj();
        root.set("models", models);
        root
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let mut set = ProfileSet::default();
        let models = v.req("models").as_obj().ok_or("models must be object")?;
        for (name, hw_obj) in models {
            for (hw_id, pts) in hw_obj.as_obj().ok_or("hw map must be object")? {
                let hw = Hardware::from_id(hw_id).ok_or_else(|| format!("bad hw {hw_id}"))?;
                let points = pts
                    .as_arr()
                    .ok_or("points must be array")?
                    .iter()
                    .map(|p| {
                        let a = p.as_arr().ok_or("point must be [b, l]")?;
                        Ok((
                            a[0].as_usize().ok_or("batch")?,
                            a[1].as_f64().ok_or("latency")?,
                        ))
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                set.insert(name, hw, BatchProfile::new(points));
            }
        }
        Ok(set)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affine_profile_latency_and_throughput() {
        let p = BatchProfile::affine(0.010, 0.002, 32);
        assert!((p.latency(1) - 0.012).abs() < 1e-12);
        assert!((p.latency(32) - 0.074).abs() < 1e-12);
        // Interpolation at a non-candidate batch.
        assert!((p.latency(3) - 0.016).abs() < 1e-12);
        // Extrapolation beyond the table keeps the slope.
        assert!((p.latency(64) - 0.138).abs() < 1e-9);
        assert!(p.throughput(32) > p.throughput(1));
    }

    #[test]
    fn throughput_has_diminishing_returns() {
        let p = BatchProfile::affine(0.05, 0.001, 64);
        let gains: Vec<f64> = [1usize, 2, 4, 8, 16, 32]
            .windows(2)
            .map(|w| p.throughput(w[1]) / p.throughput(w[0]))
            .collect();
        for pair in gains.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-9, "gains should shrink: {gains:?}");
        }
    }

    #[test]
    fn max_throughput_picks_best_batch() {
        let p = BatchProfile::affine(0.05, 0.001, 32);
        assert!((p.max_throughput() - p.throughput(32)).abs() < 1e-12);
    }

    #[test]
    fn best_hardware_prefers_lower_latency() {
        let mut mp = ModelProfile::default();
        mp.per_hw.insert(Hardware::Cpu, BatchProfile::affine(0.2, 0.1, 32));
        mp.per_hw.insert(Hardware::GpuK80, BatchProfile::affine(0.01, 0.002, 32));
        assert_eq!(mp.best_hardware(), Hardware::GpuK80);
    }

    #[test]
    fn best_hardware_tie_breaks_cheaper() {
        let mut mp = ModelProfile::default();
        mp.per_hw.insert(Hardware::Cpu, BatchProfile::affine(0.01, 0.002, 32));
        mp.per_hw.insert(Hardware::GpuK80, BatchProfile::affine(0.01, 0.002, 32));
        assert_eq!(mp.best_hardware(), Hardware::Cpu);
    }

    #[test]
    fn downgrade_order() {
        let mut mp = ModelProfile::default();
        for hw in Hardware::ALL {
            mp.per_hw.insert(hw, BatchProfile::affine(0.01, 0.001, 32));
        }
        assert_eq!(
            mp.downgrades_from(Hardware::GpuV100),
            vec![Hardware::GpuK80, Hardware::Cpu]
        );
        assert!(mp.downgrades_from(Hardware::Cpu).is_empty());
    }

    #[test]
    fn profile_set_json_roundtrip() {
        let mut set = ProfileSet::default();
        set.insert("resnet", Hardware::GpuK80, BatchProfile::affine(0.045, 0.018, 32));
        set.insert("resnet", Hardware::Cpu, BatchProfile::affine(0.1, 1.5, 8));
        let j = set.to_json();
        assert_eq!(ProfileSet::from_json(&j).unwrap(), set);
    }

    #[test]
    fn profile_set_file_roundtrip() {
        let mut set = ProfileSet::default();
        set.insert("m", Hardware::Cpu, BatchProfile::affine(0.01, 0.001, 16));
        let dir = std::env::temp_dir().join("inferline-test-profiles");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        set.save(&path).unwrap();
        assert_eq!(ProfileSet::load(&path).unwrap(), set);
    }

    #[test]
    #[should_panic(expected = "no profile")]
    fn missing_model_panics() {
        ProfileSet::default().get("ghost");
    }
}
