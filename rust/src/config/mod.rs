//! Pipeline specifications and per-stage configurations.
//!
//! A prediction pipeline is a DAG of stages (paper §2): each vertex is a
//! model (served by the underlying prediction-serving framework), each
//! edge is dataflow. Conditional control flow is captured by per-stage
//! *scale factors* s_m — the unconditional probability that a query
//! entering the pipeline visits stage m (paper §4.1).
//!
//! A [`PipelineConfig`] assigns the planner's three control dimensions to
//! every stage: hardware type, maximum batch size, replication factor.

use crate::hardware::Hardware;
use crate::util::json::Json;

/// The underlying prediction-serving framework personality (paper §7.4).
/// InferLine composes with any framework meeting its three requirements;
/// the personalities differ only in per-hop RPC/serialization overhead
/// (the paper observes TFS costs slightly more "due to some additional
/// RPC serialization overheads not present in Clipper").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    Clipper,
    TfServing,
}

impl Framework {
    /// Per stage-to-stage hop overhead (seconds) added to query transfer.
    pub fn rpc_overhead(self) -> f64 {
        match self {
            Framework::Clipper => 0.0010,
            Framework::TfServing => 0.0028,
        }
    }

    pub fn id(self) -> &'static str {
        match self {
            Framework::Clipper => "clipper",
            Framework::TfServing => "tf-serving",
        }
    }
}

/// One vertex of the pipeline DAG.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Human-readable stage name (unique within the pipeline).
    pub name: String,
    /// Model-zoo name: keys profiles and HLO artifacts (`<model>_b<B>`).
    pub model: String,
    /// Unconditional probability a pipeline query visits this stage.
    pub scale_factor: f64,
    /// Indices of downstream stages fed by this stage's output.
    pub children: Vec<usize>,
}

/// A prediction pipeline DAG.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub name: String,
    pub stages: Vec<StageSpec>,
    /// Entry stages (every query visits all roots; roots have s = 1).
    pub roots: Vec<usize>,
    pub framework: Framework,
}

impl PipelineSpec {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Validate DAG shape and scale-factor coherence. Called by
    /// constructors and by config loading.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("pipeline has no stages".into());
        }
        if self.roots.is_empty() {
            return Err("pipeline has no roots".into());
        }
        for &r in &self.roots {
            if r >= self.stages.len() {
                return Err(format!("root {r} out of range"));
            }
            if (self.stages[r].scale_factor - 1.0).abs() > 1e-9 {
                return Err(format!("root stage {} must have s = 1", self.stages[r].name));
            }
        }
        let mut indegree = vec![0usize; self.stages.len()];
        for (i, s) in self.stages.iter().enumerate() {
            if !(0.0..=1.0).contains(&s.scale_factor) || s.scale_factor == 0.0 {
                return Err(format!("stage {} scale factor {} out of (0,1]", s.name, s.scale_factor));
            }
            for &c in &s.children {
                if c >= self.stages.len() {
                    return Err(format!("stage {} child {c} out of range", s.name));
                }
                if c == i {
                    return Err(format!("stage {} is its own child", s.name));
                }
                indegree[c] += 1;
                if self.stages[c].scale_factor > s.scale_factor + 1e-9 {
                    return Err(format!(
                        "child {} scale factor exceeds parent {}",
                        self.stages[c].name, s.name
                    ));
                }
            }
        }
        for &r in &self.roots {
            if indegree[r] != 0 {
                return Err(format!("root {} has a parent", self.stages[r].name));
            }
        }
        // Tree-shaped conditional DAGs: at most one parent per stage keeps
        // branch probabilities well-defined (s_child / s_parent).
        for (i, d) in indegree.iter().enumerate() {
            if *d > 1 {
                return Err(format!("stage {} has {d} parents (tree DAGs only)", self.stages[i].name));
            }
            if *d == 0 && !self.roots.contains(&i) {
                return Err(format!("stage {} unreachable", self.stages[i].name));
            }
        }
        // Acyclicity: BFS from roots must visit every stage exactly once
        // (guaranteed by tree shape + reachability above, but verify).
        let mut seen = vec![false; self.stages.len()];
        let mut work: Vec<usize> = self.roots.clone();
        while let Some(i) = work.pop() {
            if seen[i] {
                return Err(format!("cycle through stage {}", self.stages[i].name));
            }
            seen[i] = true;
            work.extend(&self.stages[i].children);
        }
        if !seen.iter().all(|&s| s) {
            return Err("disconnected stages".into());
        }
        Ok(())
    }

    /// Conditional probability of traversing the edge parent -> child,
    /// i.e. P(visit child | visit parent) = s_child / s_parent.
    pub fn edge_probability(&self, parent: usize, child: usize) -> f64 {
        (self.stages[child].scale_factor / self.stages[parent].scale_factor).min(1.0)
    }

    /// All root-to-leaf paths (stage index sequences). Used for the
    /// worst-case service time of Algorithm 1.
    pub fn paths(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for &r in &self.roots {
            let mut stack = vec![(r, vec![r])];
            while let Some((i, path)) = stack.pop() {
                if self.stages[i].children.is_empty() {
                    out.push(path);
                } else {
                    for &c in &self.stages[i].children {
                        let mut p = path.clone();
                        p.push(c);
                        stack.push((c, p));
                    }
                }
            }
        }
        out
    }

    pub fn stage_index(&self, name: &str) -> Option<usize> {
        self.stages.iter().position(|s| s.name == name)
    }
}

/// Control parameters for one stage: the planner's three dimensions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageConfig {
    pub hw: Hardware,
    /// Maximum batch size the centralized queue hands one replica.
    pub batch: usize,
    pub replicas: usize,
}

/// A full pipeline configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    pub stages: Vec<StageConfig>,
}

impl PipelineConfig {
    /// Uniform starting configuration.
    pub fn uniform(n: usize, hw: Hardware, batch: usize, replicas: usize) -> Self {
        PipelineConfig { stages: vec![StageConfig { hw, batch, replicas }; n] }
    }

    /// $/hour of the configuration: Σ replicas × device cost (paper §4.3 —
    /// batch size does not affect cost).
    pub fn cost_per_hour(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| s.replicas as f64 * s.hw.cost_per_hour())
            .sum()
    }

    pub fn total_replicas(&self) -> usize {
        self.stages.iter().map(|s| s.replicas).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.stages
                .iter()
                .map(|s| {
                    let mut o = Json::obj();
                    o.set("hw", s.hw.id())
                        .set("batch", s.batch)
                        .set("replicas", s.replicas);
                    o
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let arr = v.as_arr().ok_or("config must be an array")?;
        let stages = arr
            .iter()
            .map(|s| {
                Ok(StageConfig {
                    hw: Hardware::from_id(s.req("hw").as_str().ok_or("hw")?)
                        .ok_or("unknown hw")?,
                    batch: s.req("batch").as_usize().ok_or("batch")?,
                    replicas: s.req("replicas").as_usize().ok_or("replicas")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(PipelineConfig { stages })
    }

    /// Compact single-line description for logs and experiment output.
    pub fn summary(&self, spec: &PipelineSpec) -> String {
        self.stages
            .iter()
            .zip(&spec.stages)
            .map(|(c, s)| format!("{}[{} b{} x{}]", s.name, c.hw, c.batch, c.replicas))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

pub mod pipelines;

#[cfg(test)]
mod tests {
    use super::*;

    fn linear_spec() -> PipelineSpec {
        PipelineSpec {
            name: "lin".into(),
            stages: vec![
                StageSpec { name: "a".into(), model: "m0".into(), scale_factor: 1.0, children: vec![1] },
                StageSpec { name: "b".into(), model: "m1".into(), scale_factor: 0.5, children: vec![] },
            ],
            roots: vec![0],
            framework: Framework::Clipper,
        }
    }

    #[test]
    fn validate_accepts_linear() {
        linear_spec().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_root_scale() {
        let mut s = linear_spec();
        s.stages[0].scale_factor = 0.9;
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_child_scale_above_parent() {
        let mut s = linear_spec();
        s.stages[1].scale_factor = 1.0;
        s.stages[0].scale_factor = 1.0;
        s.validate().unwrap(); // equal is fine
        s.stages[0].children = vec![1];
        s.stages[1].scale_factor = 1.1;
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_cycles() {
        let mut s = linear_spec();
        s.stages[1].children = vec![0];
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_unreachable() {
        let mut s = linear_spec();
        s.stages.push(StageSpec {
            name: "z".into(),
            model: "m2".into(),
            scale_factor: 0.5,
            children: vec![],
        });
        assert!(s.validate().is_err());
    }

    #[test]
    fn edge_probability_is_conditional() {
        let s = linear_spec();
        assert!((s.edge_probability(0, 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn paths_enumeration() {
        let spec = pipelines::video_monitoring();
        let mut paths = spec.paths();
        paths.sort();
        assert_eq!(paths.len(), 2);
        assert!(paths.iter().all(|p| p[0] == 0 && p.len() == 2));
    }

    #[test]
    fn cost_model() {
        let c = PipelineConfig {
            stages: vec![
                StageConfig { hw: Hardware::Cpu, batch: 1, replicas: 2 },
                StageConfig { hw: Hardware::GpuK80, batch: 8, replicas: 3 },
            ],
        };
        assert!((c.cost_per_hour() - (2.0 * 0.05 + 3.0 * 0.70)).abs() < 1e-12);
        assert_eq!(c.total_replicas(), 5);
    }

    #[test]
    fn config_json_roundtrip() {
        let c = PipelineConfig::uniform(3, Hardware::GpuK80, 4, 2);
        let j = c.to_json();
        assert_eq!(PipelineConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn all_paper_pipelines_validate() {
        for spec in [
            pipelines::image_processing(),
            pipelines::video_monitoring(),
            pipelines::social_media(),
            pipelines::tf_cascade(),
        ] {
            spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        }
    }
}
