//! The four paper pipelines (Fig 2), expressed over the model zoo.
//!
//! Scale factors follow the paper's conditional-evaluation pattern: in
//! Video Monitoring, Social Media and TF Cascade a subset of models is
//! invoked based on earlier models' outputs (paper §2).

use super::{Framework, PipelineSpec, StageSpec};

fn stage(name: &str, model: &str, s: f64, children: Vec<usize>) -> StageSpec {
    StageSpec { name: name.into(), model: model.into(), scale_factor: s, children }
}

/// Fig 2(a): basic image pre-processing followed by DNN classification.
pub fn image_processing() -> PipelineSpec {
    PipelineSpec {
        name: "image-processing".into(),
        stages: vec![
            stage("preprocess", "preprocess", 1.0, vec![1]),
            stage("classify", "resnet_lite", 1.0, vec![]),
        ],
        roots: vec![0],
        framework: Framework::Clipper,
    }
}

/// Fig 2(b): object detection feeding conditional vehicle/person
/// identification and license-plate extraction branches (inspired by
/// VideoStorm workloads).
pub fn video_monitoring() -> PipelineSpec {
    PipelineSpec {
        name: "video-monitoring".into(),
        stages: vec![
            stage("detect", "yolo_lite", 1.0, vec![1, 2]),
            stage("identify", "idmodel_lite", 0.4, vec![]),
            stage("alpr", "alpr_lite", 0.25, vec![]),
        ],
        roots: vec![0],
        framework: Framework::Clipper,
    }
}

/// Fig 2(c): translate + categorize posts from text and linked images;
/// translation runs only for non-English posts, the vision model only for
/// posts with images.
pub fn social_media() -> PipelineSpec {
    PipelineSpec {
        name: "social-media".into(),
        stages: vec![
            stage("langid", "langid", 1.0, vec![1, 3]),
            stage("translate", "nmt_lite", 0.4, vec![2]),
            stage("categorize", "tf_fast", 0.4, vec![]),
            stage("image-class", "resnet_lite", 0.5, vec![]),
        ],
        roots: vec![0],
        framework: Framework::Clipper,
    }
}

/// Fig 2(d): fast model always; slow model invoked only on low-confidence
/// queries (cascade pattern).
pub fn tf_cascade() -> PipelineSpec {
    PipelineSpec {
        name: "tf-cascade".into(),
        stages: vec![
            stage("fast", "tf_fast", 1.0, vec![1]),
            stage("slow", "tf_slow", 0.3, vec![]),
        ],
        roots: vec![0],
        framework: Framework::Clipper,
    }
}

/// All four, for sweep drivers.
pub fn all() -> Vec<PipelineSpec> {
    vec![image_processing(), video_monitoring(), social_media(), tf_cascade()]
}

/// Look up a pipeline by CLI name.
pub fn by_name(name: &str) -> Option<PipelineSpec> {
    all().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_finds_all() {
        for p in all() {
            assert_eq!(by_name(&p.name).unwrap().name, p.name);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn conditional_pipelines_have_sub_unity_branches() {
        for p in [video_monitoring(), social_media(), tf_cascade()] {
            assert!(
                p.stages.iter().any(|s| s.scale_factor < 1.0),
                "{} should have conditional stages",
                p.name
            );
        }
    }
}
