//! Deterministic fault injection for the simulator: seed-derived chaos
//! plans threaded through the event core.
//!
//! Two layers, mirroring the scenario subsystem
//! ([`crate::workload::scenarios`]):
//!
//! * [`FaultSpec`] — the declarative, JSON-loadable description: crash
//!   instants, seed-expanded crash storms, transient per-stage slowdowns
//!   and correlated stage outages, plus the recovery-policy knobs
//!   (`max_retries`, `shed_after`).
//! * [`FaultPlan`] — the compiled, time-sorted injection list the engine
//!   consumes ([`FaultSpec::compile`]). Compilation is bit-deterministic
//!   in (spec, stage count, seed): storms expand through a dedicated
//!   [`Rng`] stream per node (derived via
//!   [`child_seed`](crate::workload::scenarios::child_seed)), so the same
//!   inputs always yield the same plan, byte for byte — property-tested
//!   in `tests/simulator_props.rs`.
//!
//! The engine contract is strict: an **empty plan injects nothing**. A
//! run with [`FaultPlan::default()`] (or a spec with no events and no
//! shed policy) pushes zero fault events and takes zero fault branches,
//! so it is bit-identical to a run without fault plumbing at all — the
//! invariant the conformance suites assert across the whole grid.
//!
//! Recovery semantics live in the engine (`simulator::engine`): a crashed
//! replica's in-flight batch is requeued at the head of its stage queue
//! in original order (bounded by `max_retries` per query, then shed);
//! replacement capacity is the *controller's* job — the Tuner restores a
//! crashed stage to the Planner's floor, paying the normal
//! `replica_activation_delay`, while open-loop and null-controlled runs
//! stay degraded (degraded-mode serving, not silent wedging: a crash
//! never removes a stage's last replica — total stage death is modeled
//! by `outage` windows, which always end). Queries older than
//! `shed_after` seconds are dropped at dispatch time instead of wasting
//! batch slots they can no longer use; sheds are counted separately from
//! SLO misses.
//!
//! ## JSON schema (`"faults"` node of a scenario spec, or a standalone doc)
//!
//! ```json
//! {
//!   "max_retries": 2,
//!   "shed_after": 1.5,
//!   "events": [
//!     { "kind": "crash", "stage": 1, "time": 120 },
//!     { "kind": "crash_storm", "start": 60, "end": 180, "rate": 0.2 },
//!     { "kind": "slowdown", "stage": 0, "start": 200, "end": 260, "factor": 3 },
//!     { "kind": "outage", "stage": 2, "start": 300, "end": 315 }
//!   ]
//! }
//! ```
//!
//! Event kinds (fields beyond `kind`):
//!
//! | kind          | fields                                                      |
//! |---------------|-------------------------------------------------------------|
//! | `crash`       | `stage`, `time`                                             |
//! | `crash_storm` | `stage`? (absent = random stage per crash), `start`, `end`, `rate` (crashes/s) |
//! | `slowdown`    | `stage`, `start`, `end`, `factor` (>= 1, batch-latency multiplier) |
//! | `outage`      | `stage`, `start`, `end`                                     |
//!
//! `stage` indices are clamped to the served pipeline's stage count at
//! compile time, so one chaos family can run against pipelines of
//! different widths (the robustness matrix does exactly that). Parse
//! errors name the offending node by its path from the document root
//! (`faults.events[1]: ...`), matching the scenario-spec convention.

use std::path::Path;

use crate::util::json::{opt_f64_at, req_f64_at as req_num, Json};
use crate::util::rng::Rng;
use crate::workload::scenarios::child_seed;

/// One declarative fault node of a [`FaultSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum FaultNode {
    /// Kill one replica of `stage` at `time`.
    Crash { stage: usize, time: f64 },
    /// Poisson rain of crashes at `rate` per second over `[start, end)`,
    /// each hitting `stage` (or a seed-derived random stage when absent).
    CrashStorm { stage: Option<usize>, start: f64, end: f64, rate: f64 },
    /// Multiply `stage`'s batch latencies by `factor` over `[start, end)`
    /// (batches already in flight keep their scheduled completion).
    Slowdown { stage: usize, start: f64, end: f64, factor: f64 },
    /// Freeze dispatch at `stage` over `[start, end)`: queries queue but
    /// no batch starts (correlated whole-stage unavailability).
    Outage { stage: usize, start: f64, end: f64 },
}

/// Declarative fault-injection spec: the JSON-loadable unit, parallel to
/// [`crate::workload::scenarios::ScenarioSpec`]. Compile with
/// [`Self::compile`] to get the engine-ready [`FaultPlan`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub nodes: Vec<FaultNode>,
    /// Times a crashed batch's queries are requeued before being shed.
    pub max_retries: u32,
    /// Deadline-shed policy: drop queries older than this many seconds at
    /// dispatch time (None = never shed).
    pub shed_after: Option<f64>,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { nodes: Vec::new(), max_retries: Self::DEFAULT_MAX_RETRIES, shed_after: None }
    }
}

/// Range check at parse time (same convention as the scenario parser):
/// malformed-but-numeric specs surface as path-named CLI errors instead
/// of generator assertions.
fn check(cond: bool, path: &str, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(format!("{path}: out of range: {what}"))
    }
}

fn opt_num(node: &Json, key: &str, default: f64, path: &str) -> Result<f64, String> {
    match node.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("{path}: field {key:?} must be a number")),
    }
}

fn req_stage(node: &Json, path: &str) -> Result<usize, String> {
    let s = req_num(node, "stage", path)?;
    check(
        s >= 0.0 && s.fract() == 0.0,
        path,
        "stage must be a non-negative integer",
    )?;
    Ok(s as usize)
}

/// Shared `start` / `end` window of the interval kinds.
fn req_window(node: &Json, path: &str, kind: &str) -> Result<(f64, f64), String> {
    let start = req_num(node, "start", path)?;
    let end = req_num(node, "end", path)?;
    check(start >= 0.0, path, &format!("{kind} start must be >= 0"))?;
    check(end > start, path, &format!("{kind} end must be > start"))?;
    Ok((start, end))
}

impl FaultSpec {
    /// Default retry bound for a crashed batch's queries.
    pub const DEFAULT_MAX_RETRIES: u32 = 2;

    /// Parse a faults node (see the module docs for the schema). Errors
    /// name the offending node by its path from the document root.
    pub fn parse_at(node: &Json, path: &str) -> Result<FaultSpec, String> {
        let max_retries = opt_num(node, "max_retries", Self::DEFAULT_MAX_RETRIES as f64, path)?;
        check(
            max_retries >= 0.0 && max_retries.fract() == 0.0,
            path,
            "max_retries must be a non-negative integer",
        )?;
        let shed_after = opt_f64_at(node, "shed_after", path)?;
        check(
            shed_after.map_or(true, |s| s > 0.0),
            path,
            "shed_after must be > 0",
        )?;
        let nodes = match node.get("events") {
            None => Vec::new(),
            Some(events) => {
                let arr = events
                    .as_arr()
                    .ok_or_else(|| format!("{path}: field \"events\" must be an array"))?;
                arr.iter()
                    .enumerate()
                    .map(|(i, ev)| Self::parse_event(ev, &format!("{path}.events[{i}]")))
                    .collect::<Result<Vec<_>, _>>()?
            }
        };
        Ok(FaultSpec { nodes, max_retries: max_retries as u32, shed_after })
    }

    fn parse_event(node: &Json, path: &str) -> Result<FaultNode, String> {
        let kind = node
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{path}: missing string field \"kind\""))?;
        match kind {
            "crash" => {
                let stage = req_stage(node, path)?;
                let time = req_num(node, "time", path)?;
                check(time >= 0.0, path, "crash time must be >= 0")?;
                Ok(FaultNode::Crash { stage, time })
            }
            "crash_storm" => {
                let stage = match node.get("stage") {
                    None => None,
                    Some(_) => Some(req_stage(node, path)?),
                };
                let (start, end) = req_window(node, path, "crash_storm")?;
                let rate = req_num(node, "rate", path)?;
                check(rate > 0.0, path, "crash_storm rate must be > 0")?;
                Ok(FaultNode::CrashStorm { stage, start, end, rate })
            }
            "slowdown" => {
                let stage = req_stage(node, path)?;
                let (start, end) = req_window(node, path, "slowdown")?;
                let factor = req_num(node, "factor", path)?;
                check(factor >= 1.0, path, "slowdown factor must be >= 1")?;
                Ok(FaultNode::Slowdown { stage, start, end, factor })
            }
            "outage" => {
                let stage = req_stage(node, path)?;
                let (start, end) = req_window(node, path, "outage")?;
                Ok(FaultNode::Outage { stage, start, end })
            }
            other => Err(format!("{path}: unknown fault kind {other:?}")),
        }
    }

    /// Parse a standalone document: either a bare faults object or a doc
    /// carrying a top-level `"faults"` node (a full scenario spec works).
    pub fn parse_str(text: &str) -> Result<FaultSpec, String> {
        let doc = Json::parse(text)?;
        let node = doc.get("faults").unwrap_or(&doc);
        Self::parse_at(node, "faults")
    }

    /// Load a standalone spec file (see [`Self::parse_str`]).
    pub fn load(path: &Path) -> Result<FaultSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse_str(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Compress the fault *schedule* by `factor` (< 1 shortens), the same
    /// transform quick (CI) mode applies to the arrival schedule
    /// ([`crate::workload::scenarios::Scenario::scaled`]): crash times and
    /// interval bounds scale, storm rates divide (preserving the expected
    /// crash count per storm). `shed_after` is a latency bound relative
    /// to the SLO, not a schedule time, so it is left untouched.
    pub fn scaled(&self, factor: f64) -> FaultSpec {
        assert!(factor > 0.0, "scale factor {factor}");
        let nodes = self
            .nodes
            .iter()
            .map(|n| match *n {
                FaultNode::Crash { stage, time } => {
                    FaultNode::Crash { stage, time: time * factor }
                }
                FaultNode::CrashStorm { stage, start, end, rate } => FaultNode::CrashStorm {
                    stage,
                    start: start * factor,
                    end: end * factor,
                    rate: rate / factor,
                },
                FaultNode::Slowdown { stage, start, end, factor: f } => FaultNode::Slowdown {
                    stage,
                    start: start * factor,
                    end: end * factor,
                    factor: f,
                },
                FaultNode::Outage { stage, start, end } => FaultNode::Outage {
                    stage,
                    start: start * factor,
                    end: end * factor,
                },
            })
            .collect();
        FaultSpec { nodes, max_retries: self.max_retries, shed_after: self.shed_after }
    }

    /// Compile into the engine-ready, time-sorted [`FaultPlan`] for a
    /// pipeline with `n_stages` stages. Deterministic in (self, n_stages,
    /// seed): each storm node expands through its own seeded stream
    /// (`child_seed(seed, node_index)`), drawing the crash time and then
    /// (when the node names no stage) the stage. Stage indices are
    /// clamped into range so one spec serves pipelines of any width.
    pub fn compile(&self, n_stages: usize, seed: u64) -> FaultPlan {
        assert!(n_stages > 0, "compile needs at least one stage");
        let clamp = |s: usize| s.min(n_stages - 1) as u16;
        let mut entries = Vec::new();
        for (idx, node) in self.nodes.iter().enumerate() {
            match *node {
                FaultNode::Crash { stage, time } => {
                    entries.push(FaultEntry {
                        time,
                        action: FaultAction::Crash { stage: clamp(stage) },
                    });
                }
                FaultNode::CrashStorm { stage, start, end, rate } => {
                    let mut rng = Rng::new(child_seed(seed, idx as u64));
                    let mut t = start;
                    loop {
                        t += rng.exp(rate);
                        if t >= end {
                            break;
                        }
                        let s = match stage {
                            Some(s) => clamp(s),
                            None => rng.usize(n_stages) as u16,
                        };
                        entries.push(FaultEntry {
                            time: t,
                            action: FaultAction::Crash { stage: s },
                        });
                    }
                }
                FaultNode::Slowdown { stage, start, end, factor } => {
                    let s = clamp(stage);
                    entries.push(FaultEntry {
                        time: start,
                        action: FaultAction::SlowdownStart { stage: s, factor },
                    });
                    entries.push(FaultEntry {
                        time: end,
                        action: FaultAction::SlowdownEnd { stage: s },
                    });
                }
                FaultNode::Outage { stage, start, end } => {
                    let s = clamp(stage);
                    entries.push(FaultEntry {
                        time: start,
                        action: FaultAction::OutageStart { stage: s },
                    });
                    entries.push(FaultEntry {
                        time: end,
                        action: FaultAction::OutageEnd { stage: s },
                    });
                }
            }
        }
        // Stable sort: simultaneous faults keep spec order.
        entries.sort_by(|a, b| a.time.total_cmp(&b.time));
        FaultPlan { entries, max_retries: self.max_retries, shed_after: self.shed_after }
    }
}

/// One compiled injection the engine applies at `time`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEntry {
    pub time: f64,
    pub action: FaultAction,
}

/// The engine-level fault actions a [`FaultEntry`] carries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Kill one replica of `stage` (prefers a busy one; its in-flight
    /// batch is requeued — see the engine's crash handler).
    Crash { stage: u16 },
    /// Begin multiplying `stage`'s batch latencies by `factor`.
    SlowdownStart { stage: u16, factor: f64 },
    /// Restore `stage` to nominal batch latency.
    SlowdownEnd { stage: u16 },
    /// Freeze dispatch at `stage`.
    OutageStart { stage: u16 },
    /// Unfreeze dispatch at `stage` (outages may nest; dispatch resumes
    /// when the last one ends).
    OutageEnd { stage: u16 },
}

/// A compiled, time-sorted fault schedule plus the recovery-policy knobs.
/// [`Self::is_empty`] is the engine's zero-overhead gate: an empty plan
/// activates no fault plumbing at all.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Injections in non-decreasing time order.
    pub entries: Vec<FaultEntry>,
    /// Times a crashed batch's queries are requeued before being shed.
    pub max_retries: u32,
    /// Deadline-shed bound in seconds (None = never shed).
    pub shed_after: Option<f64>,
}

impl FaultPlan {
    /// True when the plan changes nothing: no injections and no shed
    /// policy. The engine treats such a plan exactly like no plan.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.shed_after.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn storm_spec() -> FaultSpec {
        FaultSpec {
            nodes: vec![
                FaultNode::CrashStorm { stage: None, start: 10.0, end: 100.0, rate: 0.3 },
                FaultNode::Slowdown { stage: 1, start: 40.0, end: 80.0, factor: 2.5 },
                FaultNode::Outage { stage: 0, start: 90.0, end: 95.0 },
            ],
            max_retries: 2,
            shed_after: Some(1.0),
        }
    }

    #[test]
    fn compile_is_bit_deterministic_per_seed() {
        let spec = storm_spec();
        let a = spec.compile(4, 7);
        let b = spec.compile(4, 7);
        assert_eq!(a, b, "same (spec, stages, seed) must compile identically");
        assert!(!a.entries.is_empty(), "storm produced no crashes");
        let c = spec.compile(4, 8);
        assert_ne!(a, c, "different seed should move the storm");
    }

    #[test]
    fn compile_sorts_by_time_and_clamps_stages() {
        let spec = FaultSpec {
            nodes: vec![
                FaultNode::Crash { stage: 99, time: 50.0 },
                FaultNode::Crash { stage: 0, time: 5.0 },
                FaultNode::Outage { stage: 42, start: 1.0, end: 60.0 },
            ],
            ..FaultSpec::default()
        };
        let plan = spec.compile(3, 1);
        for w in plan.entries.windows(2) {
            assert!(w[0].time <= w[1].time, "entries not time-sorted");
        }
        for e in &plan.entries {
            let stage = match e.action {
                FaultAction::Crash { stage }
                | FaultAction::SlowdownStart { stage, .. }
                | FaultAction::SlowdownEnd { stage }
                | FaultAction::OutageStart { stage }
                | FaultAction::OutageEnd { stage } => stage,
            };
            assert!(stage < 3, "stage {stage} not clamped");
        }
    }

    #[test]
    fn scaled_compresses_schedule_and_preserves_storm_mass() {
        let spec = storm_spec();
        let scaled = spec.scaled(0.2);
        match (&spec.nodes[0], &scaled.nodes[0]) {
            (
                FaultNode::CrashStorm { start: s0, end: e0, rate: r0, .. },
                FaultNode::CrashStorm { start: s1, end: e1, rate: r1, .. },
            ) => {
                assert!((s1 - s0 * 0.2).abs() < 1e-12 && (e1 - e0 * 0.2).abs() < 1e-12);
                // Expected crash count (end − start) · rate is invariant.
                assert!(((e1 - s1) * r1 - (e0 - s0) * r0).abs() < 1e-9);
            }
            other => panic!("unexpected nodes {other:?}"),
        }
        assert_eq!(scaled.shed_after, spec.shed_after, "shed_after is not a schedule time");
    }

    #[test]
    fn empty_spec_compiles_to_an_empty_plan() {
        let spec = FaultSpec { shed_after: None, ..FaultSpec::default() };
        assert!(spec.compile(3, 42).is_empty());
        assert!(FaultPlan::default().is_empty());
        let shed_only = FaultSpec { shed_after: Some(0.5), ..FaultSpec::default() };
        assert!(!shed_only.compile(3, 42).is_empty(), "a shed policy is not a no-op");
    }

    #[test]
    fn parse_round_trips_the_schema() {
        let text = r#"{
            "max_retries": 1,
            "shed_after": 1.5,
            "events": [
                { "kind": "crash", "stage": 1, "time": 120 },
                { "kind": "crash_storm", "start": 60, "end": 180, "rate": 0.2 },
                { "kind": "slowdown", "stage": 0, "start": 200, "end": 260, "factor": 3 },
                { "kind": "outage", "stage": 2, "start": 300, "end": 315 }
            ]
        }"#;
        let spec = FaultSpec::parse_str(text).unwrap();
        assert_eq!(spec.max_retries, 1);
        assert_eq!(spec.shed_after, Some(1.5));
        assert_eq!(spec.nodes.len(), 4);
        assert_eq!(spec.nodes[0], FaultNode::Crash { stage: 1, time: 120.0 });
        assert_eq!(
            spec.nodes[1],
            FaultNode::CrashStorm { stage: None, start: 60.0, end: 180.0, rate: 0.2 }
        );
    }

    #[test]
    fn parse_errors_name_the_offending_node() {
        let bad = r#"{ "events": [ { "kind": "slowdown", "stage": 0,
                       "start": 10, "end": 5, "factor": 2 } ] }"#;
        let err = FaultSpec::parse_str(bad).unwrap_err();
        assert!(err.contains("faults.events[0]"), "err: {err}");
        let unknown = r#"{ "events": [ { "kind": "meteor", "stage": 0 } ] }"#;
        let err = FaultSpec::parse_str(unknown).unwrap_err();
        assert!(err.contains("unknown fault kind"), "err: {err}");
        let shed = r#"{ "shed_after": 0 }"#;
        let err = FaultSpec::parse_str(shed).unwrap_err();
        assert!(err.contains("shed_after"), "err: {err}");
    }
}
