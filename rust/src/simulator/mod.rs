//! The Estimator: a continuous-time, discrete-event simulator of the
//! pipeline (paper §4.2), plus the controlled variant used to evaluate
//! tuners (§5, §7).
//!
//! The simulator models the deterministic behavior of queries flowing
//! through a centralized batched queueing system: one FIFO queue per
//! stage, replicas that dequeue up to their configured maximum batch size
//! the moment they go idle (batch-at-a-time, no artificial delay — the
//! queueing discipline InferLine requires of the underlying serving
//! framework, §3), and per-batch service times taken from the stage's
//! model profile. Conditional control flow is simulated by sampling each
//! query's visit set from the pipeline's scale factors with a
//! deterministic per-query RNG, so configuration comparisons see
//! identical routing.
//!
//! Because only discrete events are processed, hours of trace simulate in
//! milliseconds (validated in `benches/microbench.rs`; the paper makes the
//! same claim in §4.2).
//!
//! ## Event core
//!
//! The engine runs on the [`event_core`] queue — three structural choices
//! that make the event loop fast without changing any simulated outcome
//! (the conformance suites in `tests/` hold bit-identically across the
//! old and new cores):
//!
//! * **Slab records**: heap entries are small `Copy`
//!   `{time, seq, kind}` records; batch qid slices live in a recycled
//!   side arena ([`event_core::SliceArena`]) and only `u32` handles
//!   travel through the heap, so sift operations move 24 bytes instead
//!   of a large enum with an owned `Vec`.
//! * **Coalesced delivery**: a completed batch emits *one*
//!   `Delivery` record carrying its qid slice — not one `Enqueue`
//!   record per query per routed hop. The hops all land at the same
//!   `now + rpc` and were seq-contiguous in the old engine, so replaying
//!   them inside the delivery handler (query-major, child-minor) is
//!   provably order-identical. One record per *batch* (rather than per
//!   child stage) is deliberate: a per-child split would permute
//!   tie-breaking among simultaneous hops in multi-child fan-out.
//!   Pipelines are trees with conditional branches (per-query visit
//!   sets); stages never share a downstream child.
//! * **Indexed cancellation**: scheduled replica activations are
//!   cancelable through generation-checked handles
//!   ([`event_core::UpHandle`]), so scale-down cancels the queue record
//!   directly and a rate flap can revive it at its original activation
//!   time — replacing the old count-based stale-event bookkeeping.
//!   Cancelled records remain as tombstones until they pop, preserving
//!   the old termination behavior of controlled runs, and an O(1)
//!   non-tick counter replaces the former whole-heap termination scan.
//!
//! ## Estimator fast path
//!
//! Planner candidate evaluation funnels every decision through
//! [`feasible`]-style simulations, so the open-loop path carries three
//! coordinated optimizations — none of which change any simulated
//! outcome (regression-tested in `tests/estimator_fast_path.rs`):
//!
//! * **Shared routing plans** ([`RoutingPlan`]): a query's conditional
//!   visit set depends only on (pipeline, trace, routing seed) — never on
//!   the candidate configuration — so it is sampled once per planning run
//!   and shared (`Arc`) across every candidate simulation and worker
//!   thread, instead of re-forking the per-query RNG for each of the
//!   hundreds of `feasible()` calls in an Algorithm-2 search.
//! * **Early-abort / fast-accept feasibility** ([`check_feasible`]):
//!   feasibility only needs the sign of `P99 − SLO`, not the exact P99.
//!   The budgeted simulation runs two symmetric tallies. It counts
//!   *guaranteed* misses — completed queries over the SLO plus in-flight
//!   queries already older than the SLO (the queue-divergence bailout:
//!   when a stage's queues grow without bound, queries age past the SLO
//!   immediately and the count explodes) — and aborts the moment the
//!   count provably pushes the interpolated P99 over the SLO (just over
//!   1% of the trace). Symmetrically it counts *guaranteed* hits —
//!   completed queries at or under the SLO plus in-flight queries whose
//!   final batch is already scheduled to finish under it — and accepts
//!   the moment P99 <= SLO is certain even if every remaining query
//!   misses, skipping the tail of the trace, the backlog drain after the
//!   last arrival, and the final P99 selection. Both proofs lean on the
//!   *clamped* interpolated quantile (`sorted[floor(pos)] <= P99 <=
//!   sorted[ceil(pos)]` holds bit-exactly), so decisions are
//!   bit-identical to the unbudgeted path ([`feasible_unbudgeted`]) —
//!   locked down by `tests/feasibility_conformance.rs`. Configurations
//!   whose mean throughput cannot cover the arrival rate at all are
//!   rejected even earlier, before any simulation, by
//!   [`throughput_bound_ok`].
//! * **O(n) quantiles**: P99 extraction uses `select_nth_unstable`-based
//!   selection (`util::stats::quantile_in_place`) instead of sorting the
//!   whole latency vector.

//! ## Telemetry probes
//!
//! Observability threads through the same event loop behind the
//! [`probe::Probe`] trait — an optional read-only observer gated exactly
//! like fault injection (`Option` checked per event, every probe branch
//! cold when absent), so a probe-less run stays bit-identical to the
//! engine without the plumbing, and an attached probe can never perturb
//! simulated outcomes (`tests/probe_conformance.rs`). The recording
//! implementation ([`probe::RecordingProbe`]) captures per-query per-hop
//! spans (reservoir-sampled), per-stage time-series at a configurable
//! cadence, and an SLO-miss attribution table splitting missed queries'
//! latency into per-stage queueing vs service vs RPC — exported as a
//! Chrome trace-event document (`inferline simulate --trace-out`) and
//! CSV, and aggregated per cell by the robustness harness.
//!
//! ## Streamed open loop
//!
//! [`simulate_streamed`] is the constant-memory counterpart of
//! [`simulate`]: arrivals are pulled from a
//! [`crate::workload::ArrivalSource`] in bounded chunks, per-query
//! routing is sampled lazily by a [`RoutingSampler`] (the same sequence
//! `RoutingPlan::build` materializes), completed query records are
//! compacted away, and completions fold into a [`StreamSummary`] of O(1)
//! aggregates. Memory tracks the in-flight window instead of the
//! horizon, so multi-hour traces that cannot be materialized still
//! simulate — with aggregates bit-identical to folding the materialized
//! run's result (`tests/streaming_conformance.rs`, plus the long-horizon
//! bounded-RSS smoke in CI).
//!
//! ## Entry points
//!
//! [`SimRun`] is the unified builder over every simulation mode: start
//! from `(spec, profiles, config, params)`, attach any combination of
//! `.routing(..)`, `.faults(..)`, `.probe(..)`, `.controller(..)` and
//! `.budget(..)`, then `.run(trace)` (or `.run_streamed(..)` for the
//! bare open-loop streamed path). The historical free functions
//! ([`simulate`], [`simulate_budgeted`], [`simulate_with_faults`],
//! [`simulate_probed`], [`control::simulate_controlled`], …) survive as
//! thin delegating wrappers, and `tests/probe_conformance.rs` asserts
//! each wrapper is bit-identical to its builder spelling.

pub mod control;
mod engine;
pub mod event_core;
pub mod faults;
pub mod probe;
mod routing;

pub use engine::{
    simulate, simulate_budgeted, simulate_budgeted_with_faults, simulate_probed,
    simulate_streamed, simulate_with_faults, simulate_with_routing, BudgetVerdict, SimParams,
    SimResult, SimRun, StageStats, StreamSummary,
};
pub use routing::{RoutingPlan, RoutingSampler};

use crate::config::{PipelineConfig, PipelineSpec};
use crate::profiler::ProfileSet;
use crate::util::stats;
use crate::workload::Trace;

/// Estimate the P99 end-to-end latency of `config` on `trace`.
pub fn estimate_p99(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
) -> f64 {
    let mut result = simulate(spec, profiles, config, trace, params);
    stats::p99_in_place(&mut result.latencies)
}

/// Cheap analytic necessary condition for feasibility: every stage must
/// have enough aggregate throughput for its share of the mean arrival
/// rate, or queues diverge and the expensive simulation is wasted. The
/// planner uses this as a pre-simulation pruning bound.
pub fn throughput_bound_ok(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    lambda: f64,
) -> bool {
    if !lambda.is_finite() {
        return true;
    }
    for (i, stage) in spec.stages.iter().enumerate() {
        let c = &config.stages[i];
        let prof = profiles.get(&stage.model).get(c.hw).expect("profile");
        let capacity = c.replicas as f64 * prof.throughput(c.batch);
        if capacity < lambda * stage.scale_factor * 0.98 {
            return false;
        }
    }
    true
}

/// Outcome of a budgeted feasibility simulation ([`check_feasible`]).
#[derive(Debug, Clone, Copy)]
pub struct FeasibilityCheck {
    /// Does the configuration meet the P99 SLO on the trace?
    pub feasible: bool,
    /// True when the simulation early-aborted: enough queries were
    /// guaranteed to miss that P99 > SLO was already proven.
    pub aborted: bool,
    /// True when the simulation early-accepted: enough queries had
    /// provably met the SLO that P99 <= SLO was already proven.
    pub accepted: bool,
    /// The exact Estimator P99 — available only when the simulation ran
    /// to completion (aborted and accepted runs know just the sign of
    /// `P99 − SLO`).
    pub p99: Option<f64>,
}

/// Budgeted feasibility check: simulate with the symmetric early-abort /
/// fast-accept budget and an optional shared routing plan. The decision
/// is bit-identical to [`feasible_unbudgeted`] minus the analytic
/// throughput prune, which the caller is expected to apply first (as
/// [`feasible`] does).
pub fn check_feasible(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
    routing: Option<&RoutingPlan>,
) -> FeasibilityCheck {
    let (mut result, verdict) =
        simulate_budgeted(spec, profiles, config, trace, slo, params, routing);
    match verdict {
        BudgetVerdict::ProvedInfeasible => {
            FeasibilityCheck { feasible: false, aborted: true, accepted: false, p99: None }
        }
        BudgetVerdict::ProvedFeasible => {
            FeasibilityCheck { feasible: true, aborted: false, accepted: true, p99: None }
        }
        BudgetVerdict::Completed => {
            let p99 = stats::p99_in_place(&mut result.latencies);
            FeasibilityCheck {
                feasible: p99 <= slo,
                aborted: false,
                accepted: false,
                p99: Some(p99),
            }
        }
    }
}

/// [`check_feasible`] under a fault plan (see [`faults`]): the budgeted
/// simulation injects the plan, counting shed queries against the miss
/// ceiling and disabling the dispatch-time fast-accept sweep (an
/// in-flight batch is no longer guaranteed to complete as scheduled when
/// crashes can cancel it). With an empty plan the decision — and the
/// whole simulation — is bit-identical to [`check_feasible`].
#[allow(clippy::too_many_arguments)]
pub fn check_feasible_with_faults(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
    routing: Option<&RoutingPlan>,
    fault_plan: &faults::FaultPlan,
) -> FeasibilityCheck {
    let (mut result, verdict) = simulate_budgeted_with_faults(
        spec, profiles, config, trace, slo, params, routing, fault_plan,
    );
    match verdict {
        BudgetVerdict::ProvedInfeasible => {
            FeasibilityCheck { feasible: false, aborted: true, accepted: false, p99: None }
        }
        BudgetVerdict::ProvedFeasible => {
            FeasibilityCheck { feasible: true, aborted: false, accepted: true, p99: None }
        }
        BudgetVerdict::Completed => {
            let p99 = stats::p99_in_place(&mut result.latencies);
            FeasibilityCheck {
                feasible: p99 <= slo,
                aborted: false,
                accepted: false,
                p99: Some(p99),
            }
        }
    }
}

/// The planner's feasibility predicate: does the configuration meet the
/// P99 latency SLO on the sample trace? (Paper §4.3 `Feasible`.) Runs the
/// analytic throughput prune, then the budgeted fast-path simulation.
pub fn feasible(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
) -> bool {
    if !throughput_bound_ok(spec, profiles, config, trace.mean_rate()) {
        return false;
    }
    check_feasible(spec, profiles, config, trace, slo, params, None).feasible
}

/// Reference feasibility predicate: identical decision to [`feasible`]
/// but always simulates the full trace (no early abort). Kept as the
/// semantic baseline the fast path is regression-tested against.
pub fn feasible_unbudgeted(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
) -> bool {
    if !throughput_bound_ok(spec, profiles, config, trace.mean_rate()) {
        return false;
    }
    estimate_p99(spec, profiles, config, trace, params) <= slo
}

/// Sum of batch-1 processing latencies along the longest root→leaf path —
/// Algorithm 1's `ServiceTime` lower bound (ignores queueing).
pub fn service_time(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
) -> f64 {
    spec.paths()
        .iter()
        .map(|path| {
            path.iter()
                .map(|&i| {
                    let c = &config.stages[i];
                    let prof = profiles.get(&spec.stages[i].model).get(c.hw).expect("profile");
                    prof.latency(c.batch) + spec.framework.rpc_overhead()
                })
                .sum::<f64>()
        })
        .fold(0.0, f64::max)
}
