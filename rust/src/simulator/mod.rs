//! The Estimator: a continuous-time, discrete-event simulator of the
//! pipeline (paper §4.2), plus the controlled variant used to evaluate
//! tuners (§5, §7).
//!
//! The simulator models the deterministic behavior of queries flowing
//! through a centralized batched queueing system: one FIFO queue per
//! stage, replicas that dequeue up to their configured maximum batch size
//! the moment they go idle (batch-at-a-time, no artificial delay — the
//! queueing discipline InferLine requires of the underlying serving
//! framework, §3), and per-batch service times taken from the stage's
//! model profile. Conditional control flow is simulated by sampling each
//! query's visit set from the pipeline's scale factors with a
//! deterministic per-query RNG, so configuration comparisons see
//! identical routing.
//!
//! Because only discrete events are processed, hours of trace simulate in
//! milliseconds (validated in `benches/microbench.rs`; the paper makes the
//! same claim in §4.2).

pub mod control;
mod engine;

pub use engine::{simulate, SimParams, SimResult, StageStats};

use crate::config::{PipelineConfig, PipelineSpec};
use crate::profiler::ProfileSet;
use crate::util::stats;
use crate::workload::Trace;

/// Estimate the P99 end-to-end latency of `config` on `trace`.
pub fn estimate_p99(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
) -> f64 {
    let result = simulate(spec, profiles, config, trace, params);
    stats::p99(&result.latencies)
}

/// Cheap analytic necessary condition for feasibility: every stage must
/// have enough aggregate throughput for its share of the mean arrival
/// rate, or queues diverge and the expensive simulation is wasted. The
/// planner uses this as a pre-simulation pruning bound.
pub fn throughput_bound_ok(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    lambda: f64,
) -> bool {
    if !lambda.is_finite() {
        return true;
    }
    for (i, stage) in spec.stages.iter().enumerate() {
        let c = &config.stages[i];
        let prof = profiles.get(&stage.model).get(c.hw).expect("profile");
        let capacity = c.replicas as f64 * prof.throughput(c.batch);
        if capacity < lambda * stage.scale_factor * 0.98 {
            return false;
        }
    }
    true
}

/// The planner's feasibility predicate: does the configuration meet the
/// P99 latency SLO on the sample trace? (Paper §4.3 `Feasible`.)
pub fn feasible(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
) -> bool {
    if !throughput_bound_ok(spec, profiles, config, trace.mean_rate()) {
        return false;
    }
    estimate_p99(spec, profiles, config, trace, params) <= slo
}

/// Sum of batch-1 processing latencies along the longest root→leaf path —
/// Algorithm 1's `ServiceTime` lower bound (ignores queueing).
pub fn service_time(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
) -> f64 {
    spec.paths()
        .iter()
        .map(|path| {
            path.iter()
                .map(|&i| {
                    let c = &config.stages[i];
                    let prof = profiles.get(&spec.stages[i].model).get(c.hw).expect("profile");
                    prof.latency(c.batch) + spec.framework.rpc_overhead()
                })
                .sum::<f64>()
        })
        .fold(0.0, f64::max)
}
