//! Shared routing plans: the per-query conditional visit sets, sampled
//! once per (pipeline, trace, routing seed) and reused across candidate
//! simulations.
//!
//! Conditional control flow in the Estimator is determined by a per-query
//! forked RNG seeded from `SimParams::routing_seed` — deliberately
//! independent of the candidate configuration, so every configuration
//! comparison sees identical routing (paper §6: traces are "reused across
//! all comparison points"). That independence means the sampling work is
//! also identical across the hundreds of `feasible()` calls in one
//! Algorithm-2 search, and profiling showed the per-query RNG forks were
//! the dominant seed-arrival cost on long traces. A [`RoutingPlan`]
//! factors that sampling out: build it once, wrap it in an `Arc`, and
//! hand it to every candidate simulation (and every worker thread) of the
//! planning run. Simulations with and without a precomputed plan are
//! bit-identical (`tests/estimator_fast_path.rs`).
//!
//! The visit bitmasks double as the engine's routing table: the event
//! core's coalesced `Delivery` records replay per-query hops straight off
//! `visited & (1 << child)` tests, so no per-hop allocation or RNG access
//! survives into the event loop.

use crate::config::PipelineSpec;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Precomputed per-query routing for one (pipeline, trace, seed) triple:
/// which stages each query visits and how many stage completions it needs.
#[derive(Debug, Clone)]
pub struct RoutingPlan {
    /// Per query, in trace order: (visited-stage bitmask, visit count).
    /// Pipelines are limited to 32 stages (the engine's bitmask width).
    pub(crate) visits: Vec<(u32, u8)>,
}

impl RoutingPlan {
    /// Sample every query's visit set, exactly as the engine would when
    /// seeding arrivals without a plan: a base RNG seeded with
    /// `routing_seed`, forked once per query in trace order. Delegates
    /// to [`RoutingSampler`] so the materialized plan and the lazy
    /// streaming sampler share one sampling sequence by construction.
    pub fn build(spec: &PipelineSpec, trace: &Trace, routing_seed: u64) -> RoutingPlan {
        let mut sampler = RoutingSampler::new(spec, routing_seed);
        let visits = (0..trace.len()).map(|_| sampler.next_visit()).collect();
        RoutingPlan { visits }
    }

    /// Number of queries the plan covers (must equal the trace length).
    pub fn len(&self) -> usize {
        self.visits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.visits.is_empty()
    }
}

/// Lazy per-query routing: the streaming counterpart of
/// [`RoutingPlan`]. A [`RoutingPlan`] pre-samples a whole trace (O(n)
/// memory); a `RoutingSampler` derives the identical visit sequence
/// from the same `routing_seed` one query at a time — the base RNG
/// fork advances per query, so calling [`RoutingSampler::next_visit`]
/// n times yields exactly `RoutingPlan::build(spec, trace_of_n, seed)`
/// (asserted in this module's tests). Streamed arrivals are processed
/// in qid order, which is what makes the sequential fork sound.
pub struct RoutingSampler {
    rng: Rng,
    /// Pre-resolved (child, edge probability) lists per stage.
    edges: Vec<Vec<(usize, f64)>>,
    roots: Vec<usize>,
    /// Reusable DFS stack.
    stack: Vec<usize>,
    /// Queries sampled so far == the next query's fork tag.
    next: u64,
}

impl RoutingSampler {
    pub fn new(spec: &PipelineSpec, routing_seed: u64) -> RoutingSampler {
        debug_assert!(spec.stages.len() <= 32, "visited bitmask limit");
        // Pre-resolve edge probabilities once (avoids re-deriving the
        // conditional probabilities twice per query).
        let edges: Vec<Vec<(usize, f64)>> = spec
            .stages
            .iter()
            .enumerate()
            .map(|(s, st)| {
                st.children
                    .iter()
                    .map(|&c| (c, spec.edge_probability(s, c)))
                    .collect()
            })
            .collect();
        RoutingSampler {
            rng: Rng::new(routing_seed),
            edges,
            roots: spec.roots.clone(),
            stack: Vec::with_capacity(spec.stages.len()),
            next: 0,
        }
    }

    /// Sample the next query's (visited-stage bitmask, visit count).
    pub fn next_visit(&mut self) -> (u32, u8) {
        let mut q_rng = self.rng.fork(self.next);
        self.next += 1;
        let mut visited: u32 = 0;
        let mut remaining: u8 = 0;
        self.stack.clear();
        self.stack.extend_from_slice(&self.roots);
        while let Some(s) = self.stack.pop() {
            visited |= 1 << s;
            remaining += 1;
            for &(c, p) in &self.edges[s] {
                if p >= 1.0 || q_rng.bool(p) {
                    self.stack.push(c);
                }
            }
        }
        (visited, remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::workload::gamma_trace;

    #[test]
    fn plan_is_deterministic_and_covers_trace() {
        let spec = pipelines::social_media();
        let trace = gamma_trace(80.0, 1.0, 10.0, 3);
        let a = RoutingPlan::build(&spec, &trace, 7);
        let b = RoutingPlan::build(&spec, &trace, 7);
        assert_eq!(a.len(), trace.len());
        assert_eq!(a.visits, b.visits);
        // Every query visits at least the roots.
        for &(visited, remaining) in &a.visits {
            for &r in &spec.roots {
                assert!(visited & (1 << r) != 0);
            }
            assert!(remaining as usize >= spec.roots.len());
            assert_eq!(visited.count_ones() as usize, remaining as usize);
        }
    }

    #[test]
    fn different_seeds_route_differently() {
        let spec = pipelines::social_media();
        let trace = gamma_trace(80.0, 1.0, 20.0, 3);
        let a = RoutingPlan::build(&spec, &trace, 1);
        let b = RoutingPlan::build(&spec, &trace, 2);
        // social-media has conditional stages, so some query must differ.
        assert_ne!(a.visits, b.visits);
    }

    #[test]
    fn lazy_sampler_reproduces_the_materialized_plan() {
        for spec in [pipelines::social_media(), pipelines::image_processing()] {
            let trace = gamma_trace(80.0, 1.0, 15.0, 3);
            let plan = RoutingPlan::build(&spec, &trace, 7);
            let mut sampler = RoutingSampler::new(&spec, 7);
            let lazy: Vec<(u32, u8)> =
                (0..trace.len()).map(|_| sampler.next_visit()).collect();
            assert_eq!(plan.visits, lazy);
        }
    }
}
