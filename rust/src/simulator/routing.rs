//! Shared routing plans: the per-query conditional visit sets, sampled
//! once per (pipeline, trace, routing seed) and reused across candidate
//! simulations.
//!
//! Conditional control flow in the Estimator is determined by a per-query
//! forked RNG seeded from `SimParams::routing_seed` — deliberately
//! independent of the candidate configuration, so every configuration
//! comparison sees identical routing (paper §6: traces are "reused across
//! all comparison points"). That independence means the sampling work is
//! also identical across the hundreds of `feasible()` calls in one
//! Algorithm-2 search, and profiling showed the per-query RNG forks were
//! the dominant seed-arrival cost on long traces. A [`RoutingPlan`]
//! factors that sampling out: build it once, wrap it in an `Arc`, and
//! hand it to every candidate simulation (and every worker thread) of the
//! planning run. Simulations with and without a precomputed plan are
//! bit-identical (`tests/estimator_fast_path.rs`).
//!
//! The visit bitmasks double as the engine's routing table: the event
//! core's coalesced `Delivery` records replay per-query hops straight off
//! `visited & (1 << child)` tests, so no per-hop allocation or RNG access
//! survives into the event loop.

use crate::config::PipelineSpec;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Precomputed per-query routing for one (pipeline, trace, seed) triple:
/// which stages each query visits and how many stage completions it needs.
#[derive(Debug, Clone)]
pub struct RoutingPlan {
    /// Per query, in trace order: (visited-stage bitmask, visit count).
    /// Pipelines are limited to 32 stages (the engine's bitmask width).
    pub(crate) visits: Vec<(u32, u8)>,
}

impl RoutingPlan {
    /// Sample every query's visit set, exactly as the engine would when
    /// seeding arrivals without a plan: a base RNG seeded with
    /// `routing_seed`, forked once per query in trace order.
    pub fn build(spec: &PipelineSpec, trace: &Trace, routing_seed: u64) -> RoutingPlan {
        debug_assert!(spec.stages.len() <= 32, "visited bitmask limit");
        let mut rng = Rng::new(routing_seed);
        // Pre-resolve edge probabilities once (avoids re-deriving the
        // conditional probabilities twice per query).
        let edges: Vec<Vec<(usize, f64)>> = spec
            .stages
            .iter()
            .enumerate()
            .map(|(s, st)| {
                st.children
                    .iter()
                    .map(|&c| (c, spec.edge_probability(s, c)))
                    .collect()
            })
            .collect();
        let mut visits = Vec::with_capacity(trace.len());
        // One reusable DFS stack for all queries.
        let mut stack: Vec<usize> = Vec::with_capacity(spec.stages.len());
        for i in 0..trace.len() {
            let mut q_rng = rng.fork(i as u64);
            let mut visited: u32 = 0;
            let mut remaining: u8 = 0;
            stack.clear();
            stack.extend_from_slice(&spec.roots);
            while let Some(s) = stack.pop() {
                visited |= 1 << s;
                remaining += 1;
                for &(c, p) in &edges[s] {
                    if p >= 1.0 || q_rng.bool(p) {
                        stack.push(c);
                    }
                }
            }
            visits.push((visited, remaining));
        }
        RoutingPlan { visits }
    }

    /// Number of queries the plan covers (must equal the trace length).
    pub fn len(&self) -> usize {
        self.visits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.visits.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::workload::gamma_trace;

    #[test]
    fn plan_is_deterministic_and_covers_trace() {
        let spec = pipelines::social_media();
        let trace = gamma_trace(80.0, 1.0, 10.0, 3);
        let a = RoutingPlan::build(&spec, &trace, 7);
        let b = RoutingPlan::build(&spec, &trace, 7);
        assert_eq!(a.len(), trace.len());
        assert_eq!(a.visits, b.visits);
        // Every query visits at least the roots.
        for &(visited, remaining) in &a.visits {
            for &r in &spec.roots {
                assert!(visited & (1 << r) != 0);
            }
            assert!(remaining as usize >= spec.roots.len());
            assert_eq!(visited.count_ones() as usize, remaining as usize);
        }
    }

    #[test]
    fn different_seeds_route_differently() {
        let spec = pipelines::social_media();
        let trace = gamma_trace(80.0, 1.0, 20.0, 3);
        let a = RoutingPlan::build(&spec, &trace, 1);
        let b = RoutingPlan::build(&spec, &trace, 2);
        // social-media has conditional stages, so some query must differ.
        assert_ne!(a.visits, b.visits);
    }
}
