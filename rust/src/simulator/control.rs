//! Controlled simulation: a controller (InferLine Tuner or a baseline
//! autoscaler) observes the arrival stream and adjusts per-stage
//! replication while queries flow, with realistic replica activation
//! delays (paper §5, §7.1 "High-Frequency Tuning" experiments).
//!
//! Scaling actions ride on the engine's event core: scale-ups schedule
//! cancelable `ReplicaUp` records (`event_core::UpHandle`), scale-downs
//! cancel the earliest-scheduled ones directly, and a subsequent scale-up
//! revives cancelled records at their *original* activation time — so a
//! rate flap inside the activation window pays no second delay. See
//! `tests/controlled_conformance.rs` for the bit-identity coverage of
//! these paths (flap timelines, DS2 halt/resume, query conservation).

use crate::config::{PipelineConfig, PipelineSpec};
use crate::profiler::ProfileSet;
use crate::workload::Trace;

use super::engine::{SimParams, SimResult, SimRun};
use super::faults::FaultPlan;
use super::probe::Probe;

/// Scaling actions a controller may issue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControlAction {
    /// Set the provisioned replica target for one stage. Increases incur
    /// the activation delay; decreases take effect immediately.
    SetReplicas { stage: usize, replicas: usize },
    /// Halt the entire pipeline for `duration` seconds (models Flink-style
    /// stop–savepoint–restart reconfiguration; used by the DS2 baseline).
    Halt { duration: f64 },
}

/// Pipeline state snapshot handed to the controller each tick.
#[derive(Debug, Clone)]
pub struct ControlState {
    pub time: f64,
    /// Per-stage provisioned replicas (online + pending − retiring).
    pub provisioned: Vec<usize>,
    /// Per-stage instantaneous queue depth.
    pub queue_depths: Vec<usize>,
    /// Per-stage busy replica count.
    pub busy: Vec<usize>,
}

/// A high-frequency controller in the simulation loop.
pub trait Controller {
    /// Called on every query arrival (the Tuner's traffic monitoring tap:
    /// "it observes the incoming arrival trace streamed to it by the
    /// centralized queueing system", §3).
    fn on_arrival(&mut self, t: f64);

    /// Called every `control_interval` simulated seconds; returns scaling
    /// actions to apply now.
    fn on_tick(&mut self, t: f64, state: &ControlState) -> Vec<ControlAction>;
}

/// Run the pipeline with a controller in the loop. The returned
/// [`SimResult`] carries the cost integral and replica timeline in
/// addition to per-query latencies.
pub fn simulate_controlled(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    initial: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
    controller: &mut dyn Controller,
) -> SimResult {
    SimRun::new(spec, profiles, initial, params).controller(controller).run(trace).0
}

/// [`simulate_controlled`] with a fault plan injected (see
/// [`super::faults`]). With an empty plan the run is bit-identical to
/// [`simulate_controlled`]; with a real plan the controller sees crashes
/// through the reduced provisioned counts in its [`ControlState`] and
/// recovers capacity through its normal actions (the Tuner restores its
/// planned floor, paying the activation delay).
pub fn simulate_controlled_with_faults(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    initial: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
    controller: &mut dyn Controller,
    faults: &FaultPlan,
) -> SimResult {
    SimRun::new(spec, profiles, initial, params)
        .controller(controller)
        .faults(faults)
        .run(trace)
        .0
}

/// [`simulate_controlled`] — optionally fault-injected — with a
/// [`Probe`] observing the run (see [`super::probe`]): controller
/// actions surface through `Probe::on_action`, fault injections through
/// `Probe::on_fault`. Probes are read-only, so the result is
/// bit-identical to the probe-less run's.
#[allow(clippy::too_many_arguments)]
pub fn simulate_controlled_probed(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    initial: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
    controller: &mut dyn Controller,
    faults: Option<&FaultPlan>,
    probe: &mut dyn Probe,
) -> SimResult {
    SimRun::new(spec, profiles, initial, params)
        .controller(controller)
        .faults(faults)
        .probe(probe)
        .run(trace)
        .0
}

/// A controller that never acts (for A/B comparisons of "Planner only").
pub struct NullController;

impl Controller for NullController {
    fn on_arrival(&mut self, _t: f64) {}
    fn on_tick(&mut self, _t: f64, _state: &ControlState) -> Vec<ControlAction> {
        Vec::new()
    }
}

/// Transparent wrapper that counts the actions an inner controller
/// issues (classified against the provisioned state at decision time).
/// The robustness harness reports these as tuner-activity telemetry.
pub struct CountingController<'a> {
    inner: &'a mut dyn Controller,
    /// `SetReplicas` actions raising a stage above its current target.
    pub scale_ups: usize,
    /// `SetReplicas` actions lowering a stage below its current target.
    pub scale_downs: usize,
    /// `Halt` actions (DS2-style stop-restart reconfigurations).
    pub halts: usize,
}

impl<'a> CountingController<'a> {
    pub fn new(inner: &'a mut dyn Controller) -> Self {
        CountingController { inner, scale_ups: 0, scale_downs: 0, halts: 0 }
    }
}

impl Controller for CountingController<'_> {
    fn on_arrival(&mut self, t: f64) {
        self.inner.on_arrival(t);
    }

    fn on_tick(&mut self, t: f64, state: &ControlState) -> Vec<ControlAction> {
        let actions = self.inner.on_tick(t, state);
        for action in &actions {
            match *action {
                ControlAction::SetReplicas { stage, replicas } => {
                    match replicas.cmp(&state.provisioned[stage]) {
                        std::cmp::Ordering::Greater => self.scale_ups += 1,
                        std::cmp::Ordering::Less => self.scale_downs += 1,
                        std::cmp::Ordering::Equal => {}
                    }
                }
                ControlAction::Halt { .. } => self.halts += 1,
            }
        }
        actions
    }
}
