//! Zero-cost simulation telemetry: per-hop query spans, per-stage
//! time-series and SLO-miss attribution, observed through the event core.
//!
//! ## Trait contract
//!
//! A [`Probe`] is a **read-only observer** of the engine's event stream.
//! The engine owns an `Option<&mut dyn Probe>` (mirroring its
//! `Option<FaultRuntime>` fault gating): every probe branch in the hot
//! loop is gated on that option being `Some`, and a probe-less run takes
//! no probe branch at all — it pushes the same event records with the
//! same sequence numbers and produces a bit-identical
//! [`SimResult`](super::SimResult) (asserted across the conformance
//! suites in `tests/probe_conformance.rs`). Probes can never perturb
//! simulated outcomes *by construction*: the hooks receive copies of
//! event data (`qid`s, times, qid slices) and have no path back into
//! engine state. All hook methods default to no-ops, so an implementor
//! only pays for what it observes.
//!
//! Hooks fire in simulated-time order: `on_arrival` → `on_enqueue` (one
//! per routed hop) → `on_dispatch` (batch formation, with the scheduled
//! completion time) → `on_visit_done` → `on_query_done` when the last
//! visit completes. Fault runs add `on_retry` / `on_shed` / `on_fault`;
//! controlled runs add `on_action` for every controller decision the
//! engine applies. Time-series sampling is pull-based: after each event
//! the engine asks [`Probe::wants_sample`] and, only when it answers
//! `true`, materializes a per-stage [`StageSample`] snapshot — so the
//! snapshot cost is paid at the probe's cadence, not per event.
//!
//! ## The recording probe
//!
//! [`RecordingProbe`] captures three artifacts into a [`ProbeReport`]:
//!
//! 1. **Per-query per-hop spans** — (enqueue, dispatch, completion)
//!    timestamps plus batch id/size per stage visit, for a
//!    deterministically reservoir-sampled subset of queries (fixed
//!    internal seed, so the same run always samples the same queries).
//!    Counters (arrivals / completed / shed) cover *every* query:
//!    `completed + shed == arrivals` holds for any finished run.
//! 2. **Per-stage time-series** at a configurable cadence: queue depth,
//!    busy replicas, online replicas, busy fraction and the
//!    instantaneous arrival rate over the elapsed window.
//! 3. **SLO-miss attribution** ([`MissAttribution`]): for every missed
//!    query the critical path through its hop spans is reconstructed and
//!    its latency split into per-stage queueing (enqueue→dispatch) and
//!    service (dispatch→completion), with RPC as the telescoped
//!    remainder — aggregated into a per-stage blame table.
//!
//! ## Trace-event export schema
//!
//! [`ProbeReport::chrome_trace`] renders the spans as a Chrome
//! trace-event JSON document (loadable in Perfetto / `chrome://tracing`):
//! an object with a `traceEvents` array sorted by timestamp, where every
//! stage is one `tid` track under `pid` 1 (named via `"M"` metadata
//! events). Each sampled query contributes a `"queue"` and a `"service"`
//! duration event (`"ph": "X"`, microsecond `ts`/`dur`, `args` carrying
//! `qid`, `batch` and `batch_size`); tuner actions and fault injections
//! are instant events (`"ph": "i"`, global scope). The per-stage
//! time-series export is a flat CSV ([`ProbeReport::series_csv`]).

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::control::ControlAction;

/// Per-stage state snapshot handed to [`Probe::on_sample`].
#[derive(Debug, Clone, Copy)]
pub struct StageSample {
    /// Instantaneous queue depth.
    pub queue: usize,
    /// Replicas currently executing a batch.
    pub busy: usize,
    /// Online replicas (busy + idle).
    pub online: usize,
}

/// Read-only observer of a simulation run. Every method defaults to a
/// no-op; see the module docs for the contract and hook ordering.
pub trait Probe {
    /// The run is about to start: pipeline width and trace length.
    fn on_start(&mut self, _n_stages: usize, _n_queries: usize) {}
    /// Query `qid` arrived at the pipeline roots.
    fn on_arrival(&mut self, _qid: u32, _t: f64) {}
    /// Query `qid` entered the queue of `stage`.
    fn on_enqueue(&mut self, _stage: usize, _qid: u32, _t: f64) {}
    /// A replica of `stage` dispatched batch `batch_id` over `qids`,
    /// scheduled to complete at `done`.
    fn on_dispatch(&mut self, _stage: usize, _batch_id: u64, _qids: &[u32], _t: f64, _done: f64) {}
    /// Query `qid` finished its visit at `stage`.
    fn on_visit_done(&mut self, _stage: usize, _qid: u32, _t: f64) {}
    /// Query `qid` completed its last visit (end-to-end completion).
    fn on_query_done(&mut self, _qid: u32, _t: f64) {}
    /// Query `qid` was dropped (deadline shed or retry exhaustion).
    fn on_shed(&mut self, _qid: u32, _t: f64) {}
    /// Query `qid` was requeued at `stage` after its batch crashed.
    fn on_retry(&mut self, _stage: usize, _qid: u32, _t: f64) {}
    /// A compiled fault entry fired (`kind` names the action).
    fn on_fault(&mut self, _kind: &str, _stage: Option<usize>, _t: f64) {}
    /// The engine applied a controller action.
    fn on_action(&mut self, _action: &ControlAction, _t: f64) {}
    /// Should the engine materialize a [`StageSample`] snapshot now?
    fn wants_sample(&self, _t: f64) -> bool {
        false
    }
    /// A snapshot requested via [`Probe::wants_sample`].
    fn on_sample(&mut self, _t: f64, _stages: &[StageSample]) {}
}

/// The trivially elided probe: every hook inherits the default no-op.
/// Attaching it must be indistinguishable from attaching nothing.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopProbe;

impl Probe for NoopProbe {}

/// One stage visit of one sampled query. Timestamps are raw simulated
/// seconds; `dispatched`/`completed` are NaN while the hop is still
/// queued / in flight (or was voided by a crash and never re-ran).
#[derive(Debug, Clone, Copy)]
pub struct HopSpan {
    pub stage: u16,
    pub enqueued: f64,
    pub dispatched: f64,
    pub completed: f64,
    pub batch_id: u64,
    pub batch_size: u32,
}

/// The full span record of one sampled query.
#[derive(Debug, Clone)]
pub struct QuerySpans {
    pub qid: u32,
    pub arrival: f64,
    /// End-to-end completion time (NaN if the query never completed).
    pub done: f64,
    pub shed: bool,
    pub hops: Vec<HopSpan>,
}

impl QuerySpans {
    /// End-to-end latency reconstructed from the span chain: the
    /// completing hop's timestamp minus the arrival — the *same* float
    /// expression the engine evaluated, so it reproduces the recorded
    /// latency bit-exactly. NaN for queries that never completed.
    pub fn latency(&self) -> f64 {
        self.done - self.arrival
    }
}

/// A timeline instant (tuner action or fault injection) for the trace
/// export.
#[derive(Debug, Clone)]
pub struct InstantEvent {
    pub time: f64,
    pub name: String,
    pub detail: String,
}

/// One point of the per-stage time-series.
#[derive(Debug, Clone, Copy)]
pub struct SeriesPoint {
    pub time: f64,
    pub stage: u16,
    pub queue: usize,
    pub busy: usize,
    pub online: usize,
    /// Arrivals per second over the window since the previous sample
    /// (NaN for a zero-length window).
    pub arrival_rate: f64,
}

/// Per-stage blame table over all SLO-missed queries: where did the
/// latency of the misses go? `queueing[s]` / `service[s]` sum the
/// critical-path enqueue→dispatch and dispatch→completion seconds spent
/// at stage `s` across every missed query; `rpc` is the telescoped
/// remainder (inter-stage RPC hops plus any float residue), so
/// `queueing + service + rpc` accounts for `total_latency` exactly by
/// construction.
#[derive(Debug, Clone, Default)]
pub struct MissAttribution {
    /// Completed queries over the SLO.
    pub missed: usize,
    /// All completed queries (the miss-rate denominator).
    pub completed: usize,
    /// Queries dropped before completion (never in the miss tally).
    pub shed: usize,
    /// Per-stage queueing seconds summed over missed queries.
    pub queueing: Vec<f64>,
    /// Per-stage service seconds summed over missed queries.
    pub service: Vec<f64>,
    /// RPC + residual seconds summed over missed queries.
    pub rpc: f64,
    /// Summed end-to-end latency of the missed queries.
    pub total_latency: f64,
}

impl MissAttribution {
    /// The stage carrying the most blame (queueing + service) for the
    /// misses, or `None` when nothing missed.
    pub fn blame_stage(&self) -> Option<usize> {
        if self.missed == 0 {
            return None;
        }
        (0..self.queueing.len()).fold(None, |best, s| {
            let w = self.queueing[s] + self.service[s];
            match best {
                Some((_, bw)) if bw >= w => best,
                _ => Some((s, w)),
            }
        })
        .map(|(s, _)| s)
    }

    /// Fraction of the missed queries' total latency attributed to
    /// stage `s` (NaN when nothing missed).
    pub fn blame_share(&self, s: usize) -> f64 {
        (self.queueing[s] + self.service[s]) / self.total_latency
    }

    /// Canonical JSON encoding for the robustness report (per-cell
    /// attribution node). NaN shares serialize as `null`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("missed", self.missed)
            .set("completed", self.completed)
            .set("shed", self.shed)
            .set("rpc_s", Json::num_or_null(self.rpc))
            .set("total_latency_s", Json::num_or_null(self.total_latency))
            .set(
                "blame_stage",
                match self.blame_stage() {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            );
        let stages: Vec<Json> = (0..self.queueing.len())
            .map(|s| {
                let mut e = Json::obj();
                e.set("stage", s)
                    .set("queueing_s", Json::num_or_null(self.queueing[s]))
                    .set("service_s", Json::num_or_null(self.service[s]))
                    .set("share", Json::num_or_null(self.blame_share(s)));
                e
            })
            .collect();
        o.set("stages", Json::Arr(stages));
        o
    }
}

/// Everything a [`RecordingProbe`] captured, ready for export.
#[derive(Debug, Clone)]
pub struct ProbeReport {
    /// Reservoir-sampled per-query span records, qid order.
    pub spans: Vec<QuerySpans>,
    /// Per-stage time-series, sample-major then stage order.
    pub series: Vec<SeriesPoint>,
    /// Tuner actions and fault injections, time order.
    pub instants: Vec<InstantEvent>,
    /// Aggregated SLO-miss blame table (over *all* queries, not just
    /// the sampled ones).
    pub attribution: MissAttribution,
    /// Total queries that arrived.
    pub arrivals: usize,
    /// Queries that completed end-to-end.
    pub completed: usize,
    /// Queries shed before completion.
    pub shed: usize,
}

/// Format a possibly-undefined CSV number (non-finite → empty field).
fn csv_cell(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        String::new()
    }
}

impl ProbeReport {
    /// Header of the per-stage time-series CSV ([`Self::series_csv`]).
    pub const SERIES_HEADER: &'static str =
        "time_s,stage,queue_depth,busy_replicas,online_replicas,busy_frac,arrival_rate_qps";

    /// The per-stage time-series as CSV rows (pair with
    /// [`Self::SERIES_HEADER`]).
    pub fn series_csv(&self) -> Vec<String> {
        self.series
            .iter()
            .map(|p| {
                let busy_frac = if p.online > 0 {
                    p.busy as f64 / p.online as f64
                } else {
                    f64::NAN
                };
                format!(
                    "{},{},{},{},{},{},{}",
                    p.time,
                    p.stage,
                    p.queue,
                    p.busy,
                    p.online,
                    csv_cell(busy_frac),
                    csv_cell(p.arrival_rate),
                )
            })
            .collect()
    }

    /// Render the sampled spans, instants and stage tracks as a Chrome
    /// trace-event document (see the module docs for the schema). Events
    /// are sorted by timestamp with metadata first.
    pub fn chrome_trace(&self) -> Json {
        let n_stages = self.attribution.queueing.len();
        let mut events: Vec<(f64, Json)> = Vec::new();
        let mut meta = Json::obj();
        meta.set("name", "process_name")
            .set("ph", "M")
            .set("pid", 1usize)
            .set("tid", 0usize)
            .set("ts", 0.0)
            .set("args", {
                let mut a = Json::obj();
                a.set("name", "inferline-sim");
                a
            });
        events.push((f64::NEG_INFINITY, meta));
        for s in 0..n_stages {
            let mut m = Json::obj();
            m.set("name", "thread_name")
                .set("ph", "M")
                .set("pid", 1usize)
                .set("tid", s + 1)
                .set("ts", 0.0)
                .set("args", {
                    let mut a = Json::obj();
                    a.set("name", format!("stage {s}"));
                    a
                });
            events.push((f64::NEG_INFINITY, m));
        }
        for q in &self.spans {
            for h in &q.hops {
                if !h.dispatched.is_finite() {
                    continue;
                }
                let mut args = Json::obj();
                args.set("qid", q.qid)
                    .set("batch", h.batch_id as usize)
                    .set("batch_size", h.batch_size);
                let mut queue = Json::obj();
                queue
                    .set("name", format!("q{} queue", q.qid))
                    .set("cat", "queue")
                    .set("ph", "X")
                    .set("pid", 1usize)
                    .set("tid", h.stage as usize + 1)
                    .set("ts", h.enqueued * 1e6)
                    .set("dur", (h.dispatched - h.enqueued) * 1e6)
                    .set("args", args.clone());
                events.push((h.enqueued, queue));
                if h.completed.is_finite() {
                    let mut service = Json::obj();
                    service
                        .set("name", format!("q{} service", q.qid))
                        .set("cat", "service")
                        .set("ph", "X")
                        .set("pid", 1usize)
                        .set("tid", h.stage as usize + 1)
                        .set("ts", h.dispatched * 1e6)
                        .set("dur", (h.completed - h.dispatched) * 1e6)
                        .set("args", args);
                    events.push((h.dispatched, service));
                }
            }
        }
        for i in &self.instants {
            let mut e = Json::obj();
            e.set("name", i.name.as_str())
                .set("cat", "control")
                .set("ph", "i")
                .set("s", "g")
                .set("pid", 1usize)
                .set("tid", 0usize)
                .set("ts", i.time * 1e6)
                .set("args", {
                    let mut a = Json::obj();
                    a.set("detail", i.detail.as_str());
                    a
                });
            events.push((i.time, e));
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        let mut doc = Json::obj();
        doc.set("displayTimeUnit", "ms")
            .set("traceEvents", Json::Arr(events.into_iter().map(|(_, e)| e).collect()));
        doc
    }
}

/// One in-progress stage visit (internal mirror of [`HopSpan`]).
#[derive(Debug, Clone, Copy)]
struct Hop {
    stage: u16,
    enq: f64,
    disp: f64,
    done: f64,
    batch_id: u64,
    batch_size: u32,
}

/// Per-query bookkeeping, indexed by qid (qids are dense trace indices).
#[derive(Debug, Clone)]
struct Track {
    arrival: f64,
    done: f64,
    shed: bool,
    hops: Vec<Hop>,
}

/// Fixed seed of the deterministic span reservoir: the same run always
/// exports the same sampled queries, independent of trace length.
const RESERVOIR_SEED: u64 = 0x0BE5_E7A1;

/// The recording [`Probe`]. See the module docs for what it captures.
pub struct RecordingProbe {
    slo: f64,
    cadence: f64,
    sample_cap: usize,
    rng: Rng,
    n_stages: usize,
    tracks: Vec<Track>,
    reservoir: Vec<u32>,
    seen: usize,
    completed: usize,
    shed: usize,
    next_sample: f64,
    last_sample_t: f64,
    arrivals_since: usize,
    series: Vec<SeriesPoint>,
    instants: Vec<InstantEvent>,
}

impl RecordingProbe {
    /// Default time-series cadence (simulated seconds between samples).
    pub const DEFAULT_CADENCE: f64 = 1.0;
    /// Default span-reservoir capacity (queries with full span detail).
    pub const DEFAULT_SAMPLE_CAP: usize = 4096;

    pub fn new(slo: f64) -> Self {
        RecordingProbe {
            slo,
            cadence: Self::DEFAULT_CADENCE,
            sample_cap: Self::DEFAULT_SAMPLE_CAP,
            rng: Rng::new(RESERVOIR_SEED),
            n_stages: 0,
            tracks: Vec::new(),
            reservoir: Vec::new(),
            seen: 0,
            completed: 0,
            shed: 0,
            next_sample: 0.0,
            last_sample_t: 0.0,
            arrivals_since: 0,
            series: Vec::new(),
            instants: Vec::new(),
        }
    }

    /// Override the time-series cadence (seconds; must be positive).
    pub fn with_cadence(mut self, cadence: f64) -> Self {
        assert!(cadence > 0.0, "cadence must be positive");
        self.cadence = cadence;
        self
    }

    /// Override the span-reservoir capacity. A capacity at or above the
    /// trace length keeps every query's spans.
    pub fn with_sample_cap(mut self, cap: usize) -> Self {
        self.sample_cap = cap;
        self
    }

    fn hop_mut(&mut self, qid: u32, stage: usize) -> Option<&mut Hop> {
        self.tracks[qid as usize]
            .hops
            .iter_mut()
            .rev()
            .find(|h| h.stage == stage as u16)
    }

    /// Consume the probe and derive the report (spans for the final
    /// reservoir, the time-series, and the attribution table over all
    /// completed queries).
    pub fn finish(self) -> ProbeReport {
        let mut attribution = MissAttribution {
            missed: 0,
            completed: self.completed,
            shed: self.shed,
            queueing: vec![0.0; self.n_stages],
            service: vec![0.0; self.n_stages],
            rpc: 0.0,
            total_latency: 0.0,
        };
        for t in &self.tracks {
            if !t.done.is_finite() {
                continue;
            }
            let latency = t.done - t.arrival;
            if latency <= self.slo {
                continue;
            }
            attribution.missed += 1;
            attribution.total_latency += latency;
            let mut path_queue = 0.0;
            let mut path_service = 0.0;
            for &i in &critical_path(&t.hops) {
                let h = &t.hops[i];
                let q = h.disp - h.enq;
                let s = h.done - h.disp;
                attribution.queueing[h.stage as usize] += q;
                attribution.service[h.stage as usize] += s;
                path_queue += q;
                path_service += s;
            }
            attribution.rpc += latency - path_queue - path_service;
        }
        let mut sampled = self.reservoir;
        sampled.sort_unstable();
        let spans = sampled
            .into_iter()
            .map(|qid| {
                let t = &self.tracks[qid as usize];
                QuerySpans {
                    qid,
                    arrival: t.arrival,
                    done: t.done,
                    shed: t.shed,
                    hops: t
                        .hops
                        .iter()
                        .map(|h| HopSpan {
                            stage: h.stage,
                            enqueued: h.enq,
                            dispatched: h.disp,
                            completed: h.done,
                            batch_id: h.batch_id,
                            batch_size: h.batch_size,
                        })
                        .collect(),
                }
            })
            .collect();
        ProbeReport {
            spans,
            series: self.series,
            instants: self.instants,
            attribution,
            arrivals: self.tracks.len(),
            completed: self.completed,
            shed: self.shed,
        }
    }
}

/// Reconstruct the critical path through one query's hops: start from
/// the hop that completed last and repeatedly step to the latest hop
/// that completed at or before the current hop's enqueue (its upstream
/// dependency). Returns hop indices in root→completion order; empty
/// when no hop completed. On tree pipelines with parallel branches this
/// selects the chain that actually bounded the end-to-end latency.
fn critical_path(hops: &[Hop]) -> Vec<usize> {
    let mut path: Vec<usize> = Vec::new();
    let mut cur = match (0..hops.len())
        .filter(|&i| hops[i].done.is_finite())
        .max_by(|&a, &b| hops[a].done.partial_cmp(&hops[b].done).unwrap().then(a.cmp(&b)))
    {
        Some(i) => i,
        None => return path,
    };
    path.push(cur);
    loop {
        let enq = hops[cur].enq;
        let prev = (0..hops.len())
            .filter(|&i| {
                i != cur && !path.contains(&i) && hops[i].done.is_finite() && hops[i].done <= enq
            })
            .max_by(|&a, &b| {
                hops[a].done.partial_cmp(&hops[b].done).unwrap().then(a.cmp(&b))
            });
        match prev {
            Some(p) => {
                path.push(p);
                cur = p;
            }
            None => break,
        }
    }
    path.reverse();
    path
}

impl Probe for RecordingProbe {
    fn on_start(&mut self, n_stages: usize, n_queries: usize) {
        self.n_stages = n_stages;
        self.tracks.reserve(n_queries);
    }

    fn on_arrival(&mut self, qid: u32, t: f64) {
        debug_assert_eq!(qid as usize, self.tracks.len(), "qids arrive densely");
        self.tracks.push(Track { arrival: t, done: f64::NAN, shed: false, hops: Vec::new() });
        self.arrivals_since += 1;
        // Deterministic reservoir (Algorithm R with the fixed probe
        // seed): every query is equally likely to keep full span detail,
        // and the same run always samples the same qids.
        self.seen += 1;
        if self.reservoir.len() < self.sample_cap {
            self.reservoir.push(qid);
        } else if self.sample_cap > 0 {
            let j = self.rng.usize(self.seen);
            if j < self.sample_cap {
                self.reservoir[j] = qid;
            }
        }
    }

    fn on_enqueue(&mut self, stage: usize, qid: u32, t: f64) {
        self.tracks[qid as usize].hops.push(Hop {
            stage: stage as u16,
            enq: t,
            disp: f64::NAN,
            done: f64::NAN,
            batch_id: 0,
            batch_size: 0,
        });
    }

    fn on_dispatch(&mut self, stage: usize, batch_id: u64, qids: &[u32], t: f64, _done: f64) {
        let size = qids.len() as u32;
        for &qid in qids {
            if let Some(h) = self.hop_mut(qid, stage) {
                if h.disp.is_nan() {
                    h.disp = t;
                    h.batch_id = batch_id;
                    h.batch_size = size;
                }
            }
        }
    }

    fn on_visit_done(&mut self, stage: usize, qid: u32, t: f64) {
        if let Some(h) = self.hop_mut(qid, stage) {
            if h.done.is_nan() {
                h.done = t;
            }
        }
    }

    fn on_query_done(&mut self, qid: u32, t: f64) {
        self.tracks[qid as usize].done = t;
        self.completed += 1;
    }

    fn on_shed(&mut self, qid: u32, _t: f64) {
        let track = &mut self.tracks[qid as usize];
        if !track.shed {
            track.shed = true;
            self.shed += 1;
        }
    }

    fn on_retry(&mut self, stage: usize, qid: u32, _t: f64) {
        // The crashed batch's dispatch is void: the hop is back in the
        // queue and re-dispatches later (queueing resumes accruing).
        if let Some(h) = self.hop_mut(qid, stage) {
            if h.done.is_nan() {
                h.disp = f64::NAN;
            }
        }
    }

    fn on_fault(&mut self, kind: &str, stage: Option<usize>, t: f64) {
        self.instants.push(InstantEvent {
            time: t,
            name: format!("fault:{kind}"),
            detail: match stage {
                Some(s) => format!("stage={s}"),
                None => String::new(),
            },
        });
    }

    fn on_action(&mut self, action: &ControlAction, t: f64) {
        let (name, detail) = match *action {
            ControlAction::SetReplicas { stage, replicas } => {
                ("tuner:set-replicas", format!("stage={stage} replicas={replicas}"))
            }
            ControlAction::Halt { duration } => ("tuner:halt", format!("duration={duration}")),
        };
        self.instants.push(InstantEvent { time: t, name: name.to_string(), detail });
    }

    fn wants_sample(&self, t: f64) -> bool {
        t >= self.next_sample
    }

    fn on_sample(&mut self, t: f64, stages: &[StageSample]) {
        let dt = t - self.last_sample_t;
        let rate = if dt > 0.0 { self.arrivals_since as f64 / dt } else { f64::NAN };
        for (i, s) in stages.iter().enumerate() {
            self.series.push(SeriesPoint {
                time: t,
                stage: i as u16,
                queue: s.queue,
                busy: s.busy,
                online: s.online,
                arrival_rate: rate,
            });
        }
        self.last_sample_t = t;
        self.arrivals_since = 0;
        self.next_sample = t + self.cadence;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hop(stage: u16, enq: f64, disp: f64, done: f64) -> Hop {
        Hop { stage, enq, disp, done, batch_id: 1, batch_size: 1 }
    }

    #[test]
    fn critical_path_follows_the_bounding_chain() {
        // Root at stage 0 fans out to stages 1 and 2; stage 2 finishes
        // last, so the path is 0 -> 2 regardless of hop push order.
        let hops = vec![
            hop(0, 0.0, 0.1, 0.5),
            hop(1, 0.6, 0.6, 0.9),
            hop(2, 0.6, 0.8, 1.4),
        ];
        assert_eq!(critical_path(&hops), vec![0, 2]);
        // An undispatched hop is ignored; an empty track yields nothing.
        let partial = vec![hop(0, 0.0, 0.1, 0.5), hop(1, 0.6, f64::NAN, f64::NAN)];
        assert_eq!(critical_path(&partial), vec![0]);
        assert!(critical_path(&[]).is_empty());
    }

    #[test]
    fn reservoir_is_deterministic_and_bounded() {
        let run = |n: usize| {
            let mut p = RecordingProbe::new(0.1).with_sample_cap(8);
            p.on_start(1, n);
            for q in 0..n {
                p.on_arrival(q as u32, q as f64);
            }
            let mut r = p.reservoir.clone();
            r.sort_unstable();
            r
        };
        assert_eq!(run(100), run(100), "same run, same sample");
        assert_eq!(run(100).len(), 8);
        assert_eq!(run(5).len(), 5, "small traces keep everything");
    }

    #[test]
    fn attribution_splits_queueing_service_and_rpc() {
        let mut p = RecordingProbe::new(0.2);
        p.on_start(2, 2);
        // Query 0 misses: queued 0.3s at stage 0, served 0.2s, one RPC
        // hop, then 0.1s queue + 0.1s service at stage 1.
        p.on_arrival(0, 0.0);
        p.on_enqueue(0, 0, 0.0);
        p.on_dispatch(0, 1, &[0], 0.3, 0.5);
        p.on_visit_done(0, 0, 0.5);
        p.on_enqueue(1, 0, 0.6);
        p.on_dispatch(1, 2, &[0], 0.7, 0.8);
        p.on_visit_done(1, 0, 0.8);
        p.on_query_done(0, 0.8);
        // Query 1 hits the SLO: excluded from the table.
        p.on_arrival(1, 1.0);
        p.on_enqueue(0, 1, 1.0);
        p.on_dispatch(0, 3, &[1], 1.0, 1.1);
        p.on_visit_done(0, 1, 1.1);
        p.on_query_done(1, 1.1);
        let report = p.finish();
        let a = &report.attribution;
        assert_eq!(a.missed, 1);
        assert_eq!(a.completed, 2);
        assert_eq!(a.blame_stage(), Some(0));
        assert!((a.queueing[0] - 0.3).abs() < 1e-12, "{}", a.queueing[0]);
        assert!((a.service[0] - 0.2).abs() < 1e-12);
        assert!((a.queueing[1] - 0.1).abs() < 1e-12);
        assert!((a.service[1] - 0.1).abs() < 1e-12);
        // The split accounts for the full latency by construction.
        let path: f64 = a.queueing.iter().sum::<f64>() + a.service.iter().sum::<f64>();
        assert!(((path + a.rpc) - a.total_latency).abs() < 1e-12);
        // Completed query spans reproduce their latency bit-exactly.
        let q0 = &report.spans[0];
        assert_eq!(q0.latency().to_bits(), (0.8f64 - 0.0).to_bits());
    }

    #[test]
    fn chrome_trace_is_sorted_and_well_formed() {
        let mut p = RecordingProbe::new(0.05);
        p.on_start(2, 1);
        p.on_arrival(0, 0.0);
        p.on_enqueue(0, 0, 0.0);
        p.on_dispatch(0, 1, &[0], 0.2, 0.4);
        p.on_visit_done(0, 0, 0.4);
        p.on_query_done(0, 0.4);
        p.on_action(&ControlAction::SetReplicas { stage: 1, replicas: 3 }, 0.1);
        p.on_fault("crash", Some(0), 0.3);
        let doc = p.finish().chrome_trace();
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("trace must be valid JSON");
        let events = parsed.req("traceEvents").as_arr().unwrap();
        // 3 metadata + queue span + service span + action + fault.
        assert_eq!(events.len(), 7, "{text}");
        let mut last_ts = f64::NEG_INFINITY;
        let mut spans = 0;
        let mut instants = 0;
        for e in events {
            let ts = e.req("ts").as_f64().unwrap();
            assert!(ts >= last_ts, "timestamps must be monotone: {text}");
            last_ts = ts;
            match e.req("ph").as_str().unwrap() {
                "X" => {
                    assert!(e.req("dur").as_f64().unwrap() >= 0.0);
                    spans += 1;
                }
                "i" => instants += 1,
                "M" => {}
                other => panic!("unexpected phase {other:?}"),
            }
        }
        assert_eq!(spans, 2);
        assert_eq!(instants, 2);
    }

    #[test]
    fn series_samples_at_cadence_with_arrival_rate() {
        let mut p = RecordingProbe::new(0.1).with_cadence(1.0);
        p.on_start(1, 4);
        let snap = [StageSample { queue: 3, busy: 1, online: 2 }];
        assert!(p.wants_sample(0.0), "first sample is due immediately");
        p.on_sample(0.0, &snap);
        assert!(!p.wants_sample(0.5));
        p.on_arrival(0, 0.2);
        p.on_arrival(1, 0.4);
        assert!(p.wants_sample(1.25));
        p.on_sample(1.25, &snap);
        let report = p.finish();
        assert_eq!(report.series.len(), 2);
        let s = report.series[1];
        assert_eq!(s.queue, 3);
        assert_eq!(s.busy, 1);
        assert!((s.arrival_rate - 2.0 / 1.25).abs() < 1e-12);
        let rows = report.series_csv();
        assert_eq!(rows.len(), 2);
        assert!(rows[1].starts_with("1.25,0,3,1,2,0.5,"), "{}", rows[1]);
    }

    #[test]
    fn retry_voids_the_dispatch_and_shed_counts_once() {
        let mut p = RecordingProbe::new(0.1);
        p.on_start(1, 1);
        p.on_arrival(0, 0.0);
        p.on_enqueue(0, 0, 0.0);
        p.on_dispatch(0, 1, &[0], 0.1, 0.3);
        p.on_retry(0, 0, 0.2);
        p.on_shed(0, 0.2);
        p.on_shed(0, 0.2);
        let report = p.finish();
        assert_eq!(report.shed, 1);
        assert_eq!(report.completed, 0);
        assert_eq!(report.arrivals, 1);
        let h = &report.spans[0].hops[0];
        assert!(h.dispatched.is_nan(), "retry must void the dispatch");
        assert!(report.spans[0].shed);
    }
}
