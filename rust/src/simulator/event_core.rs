//! The engine's event core: a slab-backed queue of small `Copy` event
//! records, a recycled side arena for batch qid slices, and an indexed
//! cancelable slot table for scheduled replica activations.
//!
//! Three structural decisions, each preserving the old engine's simulated
//! outcomes bit for bit while removing its hot-loop overheads:
//!
//! * **Slab records.** [`EventRecord`] is a 24-byte `Copy` struct
//!   (`{time, seq, kind}` with `u32` payload handles). The old engine's
//!   heap moved an enum whose largest variant dragged a `Vec<u32>`
//!   through every sift — every push/pop paid the largest variant's size
//!   and a possible allocation. Batch qid slices now live in a
//!   [`SliceArena`] and only their handle travels through the heap.
//!   Ordering is unchanged: earliest `time` first, ties broken by lowest
//!   `seq` (FIFO among simultaneous events).
//!
//! * **Coalesced delivery.** After a batch completes, every routed
//!   (query, child) hop lands at the same `now + rpc`, so the engine
//!   emits one [`EventKind::Delivery`] record carrying the batch's qid
//!   slice instead of one `Enqueue` record per query per hop — a batch of
//!   32 into 2 children is one heap op, not 64. The delivery handler
//!   replays the hops in exactly the order the individual records would
//!   have popped (they were seq-contiguous at one time, so nothing could
//!   interleave between them).
//!
//! * **Indexed cancellation.** Scheduled `ReplicaUp` events are pushed
//!   through [`EventQueue::push_replica_up`], which hands back a
//!   generation-checked [`UpHandle`]. Scale-down cancels the handle
//!   directly; a later scale-up can revive it (the record is still
//!   scheduled at its original activation time, so a rate flap pays no
//!   second activation delay). Cancelled records stay in the heap as
//!   tombstones and are swallowed when they pop — deliberately, because
//!   the old stale-event scheme kept controlled runs (and their control
//!   ticks) alive until those events drained, and termination must not
//!   change. The queue also maintains an O(1) count of non-tick records
//!   (tombstones included) so the controlled-mode termination check is a
//!   counter read instead of an O(heap) scan.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Event payload. Batch qid slices are [`SliceArena`] handles; `slot`
/// indexes the queue's cancelable slot table. Every variant is `Copy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A replica finished a batch at `stage`; its qids are in `slice`.
    BatchDone { stage: u16, slice: u32 },
    /// Coalesced routing hop: the batch in `slice` (completed at `stage`
    /// one RPC earlier) lands at its routed children now.
    Delivery { stage: u16, slice: u32 },
    /// A provisioned replica comes online (cancelable via `slot`).
    ReplicaUp { stage: u16, slot: u32 },
    /// Controller tick (controlled mode).
    ControlTick,
    /// End of a DS2-style pipeline halt: dispatch everywhere.
    Resume,
    /// Scheduled fault injection: `idx` indexes the run's compiled
    /// [`FaultPlan`](super::faults::FaultPlan) entries. Pushed only when
    /// a non-empty plan is active, so fault-free runs pay nothing.
    Fault { idx: u32 },
}

/// A small `Copy` event record. `seq` is stamped by the queue on push and
/// makes the ordering total: earliest `time` pops first, ties go to the
/// lowest `seq` (insertion order).
#[derive(Debug, Clone, Copy)]
pub struct EventRecord {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for EventRecord {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for EventRecord {}
impl PartialOrd for EventRecord {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EventRecord {
    // Reverse ordering: BinaryHeap is a max-heap, we want earliest first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Generation-checked handle to a scheduled (cancelable) `ReplicaUp`
/// record. Stale handles — whose record already popped — fail every
/// operation instead of aliasing a reused slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpHandle {
    slot: u32,
    gen: u32,
}

impl UpHandle {
    /// The slot index carried by the corresponding `ReplicaUp` record.
    pub fn slot(&self) -> u32 {
        self.slot
    }
}

#[derive(Debug, Clone, Copy)]
struct CancelSlot {
    gen: u32,
    live: bool,
}

/// The event queue: a binary heap of [`EventRecord`]s plus the slot table
/// backing [`UpHandle`] cancellation and the O(1) non-tick counter.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<EventRecord>,
    seq: u64,
    /// Records in the heap that are not `ControlTick` — including
    /// cancelled-activation tombstones until they pop. Controlled-mode
    /// termination reads this instead of scanning the heap.
    non_tick: usize,
    slots: Vec<CancelSlot>,
    free_slots: Vec<u32>,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Push a record at `time`, stamping the next sequence number.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        if !matches!(kind, EventKind::ControlTick) {
            self.non_tick += 1;
        }
        self.heap.push(EventRecord { time, seq: self.seq, kind });
    }

    /// Schedule a cancelable `ReplicaUp` for `stage` at `time`.
    pub fn push_replica_up(&mut self, time: f64, stage: u16) -> UpHandle {
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.slots[s as usize].live = true;
                s
            }
            None => {
                self.slots.push(CancelSlot { gen: 0, live: true });
                (self.slots.len() - 1) as u32
            }
        };
        self.push(time, EventKind::ReplicaUp { stage, slot });
        UpHandle { slot, gen: self.slots[slot as usize].gen }
    }

    /// Cancel a scheduled activation. The record stays in the heap as a
    /// tombstone (swallowed on pop); returns false on a stale handle.
    pub fn cancel(&mut self, h: UpHandle) -> bool {
        match self.slots.get_mut(h.slot as usize) {
            Some(s) if s.gen == h.gen && s.live => {
                s.live = false;
                true
            }
            _ => false,
        }
    }

    /// Revive a cancelled activation: the record is still scheduled at
    /// its original time, so the replica comes online with no new delay.
    /// Returns false on a stale handle (the tombstone already popped).
    pub fn uncancel(&mut self, h: UpHandle) -> bool {
        match self.slots.get_mut(h.slot as usize) {
            Some(s) if s.gen == h.gen && !s.live => {
                s.live = true;
                true
            }
            _ => false,
        }
    }

    /// Retire a popped `ReplicaUp` record's slot; returns whether the
    /// activation was still live (false = cancelled tombstone: swallow).
    /// Bumps the generation so outstanding handles to this slot go stale.
    pub fn resolve_up(&mut self, slot: u32) -> bool {
        let s = &mut self.slots[slot as usize];
        let was_live = s.live;
        s.gen = s.gen.wrapping_add(1);
        s.live = false;
        self.free_slots.push(slot);
        was_live
    }

    /// Earliest scheduled time, tombstones included — cancelled records
    /// must still win arrival-merge ties exactly as live ones would, and
    /// may yet be revived before they pop.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest record (physical: tombstones pop too; the caller
    /// routes `ReplicaUp` records through [`Self::resolve_up`]).
    pub fn pop(&mut self) -> Option<EventRecord> {
        let rec = self.heap.pop();
        if let Some(r) = &rec {
            if !matches!(r.kind, EventKind::ControlTick) {
                self.non_tick -= 1;
            }
        }
        rec
    }

    /// Number of non-`ControlTick` records still in the heap (tombstones
    /// included): the controlled-mode termination test in O(1).
    pub fn non_tick_len(&self) -> usize {
        self.non_tick
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Recycled arena for batch qid slices. A slice is allocated when a batch
/// dispatches, travels through [`BatchDone`](EventKind::BatchDone) and
/// (if the batch routes anywhere) [`Delivery`](EventKind::Delivery) by
/// `u32` handle, and is freed back to the pool afterwards — one live
/// allocation per *concurrent* batch, none per batch.
#[derive(Default)]
pub struct SliceArena {
    slots: Vec<Vec<u32>>,
    free: Vec<u32>,
}

impl SliceArena {
    pub fn new() -> Self {
        SliceArena::default()
    }

    /// Allocate an empty slice and return its handle.
    pub fn alloc(&mut self) -> u32 {
        match self.free.pop() {
            Some(h) => h,
            None => {
                self.slots.push(Vec::new());
                (self.slots.len() - 1) as u32
            }
        }
    }

    pub fn get(&self, h: u32) -> &[u32] {
        &self.slots[h as usize]
    }

    pub fn get_mut(&mut self, h: u32) -> &mut Vec<u32> {
        &mut self.slots[h as usize]
    }

    /// Return a slice to the pool (its buffer keeps its capacity).
    pub fn free(&mut self, h: u32) {
        self.slots[h as usize].clear();
        self.free.push(h);
    }
}

// ---------------------------------------------------------------------
// Synthetic churn drivers for the event-core microbenchmark and the perf
// ledger. Both simulate the same logical workload — batches of BATCH
// qids fanning out to FANOUT children, hops re-aggregating into new
// batches — and fold every processed hop into a checksum, so equal
// checksums mean equal work in identical order. `churn_reference`
// models the *old* engine's queue (boxed `Vec<u32>` payloads in the
// heap, one record per hop); `churn_event_core` runs the same workload
// through the slab queue with coalesced delivery. The measured ratio is
// the isolated event-core win, free of planner logic.
// ---------------------------------------------------------------------

const CHURN_BATCH: usize = 16;
const CHURN_FANOUT: u32 = 2;

fn fold(checksum: u64, hop: u64) -> u64 {
    checksum.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(hop)
}

/// Old-style queue: an enum event whose batch variant owns a `Vec<u32>`,
/// one heap record per (query, child) hop.
pub fn churn_reference(target_hops: usize) -> u64 {
    enum RefKind {
        Batch(Vec<u32>),
        Hop(u32),
    }
    struct RefEvent {
        time: f64,
        seq: u64,
        kind: RefKind,
    }
    impl PartialEq for RefEvent {
        fn eq(&self, other: &Self) -> bool {
            self.time == other.time && self.seq == other.seq
        }
    }
    impl Eq for RefEvent {}
    impl PartialOrd for RefEvent {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for RefEvent {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .time
                .partial_cmp(&self.time)
                .unwrap_or(Ordering::Equal)
                .then(other.seq.cmp(&self.seq))
        }
    }
    let mut heap: BinaryHeap<RefEvent> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push = |heap: &mut BinaryHeap<RefEvent>, time: f64, kind: RefKind| {
        seq += 1;
        heap.push(RefEvent { time, seq, kind });
    };
    let mut checksum = 0u64;
    let mut hops = 0usize;
    let mut pending: Vec<u32> = Vec::new();
    push(&mut heap, 0.0, RefKind::Batch((0..CHURN_BATCH as u32).collect()));
    while hops < target_hops {
        let ev = heap.pop().expect("churn workload drained early");
        match ev.kind {
            RefKind::Batch(qids) => {
                for &q in &qids {
                    for c in 0..CHURN_FANOUT {
                        push(&mut heap, ev.time + 1.0, RefKind::Hop(q ^ c));
                    }
                }
            }
            RefKind::Hop(q) => {
                checksum = fold(checksum, q as u64);
                hops += 1;
                pending.push(q);
                if pending.len() == CHURN_BATCH {
                    push(&mut heap, ev.time + 0.5, RefKind::Batch(std::mem::take(&mut pending)));
                }
            }
        }
    }
    checksum
}

/// The same workload through the slab queue: one `BatchDone` and one
/// coalesced `Delivery` record per batch, hops processed inline.
pub fn churn_event_core(target_hops: usize) -> u64 {
    let mut queue = EventQueue::new();
    let mut arena = SliceArena::new();
    let mut checksum = 0u64;
    let mut hops = 0usize;
    let mut pending: Vec<u32> = Vec::new();
    let seed = arena.alloc();
    arena.get_mut(seed).extend(0..CHURN_BATCH as u32);
    queue.push(0.0, EventKind::BatchDone { stage: 0, slice: seed });
    while hops < target_hops {
        let ev = queue.pop().expect("churn workload drained early");
        match ev.kind {
            EventKind::BatchDone { slice, .. } => {
                queue.push(ev.time + 1.0, EventKind::Delivery { stage: 0, slice });
            }
            EventKind::Delivery { slice, .. } => {
                let qids = std::mem::take(arena.get_mut(slice));
                for &q in &qids {
                    for c in 0..CHURN_FANOUT {
                        if hops >= target_hops {
                            break;
                        }
                        checksum = fold(checksum, (q ^ c) as u64);
                        hops += 1;
                        pending.push(q ^ c);
                        if pending.len() == CHURN_BATCH {
                            let h = arena.alloc();
                            arena.get_mut(h).append(&mut pending);
                            queue.push(ev.time + 0.5, EventKind::BatchDone { stage: 0, slice: h });
                        }
                    }
                }
                *arena.get_mut(slice) = qids;
                arena.free(slice);
            }
            _ => unreachable!("churn workload only uses batch/delivery records"),
        }
    }
    checksum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_earliest_time_then_lowest_seq() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::Resume);
        q.push(1.0, EventKind::ControlTick);
        q.push(1.0, EventKind::Resume);
        q.push(0.5, EventKind::BatchDone { stage: 3, slice: 7 });
        let mut order = Vec::new();
        while let Some(e) = q.pop() {
            order.push((e.time, e.seq));
        }
        assert_eq!(order, vec![(0.5, 4), (1.0, 2), (1.0, 3), (2.0, 1)]);
    }

    #[test]
    fn non_tick_counter_tracks_pushes_pops_and_tombstones() {
        let mut q = EventQueue::new();
        assert_eq!(q.non_tick_len(), 0);
        q.push(1.0, EventKind::ControlTick);
        assert_eq!(q.non_tick_len(), 0);
        q.push(2.0, EventKind::Resume);
        let h = q.push_replica_up(3.0, 0);
        assert_eq!(q.non_tick_len(), 2);
        // A cancelled activation is a tombstone: still counted until it
        // physically pops (it keeps controlled runs alive, as the old
        // stale-event scheme did).
        assert!(q.cancel(h));
        assert_eq!(q.non_tick_len(), 2);
        q.pop(); // tick
        assert_eq!(q.non_tick_len(), 2);
        q.pop(); // resume
        assert_eq!(q.non_tick_len(), 1);
        let up = q.pop().unwrap(); // tombstone pops physically
        assert!(matches!(up.kind, EventKind::ReplicaUp { .. }));
        assert_eq!(q.non_tick_len(), 0);
    }

    #[test]
    fn cancel_revive_and_stale_handles() {
        let mut q = EventQueue::new();
        let h = q.push_replica_up(5.0, 1);
        assert!(!q.uncancel(h), "live activation cannot be revived");
        assert!(q.cancel(h));
        assert!(!q.cancel(h), "double-cancel must fail");
        assert!(q.uncancel(h), "cancelled activation revives");
        assert!(q.cancel(h));
        let rec = q.pop().unwrap();
        let EventKind::ReplicaUp { stage, slot } = rec.kind else {
            panic!("expected ReplicaUp");
        };
        assert_eq!(stage, 1);
        // Popped while cancelled: resolve reports it dead...
        assert!(!q.resolve_up(slot));
        // ...and the handle is stale for every further operation, even
        // after the slot is recycled for a new activation.
        assert!(!q.uncancel(h));
        assert!(!q.cancel(h));
        let h2 = q.push_replica_up(6.0, 2);
        assert_eq!(h2.slot(), h.slot(), "slot should be recycled");
        assert!(!q.cancel(h), "stale handle must not alias the recycled slot");
        let rec2 = q.pop().unwrap();
        let EventKind::ReplicaUp { slot, .. } = rec2.kind else {
            panic!("expected ReplicaUp");
        };
        assert!(q.resolve_up(slot), "live activation resolves live");
    }

    #[test]
    fn peek_time_includes_tombstones() {
        let mut q = EventQueue::new();
        let h = q.push_replica_up(1.0, 0);
        q.push(2.0, EventKind::Resume);
        assert!(q.cancel(h));
        // The tombstone at t=1 still owns the head of the queue: arrival
        // merging (and potential revival) must see its original time.
        assert_eq!(q.peek_time(), Some(1.0));
    }

    #[test]
    fn arena_recycles_slots_and_keeps_contents_isolated() {
        let mut a = SliceArena::new();
        let h1 = a.alloc();
        a.get_mut(h1).extend([1, 2, 3]);
        let h2 = a.alloc();
        a.get_mut(h2).extend([9]);
        assert_eq!(a.get(h1), &[1, 2, 3]);
        assert_eq!(a.get(h2), &[9]);
        a.free(h1);
        let h3 = a.alloc();
        assert_eq!(h3, h1, "freed slot is reused");
        assert!(a.get(h3).is_empty(), "recycled slice starts empty");
        assert_eq!(a.get(h2), &[9], "other slices untouched");
    }

    #[test]
    fn churn_drivers_do_identical_work() {
        // Equal checksums mean the coalesced-delivery driver processed
        // exactly the hops the per-hop reference did, in the same order —
        // the benchmark compares equal work, not shortcuts.
        for &n in &[1usize, 100, 5_000, 40_000] {
            assert_eq!(churn_reference(n), churn_event_core(n), "hops={n}");
        }
    }
}
