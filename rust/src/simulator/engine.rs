//! The discrete-event engine shared by the open-loop Estimator and the
//! controlled (tuner-in-the-loop) simulation, built on the
//! [`event_core`](super::event_core) queue: small `Copy` event records in
//! the heap, batch qid slices in a recycled side arena, one coalesced
//! `Delivery` record per routed batch, and generation-checked
//! cancellation for scheduled replica activations. See the module docs
//! of [`super`] and [`super::event_core`] for the architecture; the
//! invariant that governs every choice here is that simulated outcomes
//! are bit-identical to the pre-event-core engine.

use std::collections::VecDeque;

use crate::config::{PipelineConfig, PipelineSpec};
use crate::profiler::ProfileSet;
use crate::workload::{ArrivalSource, Trace};

use super::control::{ControlAction, ControlState, Controller};
use super::event_core::{EventKind, EventQueue, SliceArena, UpHandle};
use super::faults::{FaultAction, FaultEntry, FaultPlan};
use super::probe::{Probe, StageSample};
use super::routing::{RoutingPlan, RoutingSampler};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Seed for the per-query conditional-routing RNG. Fixed seed =>
    /// identical routing across configurations (paper §6: traces are
    /// "reused across all comparison points").
    pub routing_seed: u64,
    /// Seconds a newly requested replica takes to come online (paper §5:
    /// "the 5 second activation time of spinning up new replicas").
    pub replica_activation_delay: f64,
    /// Controller tick interval (controlled mode only).
    pub control_interval: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            routing_seed: 0x1FE7_11E5,
            replica_activation_delay: 5.0,
            control_interval: 1.0,
        }
    }
}

/// Per-stage simulation statistics.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Largest instantaneous queue depth observed.
    pub max_queue: usize,
    /// Number of batches executed.
    pub batches: usize,
    /// Total queries processed.
    pub queries: usize,
    /// Aggregate replica busy time (seconds x replicas).
    pub busy_time: f64,
    /// Mean batch size actually formed.
    pub mean_batch: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end latency of every completed query (completion order).
    pub latencies: Vec<f64>,
    /// (completion time, latency) pairs, completion order.
    pub completions: Vec<(f64, f64)>,
    /// Per-stage statistics.
    pub stage_stats: Vec<StageStats>,
    /// Simulated time when the last query completed.
    pub horizon: f64,
    /// Dollars spent (controlled mode; open-loop = config cost x horizon).
    pub cost_dollars: f64,
    /// (time, total provisioned replicas) timeline (controlled mode).
    pub replica_timeline: Vec<(f64, usize)>,
    /// Replica crashes applied (fault injection only; 0 otherwise).
    pub crashes: u64,
    /// Crashed-batch query requeues (bounded by the plan's `max_retries`).
    pub retries: u64,
    /// Queries dropped by the deadline-shed policy or retry exhaustion.
    /// Shed queries never complete: they are counted here, separately
    /// from SLO misses, and appear in no latency vector.
    pub shed: u64,
}

impl SimResult {
    /// SLO miss rate over all completed queries.
    pub fn miss_rate(&self, slo: f64) -> f64 {
        1.0 - crate::util::stats::attainment(&self.latencies, slo)
    }

    /// P99 miss-rate series over fixed windows of completion time:
    /// (window end, miss rate). Used by the Fig 6/7/10-12 plots. Windows
    /// with zero completions report `NaN` — there is no data, and a
    /// fabricated 0.0 would read as a perfect-attainment window; plots
    /// skip NaN points.
    pub fn miss_rate_series(&self, slo: f64, window: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut idx = 0usize;
        let mut w_end = window;
        while idx < self.completions.len() {
            let mut total = 0usize;
            let mut missed = 0usize;
            while idx < self.completions.len() && self.completions[idx].0 <= w_end {
                total += 1;
                if self.completions[idx].1 > slo {
                    missed += 1;
                }
                idx += 1;
            }
            out.push((
                w_end,
                if total == 0 { f64::NAN } else { missed as f64 / total as f64 },
            ));
            w_end += window;
        }
        out
    }
}

/// Streamed-run completion aggregates: what the engine folds each
/// completion into instead of pushing onto `SimResult`'s vectors.
struct StreamAgg {
    /// SLO the miss tally is counted against (fixed for the whole run —
    /// streamed summaries cannot re-derive misses at another SLO).
    slo: f64,
    completed: u64,
    misses: u64,
    latency_sum: f64,
    max_latency: f64,
}

/// Aggregate output of a streamed open-loop run ([`simulate_streamed`]).
///
/// Everything here is derivable from a materialized [`SimResult`] by
/// folding its vectors in completion order — bit-exactly, which is what
/// `tests/streaming_conformance.rs` asserts. Quantities that need the
/// full latency vector (P99, miss-rate series) are deliberately absent:
/// holding the vector is exactly what streaming avoids. (A fixed-memory
/// quantile sketch is possible future work; the robustness/budget
/// ledgers keep using materialized runs for P99.)
#[derive(Debug, Clone)]
pub struct StreamSummary {
    /// Queries pulled from the arrival source.
    pub queries: u64,
    /// Queries completed (== `queries` in open loop: nothing sheds).
    pub completed: u64,
    /// Completions with end-to-end latency strictly over the SLO.
    pub misses: u64,
    /// Sum of end-to-end latencies, folded in completion order.
    pub latency_sum: f64,
    /// Largest end-to-end latency observed.
    pub max_latency: f64,
    /// Simulated time of the last processed arrival or event.
    pub horizon: f64,
    /// Open-loop cost: static config rate x horizon.
    pub cost_dollars: f64,
    /// Per-stage statistics (same shape as [`SimResult::stage_stats`]).
    pub stage_stats: Vec<StageStats>,
    /// Largest number of query records resident at once. With prefix
    /// compaction this tracks the in-flight window, not the horizon —
    /// the engine's working-set measure and the number the long-horizon
    /// CI smoke bounds.
    pub peak_queries_resident: usize,
}

impl StreamSummary {
    /// Mean end-to-end latency (0.0 with no completions).
    pub fn mean_latency(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_sum / self.completed as f64
        }
    }

    /// SLO miss rate over completed queries (0.0 with no completions).
    pub fn miss_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.misses as f64 / self.completed as f64
        }
    }
}

struct StageState {
    queue: VecDeque<u32>,
    idle: usize,
    /// Online replicas (busy + idle).
    online: usize,
    /// Live scheduled activations, earliest first (the queue-side records
    /// are cancelable through these handles).
    pending_up: VecDeque<UpHandle>,
    /// Busy replicas that must retire upon finishing their batch.
    retire_debt: usize,
    /// Cancelled-but-still-scheduled activations, most recent last. A
    /// scale-up revives from the top (latest activation time — exactly
    /// the record the old count-based bookkeeping would have left live);
    /// handles whose tombstone already popped go stale and drop out.
    cancelled_up: Vec<UpHandle>,
    batch: usize,
    /// latency_table[n] = batch-processing latency for a batch of n.
    latency_table: Vec<f64>,
    stats: super::StageStats,
    batch_size_sum: usize,
}

impl StageState {
    fn provisioned(&self) -> usize {
        self.online + self.pending_up.len() - self.retire_debt.min(self.online)
    }
}

#[derive(Clone, Copy)]
struct QueryState {
    arrival: f64,
    /// Bitmask of visited stages (pipelines are <= 32 stages).
    visited: u32,
    /// Stage completions still outstanding.
    remaining: u8,
    /// Budgeted runs only: this query was already counted as a guaranteed
    /// SLO hit at dispatch time (its final batch was in flight with a
    /// known completion time), so completion must not count it again.
    hit_counted: bool,
    /// Fault runs only: dropped by the deadline-shed policy or retry
    /// exhaustion. A shed query never completes and is skipped wherever
    /// it still sits (queues, in-flight batches, delivery hops).
    shed: bool,
    /// Fault runs only: crashed-batch requeues consumed so far.
    retries: u8,
}

/// Early-abort budget for feasibility simulations: the SLO the run is
/// being checked against.
struct AbortBudget {
    slo: f64,
}

/// How a budgeted feasibility simulation ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetVerdict {
    /// The whole trace was simulated: the exact latency vector (and hence
    /// the exact P99) is available.
    Completed,
    /// Early abort: enough queries were *guaranteed* to miss the SLO that
    /// P99 > SLO was already proven.
    ProvedInfeasible,
    /// Early accept: enough queries had *provably met* the SLO that
    /// P99 <= SLO was already proven.
    ProvedFeasible,
}

/// In-flight bookkeeping for a budgeted run, symmetric in both verdicts.
///
/// `misses` counts *guaranteed* misses: completed queries over the SLO
/// plus in-flight queries already older than the SLO (their latency can
/// only grow). Once `misses` reaches `threshold`, the sorted latency
/// vector provably has its interpolated P99 above the SLO no matter how
/// the remaining queries finish.
///
/// `hits` counts *guaranteed* hits: completed queries at or under the SLO
/// plus in-flight queries in their final batch whose (already scheduled,
/// never cancelled in open loop) completion time keeps them under it.
/// Once `hits` reaches `accept_threshold`, P99 <= SLO is certain even if
/// every remaining query misses.
///
/// Either way the simulation may stop with a verdict bit-identical to the
/// full run's; the two conditions are mutually exclusive (a query is
/// counted in at most one of the two tallies, and each threshold claims
/// more than the leftover after the other fires).
struct BudgetState {
    slo: f64,
    /// Guaranteed-miss count proving P99 > SLO: with `n` trace queries
    /// and interpolation position `pos = 0.99 (n-1)`, P99 certainly
    /// exceeds the SLO once sorted[floor(pos)] does — misses sort above
    /// every non-miss, so that takes `n - floor(pos)` of them. (Below the
    /// threshold the interpolated P99 may still exceed the SLO; the full
    /// run decides, so the abort is sound, never eager.)
    threshold: usize,
    misses: usize,
    /// Arrival-order cursor for the deadline sweep: every query below it
    /// has either completed or been counted as a guaranteed miss.
    deadline_idx: usize,
    /// Guaranteed-hit count proving P99 <= SLO: the clamped interpolated
    /// quantile satisfies P99 <= sorted[ceil(pos)] bit-exactly (the same
    /// clamp the abort bound leans on, from the other side), and hits
    /// sort below every non-hit, so `ceil(pos) + 1` of them pin
    /// sorted[ceil(pos)] at or under the SLO no matter how the remaining
    /// queries finish — including queries that have not even arrived yet
    /// when the accept fires (the threshold is derived from the *full*
    /// trace length, never from completions so far).
    accept_threshold: usize,
    hits: usize,
}

impl BudgetState {
    fn new(budget: AbortBudget, n_queries: usize) -> Self {
        let (lo, hi) = if n_queries == 0 {
            (0, 0)
        } else {
            let pos = 0.99 * (n_queries - 1) as f64;
            (pos.floor() as usize, pos.ceil() as usize)
        };
        BudgetState {
            slo: budget.slo,
            threshold: (n_queries - lo).max(1),
            misses: 0,
            deadline_idx: 0,
            // An empty trace must never accept: its full-run P99 is NaN,
            // which compares infeasible at every SLO.
            accept_threshold: if n_queries == 0 { usize::MAX } else { hi + 1 },
            hits: 0,
        }
    }

    /// Count one guaranteed hit; returns true once P99 <= SLO is proven.
    fn count_hit(&mut self) -> bool {
        self.hits += 1;
        self.hits >= self.accept_threshold
    }
}

/// Fault-injection state, allocated only for a non-empty [`FaultPlan`].
/// Every fault branch in the hot loop is gated on `Engine::faults` being
/// `Some`, so fault-free runs are bit-identical to the pre-fault engine
/// (enforced by the conformance suites).
struct FaultRuntime {
    /// Compiled injections, time-sorted; `EventKind::Fault { idx }`
    /// indexes into this list.
    entries: Vec<FaultEntry>,
    /// Requeue bound for a crashed batch's queries (then shed).
    max_retries: u32,
    /// Deadline-shed bound: drop queries older than this at dispatch.
    shed_after: Option<f64>,
    /// Per-stage batch-latency multiplier (1.0 = nominal).
    slow: Vec<f64>,
    /// Per-stage outage depth; dispatch is frozen while > 0.
    outage: Vec<u32>,
    /// In-flight batch slices per stage in dispatch order; a crash kills
    /// the replica that dispatched most recently (pops the back).
    inflight: Vec<Vec<u32>>,
    /// Slices whose replica crashed mid-batch: their stale `BatchDone`
    /// is swallowed when it pops.
    doomed: Vec<u32>,
}

/// The simulation engine. Public entry points are [`simulate`] (open loop)
/// and [`super::control::simulate_controlled`].
pub(super) struct Engine<'a> {
    spec: &'a PipelineSpec,
    params: &'a SimParams,
    stages: Vec<StageState>,
    queries: Vec<QueryState>,
    events: EventQueue,
    /// Recycled qid-slice storage; only `u32` handles enter the heap.
    arena: SliceArena,
    rpc: f64,
    /// DS2-style halt: no dispatch until this time.
    halted_until: f64,
    /// Early-abort / fast-accept accounting for budgeted feasibility runs.
    budget: Option<BudgetState>,
    aborted: bool,
    accepted: bool,
    /// Fault-injection runtime (`None` ⇔ empty plan ⇔ the zero-overhead
    /// fault-free path).
    faults: Option<FaultRuntime>,
    /// Telemetry observer (`None` ⇔ the zero-overhead probe-less path;
    /// same gating discipline as `faults`, see [`super::probe`]).
    probe: Option<&'a mut dyn Probe>,
    /// Monotone batch id handed to the probe (probe runs only; the
    /// counter is touched exclusively inside probe-gated branches).
    batch_seq: u64,
    /// Queries not yet completed or shed (run-loop termination).
    outstanding: usize,
    /// Streamed runs only: absolute qid of `queries[0]`. Compaction
    /// drains the completed prefix of the query table and advances this
    /// base, so `queries[qid - query_base]` keeps resolving absolute
    /// qids. Always 0 in materialized runs — every index site subtracts
    /// it, which is bit-exact there.
    query_base: usize,
    /// Streamed runs only: O(1) completion aggregates replacing the
    /// per-query result vectors (`None` ⇔ the materialized path).
    stream: Option<StreamAgg>,
    result: SimResult,
    // Cost accounting (controlled mode).
    last_cost_time: f64,
    cost_rate_per_hour: f64,
}

impl<'a> Engine<'a> {
    pub(super) fn new(
        spec: &'a PipelineSpec,
        profiles: &'a ProfileSet,
        config: &PipelineConfig,
        params: &'a SimParams,
    ) -> Self {
        debug_assert!(spec.stages.len() <= 32, "visited bitmask limit");
        assert_eq!(spec.stages.len(), config.stages.len());
        let stages = spec
            .stages
            .iter()
            .zip(&config.stages)
            .map(|(s, c)| {
                let prof = profiles
                    .get(&s.model)
                    .get(c.hw)
                    .unwrap_or_else(|| panic!("no {} profile for {}", c.hw, s.model));
                assert!(c.batch >= 1 && c.replicas >= 1, "bad stage config");
                let latency_table: Vec<f64> =
                    (0..=c.batch).map(|n| if n == 0 { 0.0 } else { prof.latency(n) }).collect();
                StageState {
                    queue: VecDeque::new(),
                    idle: c.replicas,
                    online: c.replicas,
                    pending_up: VecDeque::new(),
                    retire_debt: 0,
                    cancelled_up: Vec::new(),
                    batch: c.batch,
                    latency_table,
                    stats: super::StageStats::default(),
                    batch_size_sum: 0,
                }
            })
            .collect();
        let cost0: f64 = config.cost_per_hour();
        Engine {
            spec,
            params,
            stages,
            queries: Vec::new(),
            events: EventQueue::new(),
            arena: SliceArena::new(),
            rpc: spec.framework.rpc_overhead(),
            halted_until: 0.0,
            budget: None,
            aborted: false,
            accepted: false,
            faults: None,
            probe: None,
            batch_seq: 0,
            outstanding: 0,
            query_base: 0,
            stream: None,
            result: SimResult {
                latencies: Vec::new(),
                completions: Vec::new(),
                stage_stats: Vec::new(),
                horizon: 0.0,
                cost_dollars: 0.0,
                replica_timeline: Vec::new(),
                crashes: 0,
                retries: 0,
                shed: 0,
            },
            last_cost_time: 0.0,
            cost_rate_per_hour: cost0,
        }
    }

    /// Activate fault injection for a non-empty plan. An empty (or
    /// absent) plan allocates nothing and leaves every fault branch cold,
    /// keeping the run bit-identical to the fault-free engine.
    pub(super) fn with_faults(mut self, plan: Option<&FaultPlan>) -> Self {
        if let Some(p) = plan {
            if !p.is_empty() {
                let n = self.stages.len();
                self.faults = Some(FaultRuntime {
                    entries: p.entries.clone(),
                    max_retries: p.max_retries,
                    shed_after: p.shed_after,
                    slow: vec![1.0; n],
                    outage: vec![0; n],
                    inflight: vec![Vec::new(); n],
                    doomed: Vec::new(),
                });
            }
        }
        self
    }

    /// Attach a telemetry probe (a read-only observer; see
    /// [`super::probe`] for the contract). `None` leaves every probe
    /// branch cold, keeping the run bit-identical to an engine without
    /// the plumbing — the same gating discipline as [`Self::with_faults`].
    pub(super) fn with_probe(mut self, probe: Option<&'a mut dyn Probe>) -> Self {
        self.probe = probe;
        self
    }

    /// Populate per-query state from a routing plan — either one shared
    /// by the caller (the planner reuses one plan across every candidate
    /// simulation of a search) or a locally built one. Both paths sample
    /// identically, so results are bit-identical either way.
    fn seed_arrivals(&mut self, trace: &Trace, routing: Option<&RoutingPlan>) {
        let local;
        let plan = match routing {
            Some(p) => {
                assert_eq!(p.len(), trace.len(), "routing plan / trace length mismatch");
                p
            }
            None => {
                local = RoutingPlan::build(self.spec, trace, self.params.routing_seed);
                &local
            }
        };
        self.queries = plan
            .visits
            .iter()
            .zip(&trace.arrivals)
            .map(|(&(visited, remaining), &arrival)| QueryState {
                arrival,
                visited,
                remaining,
                hit_counted: false,
                shed: false,
                retries: 0,
            })
            .collect();
        self.result.latencies.reserve(trace.len());
        self.result.completions.reserve(trace.len());
        // NB: arrival *events* are not pushed; run() merges the sorted
        // arrival list lazily against the heap.
    }

    /// Budgeted-run deadline sweep (the queue-divergence bailout): any
    /// arrived-but-incomplete query whose age already exceeds the SLO is
    /// a guaranteed miss — its latency only grows from here. The age test
    /// is written as `now - arrival > slo`, the *same* float expression
    /// the completion path uses for `latency > slo`: fp subtraction is
    /// monotone in `now`, so a query doomed at `now` provably produces
    /// `latency > slo` at any completion time ≥ `now` — bit-exactly, not
    /// just in real arithmetic. Arrivals are time-sorted, so the doomed
    /// set is a prefix and one monotone cursor visits each query at most
    /// once across the whole run.
    fn sweep_deadlines(&mut self, arrivals: &[f64], now: f64) {
        let Some(b) = &mut self.budget else { return };
        while b.deadline_idx < self.queries.len() && now - arrivals[b.deadline_idx] > b.slo {
            // Shed queries were already counted as guaranteed misses when
            // they were dropped ([`Self::shed_query`]); counting them
            // again here would double-book the miss ceiling.
            let q = &self.queries[b.deadline_idx];
            if q.remaining > 0 && !q.shed {
                b.misses += 1;
                if b.misses >= b.threshold {
                    self.aborted = true;
                }
            }
            b.deadline_idx += 1;
        }
    }

    /// Drop `qid` from the run: the deadline-shed policy fired or its
    /// crashed batch exhausted `max_retries`. Shed queries are counted
    /// separately from SLO misses in the result; the feasibility budget
    /// books them as guaranteed misses (they will never produce a
    /// latency at or under the SLO) unless the deadline sweep already
    /// counted them while they aged in a queue.
    fn shed_query(&mut self, qid: u32, now: f64) {
        let q = &mut self.queries[qid as usize - self.query_base];
        if q.shed || q.remaining == 0 {
            return;
        }
        q.shed = true;
        self.result.shed += 1;
        self.outstanding -= 1;
        if let Some(p) = self.probe.as_deref_mut() {
            p.on_shed(qid, now);
        }
        if let Some(b) = &mut self.budget {
            if (qid as usize) >= b.deadline_idx {
                b.misses += 1;
                if b.misses >= b.threshold {
                    self.aborted = true;
                }
            }
        }
    }

    /// Fault runs only: clear the head of a stage queue of queries that
    /// no longer need a batch slot — already-shed queries and, when the
    /// plan carries a `shed_after` policy, queries older than the bound
    /// (the same `now - arrival` float expression the deadline sweep
    /// uses). Only heads are pruned: FIFO order makes older queries
    /// surface first, so nothing sheddable hides behind the head.
    fn prune_queue_head(&mut self, stage: usize, now: f64) {
        let shed_after = match &self.faults {
            Some(f) => f.shed_after,
            None => return,
        };
        while let Some(&qid) = self.stages[stage].queue.front() {
            let q = &self.queries[qid as usize - self.query_base];
            if q.shed {
                self.stages[stage].queue.pop_front();
            } else if shed_after.is_some_and(|bound| now - q.arrival > bound) {
                self.stages[stage].queue.pop_front();
                self.shed_query(qid, now);
            } else {
                break;
            }
        }
    }

    fn try_dispatch(&mut self, stage: usize, now: f64) {
        if now < self.halted_until {
            return;
        }
        if let Some(f) = &self.faults {
            // An outage freezes dispatch at this stage; the matching
            // OutageEnd event re-dispatches.
            if f.outage[stage] > 0 {
                return;
            }
        }
        loop {
            if self.faults.is_some() {
                self.prune_queue_head(stage, now);
            }
            {
                let st = &self.stages[stage];
                if st.idle == 0 || st.queue.is_empty() {
                    break;
                }
            }
            // Batch-at-a-time: an idle replica immediately takes up to its
            // maximum batch size off the centralized queue. The qid slice
            // lives in the recycled arena; only its handle travels through
            // the event heap.
            let slice = self.arena.alloc();
            let slow = self.faults.as_ref().map_or(1.0, |f| f.slow[stage]);
            let st = &mut self.stages[stage];
            let n = st.batch.min(st.queue.len());
            self.arena.get_mut(slice).extend(st.queue.drain(..n));
            st.idle -= 1;
            // Multiplying by the nominal 1.0 factor is bit-exact, so the
            // fault-free path is unchanged.
            let latency = st.latency_table[n] * slow;
            st.stats.batches += 1;
            st.stats.queries += n;
            st.batch_size_sum += n;
            st.stats.busy_time += latency;
            let done = now + latency;
            if self.probe.is_some() {
                self.batch_seq += 1;
                let batch_id = self.batch_seq;
                let qids = self.arena.get(slice);
                if let Some(p) = self.probe.as_deref_mut() {
                    p.on_dispatch(stage, batch_id, qids, now, done);
                }
            }
            if self.faults.is_none() {
                if let Some(b) = &mut self.budget {
                    // Fast-accept in-flight sweep: a query whose *final*
                    // outstanding visit is in this batch completes exactly at
                    // `done` (open-loop batches are never cancelled), so its
                    // latency is already decided. `done - arrival` is the
                    // *same* float expression the completion path evaluates
                    // at the BatchDone event (whose time is this very `done`
                    // value), so counting it now as a guaranteed hit is
                    // bit-exact, not just sound in real arithmetic. With
                    // faults active the premise fails — a crash *can* cancel
                    // this batch and retry its queries later — so the sweep
                    // is disabled and hits are only counted at completion,
                    // never twice.
                    for &qid in self.arena.get(slice) {
                        let q = &mut self.queries[qid as usize - self.query_base];
                        if q.remaining == 1 && !q.hit_counted && done - q.arrival <= b.slo {
                            q.hit_counted = true;
                            if b.count_hit() {
                                self.accepted = true;
                            }
                        }
                    }
                }
            } else if let Some(f) = &mut self.faults {
                f.inflight[stage].push(slice);
            }
            self.events.push(done, EventKind::BatchDone { stage: stage as u16, slice });
        }
    }

    fn enqueue(&mut self, stage: usize, qid: u32, now: f64) {
        let st = &mut self.stages[stage];
        st.queue.push_back(qid);
        st.stats.max_queue = st.stats.max_queue.max(st.queue.len());
        if let Some(p) = self.probe.as_deref_mut() {
            p.on_enqueue(stage, qid, now);
        }
        self.try_dispatch(stage, now);
    }

    /// Materialize a per-stage snapshot for the probe when it asks for
    /// one. The `wants_sample` pre-check keeps the snapshot allocation
    /// off the probe-less (and cadence-idle) path.
    fn probe_sample(&mut self, now: f64) {
        if !self.probe.as_ref().is_some_and(|p| p.wants_sample(now)) {
            return;
        }
        let snap: Vec<StageSample> = self
            .stages
            .iter()
            .map(|s| StageSample {
                queue: s.queue.len(),
                busy: s.online - s.idle,
                online: s.online,
            })
            .collect();
        if let Some(p) = self.probe.as_deref_mut() {
            p.on_sample(now, &snap);
        }
    }

    /// One stage visit finished for `qid` at `now`. Routing to children
    /// is *not* done here — the BatchDone handler emits one coalesced
    /// Delivery record for the whole batch instead.
    fn complete_query_visit(&mut self, qid: u32, now: f64) {
        let q = &mut self.queries[qid as usize - self.query_base];
        // A shed query may still ride along in batches that were formed
        // before it was dropped (or on parallel branches): its visits are
        // no-ops — it was already removed from every tally it can affect.
        if q.shed {
            return;
        }
        q.remaining -= 1;
        if q.remaining == 0 {
            let latency = now - q.arrival;
            let hit_counted = q.hit_counted;
            if let Some(agg) = &mut self.stream {
                // Streamed runs fold completions into O(1) aggregates
                // instead of per-query vectors. Completions arrive in
                // the same order as the materialized run's, so the
                // folded sums are bit-identical to folding that run's
                // latency vector (asserted by the conformance suite).
                agg.completed += 1;
                if latency > agg.slo {
                    agg.misses += 1;
                }
                agg.latency_sum += latency;
                if latency > agg.max_latency {
                    agg.max_latency = latency;
                }
            } else {
                self.result.latencies.push(latency);
                self.result.completions.push((now, latency));
            }
            if let Some(b) = &mut self.budget {
                // No *miss* counting here: the deadline sweep at this same
                // `now` already counted every miss — `latency > slo` is
                // exactly its `now - arrival > slo` condition, and
                // deadlines are sorted, so the cursor is provably past
                // `qid`. Hits are tallied here (unless the dispatch-time
                // sweep already claimed this query).
                debug_assert!(latency <= b.slo || (qid as usize) < b.deadline_idx);
                if latency <= b.slo && !hit_counted && b.count_hit() {
                    self.accepted = true;
                }
            }
        }
    }

    fn accrue_cost(&mut self, now: f64) {
        let dt = now - self.last_cost_time;
        if dt > 0.0 {
            self.result.cost_dollars += self.cost_rate_per_hour * dt / 3600.0;
            self.last_cost_time = now;
        }
    }

    fn recompute_cost_rate(&mut self, config_hw: &PipelineConfig) {
        self.cost_rate_per_hour = self
            .stages
            .iter()
            .zip(&config_hw.stages)
            .map(|(st, c)| st.provisioned() as f64 * c.hw.cost_per_hour())
            .sum();
    }

    fn total_provisioned(&self) -> usize {
        self.stages.iter().map(|s| s.provisioned()).sum()
    }

    fn apply_action(
        &mut self,
        action: &ControlAction,
        config_hw: &PipelineConfig,
        now: f64,
    ) {
        if let Some(p) = self.probe.as_deref_mut() {
            p.on_action(action, now);
        }
        match *action {
            ControlAction::SetReplicas { stage, replicas } => {
                let target = replicas.max(1);
                self.accrue_cost(now);
                let current = self.stages[stage].provisioned();
                if target > current {
                    let mut add = target - current;
                    // A rate flap (scale-down immediately followed by
                    // scale-up) must not pay the activation delay for
                    // capacity that was never actually released. Reclaim
                    // in two steps, cheapest capacity first:
                    //  1. retiring replicas — still online finishing
                    //     their current batch; cancelling the retirement
                    //     restores them instantly;
                    //  2. cancelled-but-still-scheduled activations —
                    //     un-cancelling the queue record brings them
                    //     online at the original (earlier) activation
                    //     time, latest-scheduled first.
                    // Only what remains is genuinely new and pays the
                    // full activation delay.
                    {
                        let st = &mut self.stages[stage];
                        let reclaim = add.min(st.retire_debt);
                        st.retire_debt -= reclaim;
                        add -= reclaim;
                    }
                    while add > 0 {
                        let Some(h) = self.stages[stage].cancelled_up.pop() else { break };
                        if self.events.uncancel(h) {
                            // Revived records have the earliest activation
                            // times of any live pending activation, so the
                            // front keeps `pending_up` in pop order.
                            self.stages[stage].pending_up.push_front(h);
                            add -= 1;
                        }
                        // Stale handle: its tombstone already popped;
                        // simply drop it and keep reclaiming.
                    }
                    if add > 0 {
                        let when = now + self.params.replica_activation_delay;
                        for _ in 0..add {
                            let h = self.events.push_replica_up(when, stage as u16);
                            self.stages[stage].pending_up.push_back(h);
                        }
                    }
                } else if target < current {
                    // Remove: cancel pending activations first (earliest-
                    // scheduled first — the ones the old stale-event
                    // bookkeeping would have swallowed), then idle
                    // replicas, then mark busy replicas to retire on their
                    // current batch's completion.
                    let mut to_remove = current - target;
                    while to_remove > 0 {
                        let Some(h) = self.stages[stage].pending_up.pop_front() else { break };
                        let cancelled = self.events.cancel(h);
                        // Checked in release builds too: a stale handle here
                        // (possible only through an accounting bug, e.g.
                        // under fault-driven churn) would silently corrupt
                        // the replica bookkeeping from this point on.
                        assert!(cancelled, "pending activation handle went stale");
                        self.stages[stage].cancelled_up.push(h);
                        to_remove -= 1;
                    }
                    let st = &mut self.stages[stage];
                    let idle_remove = to_remove.min(st.idle);
                    st.idle -= idle_remove;
                    st.online -= idle_remove;
                    to_remove -= idle_remove;
                    st.retire_debt += to_remove;
                }
                self.recompute_cost_rate(config_hw);
                let t = self.total_provisioned();
                self.result.replica_timeline.push((now, t));
            }
            ControlAction::Halt { duration } => {
                self.halted_until = self.halted_until.max(now + duration);
                self.events.push(self.halted_until, EventKind::Resume);
            }
        }
    }

    /// Apply the compiled fault entry `idx` (a `Fault` event popped).
    fn apply_fault(&mut self, idx: usize, config_hw: &PipelineConfig, now: f64) {
        let entry = self.faults.as_ref().expect("fault event without a plan").entries[idx];
        if self.probe.is_some() {
            let (kind, stage) = match entry.action {
                FaultAction::Crash { stage } => ("crash", stage),
                FaultAction::SlowdownStart { stage, .. } => ("slowdown-start", stage),
                FaultAction::SlowdownEnd { stage } => ("slowdown-end", stage),
                FaultAction::OutageStart { stage } => ("outage-start", stage),
                FaultAction::OutageEnd { stage } => ("outage-end", stage),
            };
            if let Some(p) = self.probe.as_deref_mut() {
                p.on_fault(kind, Some(stage as usize), now);
            }
        }
        match entry.action {
            FaultAction::Crash { stage } => self.apply_crash(stage as usize, config_hw, now),
            FaultAction::SlowdownStart { stage, factor } => {
                // Affects batches dispatched from now on; batches already
                // in flight keep their scheduled completion.
                self.faults.as_mut().unwrap().slow[stage as usize] = factor;
            }
            FaultAction::SlowdownEnd { stage } => {
                self.faults.as_mut().unwrap().slow[stage as usize] = 1.0;
            }
            FaultAction::OutageStart { stage } => {
                self.faults.as_mut().unwrap().outage[stage as usize] += 1;
            }
            FaultAction::OutageEnd { stage } => {
                let s = stage as usize;
                let f = self.faults.as_mut().unwrap();
                f.outage[s] = f.outage[s].saturating_sub(1);
                if f.outage[s] == 0 {
                    self.try_dispatch(s, now);
                }
            }
        }
    }

    /// Kill one replica of stage `s`. Prefers a busy replica (the one
    /// that dispatched most recently): its in-flight batch is lost, the
    /// stale `BatchDone` is doomed, and the batch's queries are requeued
    /// at the *head* of the stage queue in original order (each retry
    /// counted; a query past `max_retries` is shed instead). Replacement
    /// capacity is the controller's job — open-loop and null-controlled
    /// runs stay degraded; the Tuner restores its planned floor through
    /// the normal activation path, paying `replica_activation_delay`.
    ///
    /// A crash never removes a stage's *last* replica (when none is
    /// pending activation either): with no completion or activation
    /// event left, a dead stage could wedge a controlled run's tick loop
    /// forever. Total stage death is modeled by `outage` windows, which
    /// always end.
    fn apply_crash(&mut self, s: usize, config_hw: &PipelineConfig, now: f64) {
        {
            let st = &self.stages[s];
            if st.online == 0 || (st.online == 1 && st.pending_up.is_empty()) {
                return;
            }
        }
        self.accrue_cost(now);
        self.result.crashes += 1;
        let busy = self.stages[s].online - self.stages[s].idle;
        if busy > 0 {
            {
                let st = &mut self.stages[s];
                st.online -= 1;
                // A pending retirement wanted a busy replica gone; the
                // crash delivered one. Without this, a later scale-up
                // could "reclaim" capacity the crash already destroyed.
                if st.retire_debt > 0 {
                    st.retire_debt -= 1;
                }
            }
            let f = self.faults.as_mut().expect("crash without fault runtime");
            let slice = f.inflight[s].pop().expect("busy stage with no in-flight batch");
            f.doomed.push(slice);
            let max_retries = f.max_retries;
            let qids = std::mem::take(self.arena.get_mut(slice));
            // Reverse iteration + push_front keeps the batch's original
            // order at the head of the queue.
            for &qid in qids.iter().rev() {
                if self.queries[qid as usize - self.query_base].shed {
                    continue;
                }
                if self.queries[qid as usize - self.query_base].retries as u32 >= max_retries {
                    self.shed_query(qid, now);
                } else {
                    self.queries[qid as usize - self.query_base].retries =
                        self.queries[qid as usize - self.query_base].retries.saturating_add(1);
                    self.result.retries += 1;
                    self.stages[s].queue.push_front(qid);
                    if let Some(p) = self.probe.as_deref_mut() {
                        p.on_retry(s, qid, now);
                    }
                }
            }
            *self.arena.get_mut(slice) = qids;
            let st = &mut self.stages[s];
            st.stats.max_queue = st.stats.max_queue.max(st.queue.len());
        } else {
            let st = &mut self.stages[s];
            st.online -= 1;
            st.idle -= 1;
        }
        self.recompute_cost_rate(config_hw);
        let t = self.total_provisioned();
        self.result.replica_timeline.push((now, t));
        self.try_dispatch(s, now);
    }

    /// Handle one `BatchDone` event: retire or idle the replica, record
    /// completions, and emit the coalesced `Delivery` record. Extracted
    /// verbatim from the materialized run loop so the streamed loop
    /// shares it (both loops dispatch the same event kinds).
    fn on_batch_done(&mut self, stage: u16, slice: u32, now: f64) {
        let s = stage as usize;
        let doomed = match &mut self.faults {
            Some(f) => match f.doomed.iter().position(|&d| d == slice) {
                Some(pos) => {
                    f.doomed.swap_remove(pos);
                    true
                }
                None => false,
            },
            None => false,
        };
        if doomed {
            // The replica crashed mid-batch: its queries were
            // requeued (or shed) at crash time and the replica
            // already left the stage bookkeeping, so the stale
            // completion only returns the slice to the pool.
            self.arena.free(slice);
        } else {
            if let Some(f) = &mut self.faults {
                if let Some(pos) = f.inflight[s].iter().position(|&x| x == slice) {
                    f.inflight[s].remove(pos);
                }
            }
            {
                let st = &mut self.stages[s];
                if st.retire_debt > 0 {
                    st.retire_debt -= 1;
                    st.online -= 1;
                } else {
                    st.idle += 1;
                }
            }
            // Completions are recorded at the batch's finish
            // time; the routed hops land one RPC later through a
            // single coalesced Delivery record reusing this very
            // qid slice — unless nothing routes anywhere, in
            // which case the slice goes straight back to the
            // pool (an empty Delivery would keep controlled runs
            // alive past their old termination point).
            let spec = self.spec;
            let qids = std::mem::take(self.arena.get_mut(slice));
            let mut routes = false;
            for &qid in &qids {
                if !routes {
                    let visited = self.queries[qid as usize - self.query_base].visited;
                    for &c in &spec.stages[s].children {
                        if visited & (1 << c) != 0 {
                            routes = true;
                            break;
                        }
                    }
                }
                self.complete_query_visit(qid, now);
                if self.probe.is_some() && !self.queries[qid as usize - self.query_base].shed {
                    let finished = self.queries[qid as usize - self.query_base].remaining == 0;
                    if let Some(p) = self.probe.as_deref_mut() {
                        p.on_visit_done(s, qid, now);
                        if finished {
                            p.on_query_done(qid, now);
                        }
                    }
                }
                if self.queries[qid as usize - self.query_base].remaining == 0 {
                    self.outstanding -= 1;
                }
            }
            *self.arena.get_mut(slice) = qids;
            if routes {
                self.events.push(now + self.rpc, EventKind::Delivery { stage, slice });
            } else {
                self.arena.free(slice);
            }
            self.try_dispatch(s, now);
        }
    }

    /// Handle one `Delivery` event: replay the batch's routed hops.
    /// Extracted verbatim from the materialized run loop (see
    /// [`Self::on_batch_done`]); the `query_base` guard is the one
    /// streaming-only addition, dead in materialized runs.
    fn on_delivery(&mut self, stage: u16, slice: u32, now: f64) {
        let s = stage as usize;
        let spec = self.spec;
        let qids = std::mem::take(self.arena.get_mut(slice));
        // This one record stands in for the per-hop Enqueue
        // records the old engine pushed back-to-back: they
        // were seq-contiguous at a single time, so nothing
        // could interleave between them, and replaying the
        // hops qid-major, child-minor is order-identical.
        // The budget-proof check between hops replicates the
        // main loop's per-record check (the deadline sweep
        // is a no-op at an unchanged `now`, so only the
        // proof flags matter); the first hop is covered by
        // the check the loop already ran for this record.
        let mut first = true;
        'hops: for &qid in &qids {
            // Streamed runs only: a query that completed between this
            // record's scheduling and now may have been compacted away
            // (`query_base` moved past it). A completed query routes
            // nowhere — were a child of `s` in its visit set, that
            // visit would still be outstanding — so skipping the hop
            // replay is a no-op; unreachable when `query_base` is 0.
            if (qid as usize) < self.query_base {
                continue;
            }
            if self.faults.is_some() && self.queries[qid as usize - self.query_base].shed {
                // Shed queries route nowhere: dropping the hop
                // here saves the downstream queue traffic the
                // head-prune would discard anyway.
                continue;
            }
            let visited = self.queries[qid as usize - self.query_base].visited;
            for &c in &spec.stages[s].children {
                if visited & (1 << c) == 0 {
                    continue;
                }
                if !first && (self.aborted || self.accepted) {
                    break 'hops;
                }
                first = false;
                self.enqueue(c, qid, now);
            }
        }
        *self.arena.get_mut(slice) = qids;
        self.arena.free(slice);
    }

    /// Streamed runs only: drop the completed prefix of the query table
    /// and advance `query_base` so absolute qids keep resolving. Called
    /// at chunk boundaries (the prefix is longest right after a chunk
    /// drains); the minimum batch amortizes the drain's memmove.
    fn compact_queries(&mut self) {
        const MIN_COMPACT: usize = 1024;
        let k = self.queries.iter().take_while(|q| q.remaining == 0).count();
        if k >= MIN_COMPACT {
            self.queries.drain(..k);
            self.query_base += k;
        }
    }

    /// Fold per-stage stats into their result form (fills `mean_batch`).
    fn finalize_stage_stats(&self) -> Vec<super::StageStats> {
        self.stages
            .iter()
            .map(|s| {
                let mut st = s.stats.clone();
                st.mean_batch = if st.batches == 0 {
                    0.0
                } else {
                    s.batch_size_sum as f64 / st.batches as f64
                };
                st
            })
            .collect()
    }

    /// Full-control entry point: optional shared routing plan, optional
    /// early-abort/fast-accept budget. Returns the (possibly partial)
    /// result and the budget verdict. Budgets are only meaningful
    /// open-loop (feasibility checks); controlled runs pass `None`.
    fn run_ext(
        mut self,
        trace: &Trace,
        config_hw: &PipelineConfig,
        mut controller: Option<&mut dyn Controller>,
        routing: Option<&RoutingPlan>,
        budget: Option<AbortBudget>,
    ) -> (SimResult, BudgetVerdict) {
        debug_assert!(
            budget.is_none() || controller.is_none(),
            "abort budgets are for open-loop feasibility runs"
        );
        self.budget = budget.map(|b| BudgetState::new(b, trace.len()));
        self.seed_arrivals(trace, routing);
        let n_stages = self.stages.len();
        if let Some(p) = self.probe.as_deref_mut() {
            p.on_start(n_stages, trace.len());
        }
        // Schedule the compiled fault plan. An inactive runtime pushes
        // nothing, so the event stream — every record and every seq
        // number — is identical to the fault-free engine's.
        let n_faults = self.faults.as_ref().map_or(0, |f| f.entries.len());
        for i in 0..n_faults {
            let t = self.faults.as_ref().unwrap().entries[i].time;
            self.events.push(t, EventKind::Fault { idx: i as u32 });
        }
        if controller.is_some() {
            self.events.push(self.params.control_interval, EventKind::ControlTick);
            self.result
                .replica_timeline
                .push((0.0, self.total_provisioned()));
        }
        self.outstanding = self.queries.len();
        // Perf: arrivals are already time-sorted, so they are merged
        // lazily against the event heap instead of being pre-pushed. The
        // heap then only holds in-flight events (hundreds) instead of the
        // whole trace (hundreds of thousands) — log-factor win on every
        // push/pop. Ties break toward the arrival (matching the previous
        // all-arrivals-pushed-first ordering). Cancelled-activation
        // tombstones keep their place in the merge: peek_time sees them
        // until they pop, exactly like the old stale events.
        let mut next_arrival = 0usize;
        loop {
            let arrival_time = trace.arrivals.get(next_arrival).copied();
            let event_time = self.events.peek_time();
            let take_arrival = match (arrival_time, event_time) {
                (Some(a), Some(e)) => a <= e,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            if take_arrival {
                let now = arrival_time.unwrap();
                self.sweep_deadlines(&trace.arrivals, now);
                if self.aborted || self.accepted {
                    break;
                }
                let qid = next_arrival as u32;
                next_arrival += 1;
                if let Some(p) = self.probe.as_deref_mut() {
                    p.on_arrival(qid, now);
                }
                if let Some(c) = controller.as_deref_mut() {
                    c.on_arrival(now);
                }
                // Roots are read through the long-lived spec reference —
                // no per-arrival clone of the root list.
                let spec = self.spec;
                for &r in &spec.roots {
                    self.enqueue(r, qid, now);
                }
                self.result.horizon = now;
                self.probe_sample(now);
                continue;
            }
            let ev = self.events.pop().unwrap();
            let now = ev.time;
            self.sweep_deadlines(&trace.arrivals, now);
            if self.aborted || self.accepted {
                break;
            }
            match ev.kind {
                EventKind::BatchDone { stage, slice } => self.on_batch_done(stage, slice, now),
                EventKind::Delivery { stage, slice } => self.on_delivery(stage, slice, now),
                EventKind::ReplicaUp { stage, slot } => {
                    // Retire the cancel slot; `false` means a scale-down
                    // cancelled this activation and never revived it —
                    // swallow the tombstone exactly where the old
                    // stale-event count consumed it (skipping the
                    // horizon update and termination checks below).
                    if !self.events.resolve_up(slot) {
                        continue;
                    }
                    let s = stage as usize;
                    let st = &mut self.stages[s];
                    let h = st.pending_up.pop_front();
                    debug_assert!(h.is_some_and(|h| h.slot() == slot), "activation order skew");
                    st.online += 1;
                    st.idle += 1;
                    self.try_dispatch(s, now);
                }
                EventKind::ControlTick => {
                    if let Some(c) = controller.as_deref_mut() {
                        let state = ControlState {
                            time: now,
                            provisioned: self.stages.iter().map(|s| s.provisioned()).collect(),
                            queue_depths: self.stages.iter().map(|s| s.queue.len()).collect(),
                            busy: self
                                .stages
                                .iter()
                                .map(|s| s.online - s.idle)
                                .collect(),
                        };
                        let actions = c.on_tick(now, &state);
                        for a in &actions {
                            self.apply_action(a, config_hw, now);
                        }
                        if self.outstanding > 0 {
                            let next = now + self.params.control_interval;
                            self.events.push(next, EventKind::ControlTick);
                        }
                    }
                }
                EventKind::Resume => {
                    for s in 0..self.stages.len() {
                        self.try_dispatch(s, now);
                    }
                }
                EventKind::Fault { idx } => {
                    self.apply_fault(idx as usize, config_hw, now);
                }
            }
            self.result.horizon = now;
            self.probe_sample(now);
            if self.outstanding == 0 && controller.is_none() {
                break;
            }
            // Controlled-mode termination: nothing left but control
            // ticks. The non-tick counter includes cancelled-activation
            // tombstones still scheduled — they keep the run (and its
            // ticks) alive until their activation time passes, exactly
            // as the old whole-heap scan did, but in O(1).
            if self.outstanding == 0 && self.events.non_tick_len() == 0 {
                break;
            }
        }
        self.accrue_cost(self.result.horizon);
        self.result.stage_stats = self.finalize_stage_stats();
        // A query lands in at most one of the two tallies (a counted hit
        // can never age past the deadline before its scheduled completion
        // event is processed), so the two thresholds cannot both be met.
        debug_assert!(!(self.aborted && self.accepted), "contradictory budget verdicts");
        let verdict = if self.aborted {
            BudgetVerdict::ProvedInfeasible
        } else if self.accepted {
            BudgetVerdict::ProvedFeasible
        } else {
            BudgetVerdict::Completed
        };
        (self.result, verdict)
    }

    /// Streamed open-loop run: pull arrivals from `source` in chunks of
    /// at most `chunk`, sample routing lazily, and fold completions into
    /// a [`StreamSummary`] — memory stays O(in-flight window), never
    /// O(trace).
    ///
    /// Equivalence with the materialized run loop, piece by piece: the
    /// source yields the same arrival values in the same order as the
    /// materialized trace (the workload-layer streaming contract); the
    /// [`RoutingSampler`] yields the same visit sequence as
    /// `RoutingPlan::build` (it *is* the plan builder); the arrival/heap
    /// merge uses the identical `a <= e` tie-break; and the event arms
    /// call the same extracted handlers. So every dispatch, completion
    /// time, and stat lands bit-identically — asserted against
    /// [`simulate`] by `tests/streaming_conformance.rs` across chunk
    /// sizes including 1.
    pub(super) fn run_streamed(
        mut self,
        source: &mut dyn ArrivalSource,
        slo: f64,
        chunk: usize,
    ) -> StreamSummary {
        assert!(chunk > 0, "chunk size must be positive");
        debug_assert!(
            self.budget.is_none() && self.faults.is_none() && self.probe.is_none(),
            "streamed runs are plain open loop"
        );
        self.stream = Some(StreamAgg {
            slo,
            completed: 0,
            misses: 0,
            latency_sum: 0.0,
            max_latency: 0.0,
        });
        let mut sampler = RoutingSampler::new(self.spec, self.params.routing_seed);
        let mut buf: Vec<f64> = Vec::with_capacity(chunk);
        let mut pos = 0usize;
        let mut source_done = false;
        let mut pulled: u64 = 0;
        let mut peak_resident = 0usize;
        loop {
            if pos == buf.len() && !source_done {
                buf.clear();
                pos = 0;
                if source.next_chunk(&mut buf, chunk) == 0 {
                    source_done = true;
                }
                // A chunk boundary is the natural compaction point: the
                // completed prefix is longest right after a chunk drains.
                self.compact_queries();
            }
            // Same lazy merge as the materialized loop: chunk arrivals
            // are time-sorted, ties break toward the arrival.
            let arrival_time = buf.get(pos).copied();
            let event_time = self.events.peek_time();
            let take_arrival = match (arrival_time, event_time) {
                (Some(a), Some(e)) => a <= e,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            if take_arrival {
                let now = arrival_time.unwrap();
                pos += 1;
                assert!(pulled <= u32::MAX as u64, "streamed run exceeds the u32 qid space");
                let qid = pulled as u32;
                pulled += 1;
                let (visited, remaining) = sampler.next_visit();
                self.queries.push(QueryState {
                    arrival: now,
                    visited,
                    remaining,
                    hit_counted: false,
                    shed: false,
                    retries: 0,
                });
                peak_resident = peak_resident.max(self.queries.len());
                self.outstanding += 1;
                let spec = self.spec;
                for &r in &spec.roots {
                    self.enqueue(r, qid, now);
                }
                self.result.horizon = now;
                continue;
            }
            let ev = self.events.pop().unwrap();
            let now = ev.time;
            match ev.kind {
                EventKind::BatchDone { stage, slice } => self.on_batch_done(stage, slice, now),
                EventKind::Delivery { stage, slice } => self.on_delivery(stage, slice, now),
                _ => unreachable!("open-loop streamed runs schedule only batch events"),
            }
            self.result.horizon = now;
            // Unlike the materialized loop, `outstanding == 0` can occur
            // mid-stream (a rate lull drains the pipeline); the run ends
            // only once the source is dry too.
            if self.outstanding == 0 && source_done && pos == buf.len() {
                break;
            }
        }
        let stage_stats = self.finalize_stage_stats();
        let agg = self.stream.take().expect("streamed run lost its aggregates");
        StreamSummary {
            queries: pulled,
            completed: agg.completed,
            misses: agg.misses,
            latency_sum: agg.latency_sum,
            max_latency: agg.max_latency,
            horizon: self.result.horizon,
            cost_dollars: 0.0,
            stage_stats,
            peak_queries_resident: peak_resident,
        }
    }
}

/// Builder-style entry point unifying the whole `simulate_*` family.
///
/// Every public simulation mode is one [`SimRun`] with zero or more
/// options attached:
///
/// ```ignore
/// // Open-loop Estimator run (== `simulate`):
/// let result = SimRun::new(&spec, &profiles, &config, &params).run(&trace).0;
/// // Budgeted feasibility probe under a fault plan:
/// let (result, verdict) = SimRun::new(&spec, &profiles, &config, &params)
///     .faults(&plan)
///     .budget(slo)
///     .run(&trace);
/// // Tuner in the loop with telemetry:
/// let result = SimRun::new(&spec, &profiles, &config, &params)
///     .controller(&mut tuner)
///     .probe(&mut rec)
///     .run(&trace)
///     .0;
/// ```
///
/// The legacy free functions ([`simulate`], [`simulate_budgeted`], …
/// and the `simulate_controlled*` family in [`super::control`]) are thin
/// delegating wrappers over this builder, and every combination they
/// expressed is bit-identical through it (asserted by
/// `tests/probe_conformance.rs`).
///
/// Mode semantics, inherited from the engine:
///
/// * `.budget(slo)` arms early-abort/fast-accept feasibility proofs and
///   is meaningful open-loop only — combining it with `.controller(..)`
///   is a contract violation (debug-asserted, like the engine itself).
/// * `.run()` prices open-loop runs statically (config $/hr × makespan);
///   controlled runs keep the engine's cost integral over the replica
///   timeline.
/// * `.run_streamed(..)` is the O(in-flight-window) open-loop path and
///   accepts no other option (hard assert): routing is sampled lazily,
///   and budgets/faults/probes are materialized-run features.
pub struct SimRun<'a> {
    spec: &'a PipelineSpec,
    profiles: &'a ProfileSet,
    config: &'a PipelineConfig,
    params: &'a SimParams,
    routing: Option<&'a RoutingPlan>,
    faults: Option<&'a FaultPlan>,
    probe: Option<&'a mut dyn Probe>,
    controller: Option<&'a mut dyn Controller>,
    budget_slo: Option<f64>,
}

impl<'a> SimRun<'a> {
    /// A plain open-loop run of `config` (the paper's Estimator); attach
    /// options, then call [`run`](Self::run) or
    /// [`run_streamed`](Self::run_streamed).
    pub fn new(
        spec: &'a PipelineSpec,
        profiles: &'a ProfileSet,
        config: &'a PipelineConfig,
        params: &'a SimParams,
    ) -> Self {
        SimRun {
            spec,
            profiles,
            config,
            params,
            routing: None,
            faults: None,
            probe: None,
            controller: None,
            budget_slo: None,
        }
    }

    /// Share a precomputed [`RoutingPlan`] (same spec, trace and routing
    /// seed). Bit-identical with or without; skips per-query sampling.
    pub fn routing(mut self, plan: impl Into<Option<&'a RoutingPlan>>) -> Self {
        self.routing = plan.into();
        self
    }

    /// Inject a compiled [`FaultPlan`]. An empty plan is bit-identical
    /// to no plan at all (the no-fault invariant).
    pub fn faults(mut self, plan: impl Into<Option<&'a FaultPlan>>) -> Self {
        self.faults = plan.into();
        self
    }

    /// Attach a read-only [`Probe`]; the result stays bit-identical to
    /// the probe-less run.
    pub fn probe(mut self, probe: &'a mut dyn Probe) -> Self {
        self.probe = Some(probe);
        self
    }

    /// Close the loop with a [`Controller`] ticking every
    /// `params.control_interval`.
    pub fn controller(mut self, controller: &'a mut dyn Controller) -> Self {
        self.controller = Some(controller);
        self
    }

    /// Arm the early-abort/fast-accept feasibility budget for this SLO
    /// (open-loop only; see [`simulate_budgeted`] for the proof bounds).
    pub fn budget(mut self, slo: f64) -> Self {
        self.budget_slo = Some(slo);
        self
    }

    /// Run over a materialized trace. The [`BudgetVerdict`] is
    /// `Completed` unless [`budget`](Self::budget) was armed.
    pub fn run(self, trace: &Trace) -> (SimResult, BudgetVerdict) {
        let SimRun {
            spec,
            profiles,
            config,
            params,
            routing,
            faults,
            probe,
            controller,
            budget_slo,
        } = self;
        let open_loop = controller.is_none();
        let budget = budget_slo.map(|slo| AbortBudget { slo });
        let (mut result, verdict) = Engine::new(spec, profiles, config, params)
            .with_faults(faults)
            .with_probe(probe)
            .run_ext(trace, config, controller, routing, budget);
        if open_loop {
            // Open loop: cost = static config rate x makespan. Controlled
            // runs keep the engine's cost integral over replica changes.
            result.cost_dollars = config.cost_per_hour() * result.horizon / 3600.0;
        }
        (result, verdict)
    }

    /// Run pulling arrivals from an [`ArrivalSource`] in chunks of at
    /// most `chunk`; see [`simulate_streamed`] for the equivalence
    /// contract. Only a bare open-loop builder may stream.
    pub fn run_streamed(
        self,
        source: &mut dyn ArrivalSource,
        slo: f64,
        chunk: usize,
    ) -> StreamSummary {
        assert!(
            self.routing.is_none()
                && self.faults.is_none()
                && self.probe.is_none()
                && self.controller.is_none()
                && self.budget_slo.is_none(),
            "streamed runs are plain open loop: attach no routing/faults/probe/controller/budget"
        );
        let mut summary = Engine::new(self.spec, self.profiles, self.config, self.params)
            .run_streamed(source, slo, chunk);
        // Open loop: cost = static config rate x makespan.
        summary.cost_dollars = self.config.cost_per_hour() * summary.horizon / 3600.0;
        summary
    }
}

/// Open-loop simulation: the paper's Estimator (§4.2). Simulates the whole
/// trace through the given static configuration and returns every query's
/// end-to-end latency.
pub fn simulate(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
) -> SimResult {
    simulate_with_routing(spec, profiles, config, trace, params, None)
}

/// [`simulate`] with an optional precomputed [`RoutingPlan`] (built for
/// the same spec, trace and `params.routing_seed`). Results are
/// bit-identical with and without the plan; sharing one across candidate
/// simulations skips the per-query visit-set sampling.
pub fn simulate_with_routing(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
    routing: Option<&RoutingPlan>,
) -> SimResult {
    SimRun::new(spec, profiles, config, params).routing(routing).run(trace).0
}

/// Budgeted open-loop simulation for feasibility checks, symmetric in
/// both directions: stops as soon as enough queries are *guaranteed* to
/// miss the SLO that the final P99 provably exceeds it, or as soon as
/// enough queries have *provably met* it that P99 <= SLO is certain even
/// if every remaining query misses (see `BudgetState` for the exact
/// bounds; both lean on the clamped interpolated-quantile definition of
/// `util::stats::quantile`). Returns the (partial, when stopped early)
/// result and the [`BudgetVerdict`]. A `Completed` run is bit-identical
/// to [`simulate`], and either proof agrees bit-exactly with the verdict
/// the full run's `p99 <= slo` comparison would reach.
pub fn simulate_budgeted(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
    routing: Option<&RoutingPlan>,
) -> (SimResult, BudgetVerdict) {
    SimRun::new(spec, profiles, config, params).routing(routing).budget(slo).run(trace)
}

/// [`simulate`] with a fault plan injected (see [`super::faults`]). With
/// an *empty* plan the run is bit-identical to [`simulate`] — no fault
/// state is allocated and no fault event is pushed (asserted across the
/// conformance suites). Shed queries appear in no latency vector; the
/// crash/retry/shed telemetry is in the result's counters.
pub fn simulate_with_faults(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
    faults: &FaultPlan,
) -> SimResult {
    SimRun::new(spec, profiles, config, params).faults(faults).run(trace).0
}

/// [`simulate_budgeted`] with a fault plan injected. The dispatch-time
/// fast-accept sweep is disabled while faults are active (a crash can
/// cancel an in-flight batch, so "already scheduled" completions are no
/// longer guaranteed); hits are counted only at completion, misses by
/// the deadline sweep, and shed queries against the miss ceiling — so a
/// `ProvedFeasible` verdict still guarantees P99 <= SLO even when every
/// shed or unfinished query is charged as a miss.
#[allow(clippy::too_many_arguments)]
pub fn simulate_budgeted_with_faults(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    slo: f64,
    params: &SimParams,
    routing: Option<&RoutingPlan>,
    faults: &FaultPlan,
) -> (SimResult, BudgetVerdict) {
    SimRun::new(spec, profiles, config, params)
        .routing(routing)
        .faults(faults)
        .budget(slo)
        .run(trace)
}

/// [`simulate`] — optionally fault-injected — with a [`Probe`] observing
/// the run (see [`super::probe`] for the trait contract and what the
/// recording probe captures). Probes are read-only: the returned result
/// is bit-identical to the probe-less run's, with or without faults
/// (asserted by `tests/probe_conformance.rs`).
pub fn simulate_probed(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    trace: &Trace,
    params: &SimParams,
    faults: Option<&FaultPlan>,
    probe: &mut dyn Probe,
) -> SimResult {
    SimRun::new(spec, profiles, config, params).faults(faults).probe(probe).run(trace).0
}

/// Streamed open-loop simulation: [`simulate`] without the memory.
/// Arrivals are pulled from an [`ArrivalSource`] in chunks of at most
/// `chunk` and completions fold into a [`StreamSummary`], so neither the
/// trace, the routing plan, nor the latency vectors are ever
/// materialized — memory is O(in-flight window) on any horizon. The
/// summary's aggregates are bit-identical to folding [`simulate`]'s
/// result over the materialized equivalent of the source, for any chunk
/// size >= 1 (asserted by `tests/streaming_conformance.rs`). `slo` only
/// feeds the miss tally; it does not shed or abort anything.
pub fn simulate_streamed(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    config: &PipelineConfig,
    source: &mut dyn ArrivalSource,
    params: &SimParams,
    slo: f64,
    chunk: usize,
) -> StreamSummary {
    SimRun::new(spec, profiles, config, params).run_streamed(source, slo, chunk)
}
