//! Baseline planners and tuners the paper compares against (§6, §7, §8):
//!
//! * [`coarse`] — the Coarse-Grained baseline: the pipeline is treated as
//!   a single black-box service, profiled end to end, and replicated as a
//!   unit; provisioning targets either the mean (CG-Mean) or the peak
//!   (CG-Peak) rate of the sample trace.
//! * [`autoscale`] — the AutoScale [12] reactive tuner used to scale the
//!   coarse-grained pipelines at runtime.
//! * [`ds2`] — the DS2 [17] rate-based streaming autoscaler with
//!   Flink-style halt-and-restart reconfiguration (Fig 14).
//! * [`oracle`] — the Planner given full knowledge of the live trace
//!   (Fig 10's "oracle planner" comparison point).

pub mod autoscale;
pub mod coarse;
pub mod ds2;
pub mod oracle;
