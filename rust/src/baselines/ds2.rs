//! DS2 [17] baseline: rate-based streaming autoscaler (paper §8, Fig 14).
//!
//! DS2 instruments each operator to estimate its *true* (useful-time)
//! processing rate, combines the rates with the dataflow topology, and
//! jumps directly to the estimated optimal parallelism for every operator
//! at once. Two properties the paper highlights:
//!
//! * it provisions for the observed (average) rate and ignores
//!   burstiness — under CV=4 traffic transient bursts overload it;
//! * reconfiguration on Apache Flink requires halting the pipeline,
//!   taking a savepoint and restarting — queues build during every
//!   rescale ("requiring Apache Flink to halt processing and save state
//!   before migrating to the new configuration").
//!
//! The deployment is batch-less (batch = 1), matching the paper's DS2
//! setup ("deployed ... in DS2 running on Apache Flink without any
//! batching").

use crate::config::PipelineSpec;
use crate::simulator::control::{ControlAction, ControlState, Controller};
use crate::tuner::envelope::RateMonitor;

/// DS2-style controller.
pub struct Ds2Controller {
    /// Per-stage true processing rate of one replica (1 / service time).
    true_rates: Vec<f64>,
    /// Per-stage traffic share (scale factors).
    scale_factors: Vec<f64>,
    monitor: RateMonitor,
    /// Metrics aggregation window (seconds).
    pub window: f64,
    /// Decision epoch.
    pub epoch: f64,
    /// Pipeline halt duration per reconfiguration (savepoint + restore).
    pub restart_penalty: f64,
    /// Target operator utilization (DS2 provisions to the observed rate;
    /// a mild margin avoids flapping).
    pub target_utilization: f64,
    /// Relative rate change needed to trigger a reconfiguration (DS2's
    /// activation threshold — without it the estimator noise would cause
    /// a halt every epoch).
    pub rate_threshold: f64,
    last_decision: f64,
    last_planned_rate: f64,
    first_arrival: Option<f64>,
}

impl Ds2Controller {
    /// Build from the pipeline spec and per-stage batch-1 service times.
    pub fn new(spec: &PipelineSpec, service_times: &[f64]) -> Self {
        assert_eq!(spec.stages.len(), service_times.len());
        Ds2Controller {
            true_rates: service_times.iter().map(|&s| 1.0 / s).collect(),
            scale_factors: spec.stages.iter().map(|s| s.scale_factor).collect(),
            monitor: RateMonitor::new(vec![60.0]),
            window: 10.0,
            epoch: 10.0,
            restart_penalty: 2.0,
            target_utilization: 0.9,
            rate_threshold: 0.10,
            last_decision: f64::NEG_INFINITY,
            last_planned_rate: f64::NAN,
            first_arrival: None,
        }
    }
}

impl Controller for Ds2Controller {
    fn on_arrival(&mut self, t: f64) {
        self.first_arrival.get_or_insert(t);
        self.monitor.on_arrival(t);
    }

    fn on_tick(&mut self, now: f64, state: &ControlState) -> Vec<ControlAction> {
        // Metrics window must be full before the rate estimate means
        // anything (a cold estimator would tear the pipeline down at t=0).
        let warm = self.first_arrival.map_or(false, |t0| now - t0 >= self.window);
        if !warm || now - self.last_decision < self.epoch {
            return Vec::new();
        }
        self.last_decision = now;
        let rate = self.monitor.count_in(now, self.window) as f64 / self.window;
        // Activation threshold: ignore small fluctuations of the rate
        // estimate (otherwise the controller would halt every epoch).
        if self.last_planned_rate.is_finite()
            && (rate - self.last_planned_rate).abs()
                <= self.rate_threshold * self.last_planned_rate
        {
            return Vec::new();
        }
        // Optimal parallelism for all operators at once (DS2's one-shot
        // estimate from observed rates + topology).
        let targets: Vec<usize> = self
            .true_rates
            .iter()
            .zip(&self.scale_factors)
            .map(|(&mu, &s)| ((rate * s) / (mu * self.target_utilization)).ceil().max(1.0) as usize)
            .collect();
        if targets == state.provisioned {
            self.last_planned_rate = rate;
            return Vec::new();
        }
        self.last_planned_rate = rate;
        // Flink-style reconfiguration: halt, then apply the new plan.
        let mut actions = vec![ControlAction::Halt { duration: self.restart_penalty }];
        for (stage, &replicas) in targets.iter().enumerate() {
            if replicas != state.provisioned[stage] {
                actions.push(ControlAction::SetReplicas { stage, replicas });
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{pipelines, PipelineConfig, StageConfig};
    use crate::hardware::Hardware;
    use crate::profiler::analytic::paper_profiles;
    use crate::simulator::{control::simulate_controlled, SimParams};
    use crate::workload::{gamma_trace, varying_trace, Phase};

    fn ds2_setup() -> (crate::config::PipelineSpec, crate::profiler::ProfileSet, PipelineConfig, Vec<f64>) {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        // Batch-less deployment on best hardware, provisioned for 50 qps.
        let service_times: Vec<f64> = spec
            .stages
            .iter()
            .map(|s| {
                let mp = profiles.get(&s.model);
                mp.get(mp.best_hardware()).unwrap().latency(1)
            })
            .collect();
        let config = PipelineConfig {
            stages: spec
                .stages
                .iter()
                .zip(&service_times)
                .map(|(s, &st)| StageConfig {
                    hw: {
                        let mp = profiles.get(&s.model);
                        mp.best_hardware()
                    },
                    batch: 1,
                    replicas: ((50.0 * s.scale_factor * st) / 0.9).ceil().max(1.0) as usize,
                })
                .collect(),
        };
        let _ = Hardware::Cpu;
        (spec, profiles, config, service_times)
    }

    #[test]
    fn handles_uniform_load() {
        // Fig 14(a) CV=1 case: provisioning for the average rate works.
        let (spec, profiles, config, sts) = ds2_setup();
        let live = gamma_trace(50.0, 1.0, 180.0, 41);
        let mut ds2 = Ds2Controller::new(&spec, &sts);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut ds2,
        );
        assert!(result.miss_rate(0.3) < 0.05, "miss {}", result.miss_rate(0.3));
    }

    #[test]
    fn misses_slo_under_bursty_load() {
        // Fig 14(a) CV=4 case: average-rate provisioning + halts => misses.
        let (spec, profiles, config, sts) = ds2_setup();
        let live = gamma_trace(50.0, 4.0, 180.0, 43);
        let mut ds2 = Ds2Controller::new(&spec, &sts);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut ds2,
        );
        assert!(result.miss_rate(0.3) > 0.02, "miss {}", result.miss_rate(0.3));
    }

    #[test]
    fn reconfiguration_halts_hurt_under_rate_ramp() {
        // Fig 14(b): rate 50 -> 100 over 60 s; repeated halts delay
        // recovery relative to InferLine's tuner.
        let (spec, profiles, config, sts) = ds2_setup();
        let live = varying_trace(
            &[
                Phase { lambda: 50.0, cv: 1.0, duration: 60.0, ramp: false },
                Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: true },
                Phase { lambda: 100.0, cv: 1.0, duration: 120.0, ramp: false },
            ],
            47,
        );
        let mut ds2 = Ds2Controller::new(&spec, &sts);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut ds2,
        );
        // There must be at least one reconfiguration (replica changes).
        assert!(result.replica_timeline.len() > 1, "never reconfigured");
        // And some queries incur elevated latency during halts.
        let p99 = crate::util::stats::p99(&result.latencies);
        assert!(p99 > 0.15, "p99 {p99} suspiciously low for halting baseline");
    }
}
