//! Coarse-grained baseline planner (paper §6 "Coarse-Grained Baseline
//! Comparison").
//!
//! Current serving systems treat the pipeline as one black-box service:
//! (1) profile the *whole pipeline* to find the single maximum batch size
//! that meets the SLO, (2) replicate the entire pipeline as a unit until
//! it sustains the target throughput. The target is either the mean
//! arrival rate of the sample trace (CG-Mean) or the peak rate over a
//! sliding window equal to the SLO (CG-Peak).

use crate::config::{PipelineConfig, PipelineSpec, StageConfig};
use crate::profiler::{ProfileSet, BATCH_CANDIDATES};
use crate::simulator::{self, SimParams};
use crate::workload::Trace;

/// Which statistic of the sample trace to provision for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoarseTarget {
    Mean,
    Peak,
}

/// Outcome of coarse-grained planning.
#[derive(Debug, Clone)]
pub struct CoarsePlan {
    pub config: PipelineConfig,
    /// Uniform black-box batch size.
    pub batch: usize,
    /// Pipeline-unit replication factor.
    pub units: usize,
    /// Single-unit pipeline throughput (QPS).
    pub unit_throughput: f64,
    pub cost_per_hour: f64,
}

/// The hardware a CG pipeline unit places a stage on. The baseline has no
/// per-stage hardware reasoning (that is InferLine's contribution): the
/// whole pipeline replica is deployed to GPU serving nodes, as in the
/// paper's EC2 testbed (p2.8xlarge K80 nodes). Models without a GPU
/// profile fall back to CPU.
fn unit_hw(profiles: &ProfileSet, model: &str) -> crate::hardware::Hardware {
    use crate::hardware::Hardware;
    if profiles.get(model).get(Hardware::GpuK80).is_some() {
        Hardware::GpuK80
    } else {
        Hardware::Cpu
    }
}

/// One pipeline unit at batch `b`, replicated `units` times.
fn unit_config(spec: &PipelineSpec, profiles: &ProfileSet, batch: usize, units: usize) -> PipelineConfig {
    PipelineConfig {
        stages: spec
            .stages
            .iter()
            .map(|s| {
                let hw = unit_hw(profiles, &s.model);
                let cap = profiles.get(&s.model).get(hw).unwrap().max_batch();
                StageConfig { hw, batch: batch.min(cap), replicas: units }
            })
            .collect(),
    }
}

/// Throughput of a single pipeline unit at batch `b`: the bottleneck
/// stage's throughput normalized by its traffic share.
fn unit_throughput(spec: &PipelineSpec, profiles: &ProfileSet, batch: usize) -> f64 {
    spec.stages
        .iter()
        .map(|s| {
            let prof = profiles.get(&s.model).get(unit_hw(profiles, &s.model)).unwrap();
            let b = batch.min(prof.max_batch());
            prof.throughput(b) / s.scale_factor
        })
        .fold(f64::INFINITY, f64::min)
}

/// Black-box profiling: the largest batch size whose end-to-end pipeline
/// processing latency at *full* batches fits within half the SLO. The
/// baseline has no Estimator; when operators tune a black-box service
/// they must leave the other half of the latency budget for queueing —
/// without that headroom the deployed pipeline would miss P99 under any
/// non-trivial load (and the paper reports CG-Peak *does* meet SLOs,
/// just expensively).
pub fn max_feasible_batch(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    slo: f64,
    _params: &SimParams,
) -> usize {
    let mut best = 1usize;
    for &b in BATCH_CANDIDATES.iter() {
        let config = unit_config(spec, profiles, b, 1);
        if simulator::service_time(spec, profiles, &config) <= slo * 0.5 {
            best = b;
        } else {
            break;
        }
    }
    best
}

/// CG-Mean / CG-Peak planning (paper §6).
pub fn plan(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    sample: &Trace,
    slo: f64,
    target: CoarseTarget,
) -> CoarsePlan {
    let params = SimParams::default();
    let batch = max_feasible_batch(spec, profiles, slo, &params);
    let unit_thru = unit_throughput(spec, profiles, batch);
    let rate = match target {
        CoarseTarget::Mean => sample.mean_rate(),
        // Peak over a window the size of the SLO (paper §6).
        CoarseTarget::Peak => sample.peak_rate(slo),
    };
    let units = (rate / unit_thru).ceil().max(1.0) as usize;
    let config = unit_config(spec, profiles, batch, units);
    CoarsePlan {
        cost_per_hour: config.cost_per_hour(),
        config,
        batch,
        units,
        unit_throughput: unit_thru,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::planner;
    use crate::profiler::analytic::paper_profiles;
    use crate::workload::gamma_trace;

    #[test]
    fn peak_provisions_at_least_mean() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(150.0, 4.0, 60.0, 3);
        let mean = plan(&spec, &profiles, &sample, 0.3, CoarseTarget::Mean);
        let peak = plan(&spec, &profiles, &sample, 0.3, CoarseTarget::Peak);
        assert!(peak.units >= mean.units, "peak {} < mean {}", peak.units, mean.units);
        assert!(peak.cost_per_hour >= mean.cost_per_hour);
    }

    #[test]
    fn batch_shrinks_with_tighter_slo() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let params = SimParams::default();
        let tight = max_feasible_batch(&spec, &profiles, 0.15, &params);
        let loose = max_feasible_batch(&spec, &profiles, 1.0, &params);
        assert!(loose >= tight, "loose {loose} < tight {tight}");
        assert!(tight >= 1);
    }

    #[test]
    fn inferline_planner_is_cheaper_than_cg_peak() {
        // The paper's headline: fine-grained per-stage planning beats
        // whole-pipeline replication on cost (up to 7.6x, Fig 5).
        let spec = pipelines::video_monitoring();
        let profiles = paper_profiles();
        let sample = gamma_trace(100.0, 1.0, 30.0, 11);
        let slo = 0.3;
        let il = planner::plan(&spec, &profiles, &sample, slo).unwrap();
        let cg = plan(&spec, &profiles, &sample, slo, CoarseTarget::Peak);
        assert!(
            il.cost_per_hour < cg.cost_per_hour,
            "InferLine {} vs CG-Peak {}",
            il.cost_per_hour,
            cg.cost_per_hour
        );
    }

    #[test]
    fn cg_mean_underprovisions_bursty_workloads() {
        // CG-Mean ignores burstiness: under CV=4 it should miss SLOs
        // (paper Fig 5 bottom row).
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(150.0, 4.0, 60.0, 7);
        let slo = 0.15;
        let cg = plan(&spec, &profiles, &sample, slo, CoarseTarget::Mean);
        let live = gamma_trace(150.0, 4.0, 120.0, 8);
        let result = simulator::simulate(
            &spec, &profiles, &cg.config, &live, &SimParams::default(),
        );
        assert!(
            result.miss_rate(slo) > 0.01,
            "CG-Mean unexpectedly fine: {}",
            result.miss_rate(slo)
        );
    }

    #[test]
    fn unit_throughput_accounts_for_scale_factors() {
        let spec = pipelines::tf_cascade();
        let profiles = paper_profiles();
        // tf_slow has s=0.3: its effective per-unit throughput triples.
        let t = unit_throughput(&spec, &profiles, 1);
        let slow_prof = profiles.get("tf_slow");
        let raw = slow_prof.get(slow_prof.best_hardware()).unwrap().throughput(1);
        assert!(t >= raw, "scale factor should relax the bottleneck");
    }
}
