//! Oracle planner baseline (paper Fig 10): the InferLine Planner given
//! full knowledge of the live trace it will serve. It configures once —
//! perfectly for the whole trace — but cannot react online, so it pays
//! peak cost for the entire duration (the trade-off Fig 10 illustrates).

use crate::config::{PipelineSpec, PipelineConfig};
use crate::planner::{Plan, PlanError, Planner};
use crate::profiler::ProfileSet;
use crate::workload::Trace;

/// Plan with oracle knowledge: the "sample" trace *is* the live trace.
pub fn plan_with_oracle(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    live_trace: &Trace,
    slo: f64,
) -> Result<Plan, PlanError> {
    Planner::new(spec, profiles).plan(live_trace, slo)
}

/// Convenience: the oracle's static config.
pub fn oracle_config(
    spec: &PipelineSpec,
    profiles: &ProfileSet,
    live_trace: &Trace,
    slo: f64,
) -> Result<PipelineConfig, PlanError> {
    plan_with_oracle(spec, profiles, live_trace, slo).map(|p| p.config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::profiler::analytic::paper_profiles;
    use crate::simulator::{self, SimParams};
    use crate::workload::{varying_trace, Phase};

    #[test]
    fn oracle_meets_slo_on_rate_change_it_knows_about() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let slo = 0.3;
        let live = varying_trace(
            &[
                Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: false },
                Phase { lambda: 200.0, cv: 1.0, duration: 60.0, ramp: false },
            ],
            55,
        );
        let plan = plan_with_oracle(&spec, &profiles, &live, slo).unwrap();
        let result = simulator::simulate(
            &spec, &profiles, &plan.config, &live, &SimParams::default(),
        );
        assert!(result.miss_rate(slo) < 0.011, "miss {}", result.miss_rate(slo));
    }

    #[test]
    fn oracle_costs_more_than_it_needs_before_the_spike() {
        // The oracle pays for peak capacity the whole time; a plan for the
        // pre-spike segment alone is cheaper.
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let slo = 0.3;
        let quiet = varying_trace(
            &[Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: false }],
            57,
        );
        let spiky = quiet.concat(&varying_trace(
            &[Phase { lambda: 250.0, cv: 1.0, duration: 60.0, ramp: false }],
            58,
        ));
        let oracle = plan_with_oracle(&spec, &profiles, &spiky, slo).unwrap();
        let quiet_plan = plan_with_oracle(&spec, &profiles, &quiet, slo).unwrap();
        assert!(
            oracle.cost_per_hour > quiet_plan.cost_per_hour,
            "oracle {} should exceed quiet {}",
            oracle.cost_per_hour,
            quiet_plan.cost_per_hour
        );
    }
}
