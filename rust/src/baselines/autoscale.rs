//! AutoScale-style coarse-grained reactive tuner (paper §6: "the
//! coarse-grained tuning mechanism scales the number of pipeline replicas
//! using the scaling algorithm introduced in [12]").
//!
//! The mechanism watches the *mean* request rate over a trailing window
//! and re-provisions whole pipeline units to match it — bit-at-a-time
//! capacity management without any notion of burstiness or batching. Two
//! properties make it slower than InferLine's Tuner (Fig 7, Fig 12):
//! its detection statistic is a trailing mean (bursts are smoothed away
//! until the pipeline is already overloaded), and it scales the entire
//! pipeline as a unit (every stage together, on a slower decision epoch).

use crate::simulator::control::{ControlAction, ControlState, Controller};
use crate::tuner::envelope::RateMonitor;

/// How targets are derived from the observed rate.
enum Mode {
    /// Whole-pipeline units: every stage gets `units` replicas.
    Units { unit_throughput: f64 },
    /// Proportional: scale a base per-stage allocation by rate/base_rate
    /// (used when the baseline tuner manages an InferLine-planned config,
    /// paper Fig 12 "InferLine Plan + Baseline Tune").
    Proportional { base: Vec<usize>, base_rate: f64 },
}

/// Reactive whole-pipeline scaler.
pub struct AutoScaleTuner {
    mode: Mode,
    /// Current unit multiplier (units, or proportional numerator).
    units: usize,
    monitor: RateMonitor,
    /// Trailing window for the rate estimate (seconds).
    pub rate_window: f64,
    /// Decision epoch (seconds) — whole-pipeline reconfiguration is slow.
    pub epoch: f64,
    /// Scale-down stabilization delay (15 s in [12]).
    pub downscale_delay: f64,
    last_decision: f64,
    last_change: f64,
    first_arrival: Option<f64>,
    /// Headroom factor on the rate estimate (capacity target utilization).
    pub headroom: f64,
}

impl AutoScaleTuner {
    pub fn new(unit_throughput: f64, initial_units: usize) -> Self {
        Self::with_mode(Mode::Units { unit_throughput }, initial_units)
    }

    /// Proportional variant: scale `base` per-stage replicas linearly in
    /// observed-rate / `base_rate`.
    pub fn proportional(base: Vec<usize>, base_rate: f64) -> Self {
        Self::with_mode(Mode::Proportional { base, base_rate }, 1)
    }

    fn with_mode(mode: Mode, initial_units: usize) -> Self {
        AutoScaleTuner {
            mode,
            units: initial_units,
            monitor: RateMonitor::new(vec![60.0]),
            rate_window: 15.0,
            epoch: 10.0,
            downscale_delay: 15.0,
            last_decision: f64::NEG_INFINITY,
            last_change: f64::NEG_INFINITY,
            first_arrival: None,
            headroom: 1.1,
        }
    }
}

impl Controller for AutoScaleTuner {
    fn on_arrival(&mut self, t: f64) {
        self.first_arrival.get_or_insert(t);
        self.monitor.on_arrival(t);
    }

    fn on_tick(&mut self, now: f64, state: &ControlState) -> Vec<ControlAction> {
        // Wait for a full rate window before acting (cold-start guard).
        let warm = self.first_arrival.map_or(false, |t0| now - t0 >= self.rate_window);
        if !warm || now - self.last_decision < self.epoch {
            return Vec::new();
        }
        self.last_decision = now;
        // Capacity management per [12]: hold capacity for the recent peak
        // demand (max 10 s-bucket rate over the trailing window), releasing
        // it only after the stabilization delay. A trailing *mean* would
        // oscillate and shed the spike capacity instantly.
        let rate = self
            .monitor
            .max_bucket_rate(now, self.rate_window.max(60.0), 10.0);
        let targets: Vec<usize> = match &self.mode {
            Mode::Units { unit_throughput } => {
                let units =
                    ((rate * self.headroom) / unit_throughput).ceil().max(1.0) as usize;
                vec![units; state.provisioned.len()]
            }
            Mode::Proportional { base, base_rate } => {
                let factor = (rate * self.headroom / base_rate).max(0.0);
                base.iter()
                    .map(|&b| ((b as f64 * factor).ceil() as usize).max(1))
                    .collect()
            }
        };
        let total: usize = targets.iter().sum();
        let current: usize = state.provisioned.iter().sum();
        let mut actions = Vec::new();
        let scale_now = total > current
            || (total < current && now - self.last_change >= self.downscale_delay);
        if scale_now && targets != state.provisioned {
            self.units = total;
            self.last_change = now;
            for (stage, &replicas) in targets.iter().enumerate() {
                if replicas != state.provisioned[stage] {
                    actions.push(ControlAction::SetReplicas { stage, replicas });
                }
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::coarse::{self, CoarseTarget};
    use crate::config::pipelines;
    use crate::profiler::analytic::paper_profiles;
    use crate::simulator::{control::simulate_controlled, SimParams};
    use crate::workload::{gamma_trace, varying_trace, Phase};

    #[test]
    fn scales_whole_pipeline_units() {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(50.0, 1.0, 30.0, 1);
        let cg = coarse::plan(&spec, &profiles, &sample, 0.3, CoarseTarget::Mean);
        let live = varying_trace(
            &[
                Phase { lambda: 50.0, cv: 1.0, duration: 40.0, ramp: false },
                Phase { lambda: 150.0, cv: 1.0, duration: 120.0, ramp: false },
            ],
            9,
        );
        let mut tuner = AutoScaleTuner::new(cg.unit_throughput, cg.units);
        let result = simulate_controlled(
            &spec, &profiles, &cg.config, &live, &SimParams::default(), &mut tuner,
        );
        // It must eventually scale up, and every stage together.
        let max_seen = result.replica_timeline.iter().map(|&(_, n)| n).max().unwrap();
        let initial: usize = cg.config.stages.iter().map(|s| s.replicas).sum();
        assert!(max_seen > initial, "never scaled: {initial} -> {max_seen}");
    }

    #[test]
    fn reacts_slower_than_inferline_tuner() {
        // The Fig 7 phenomenon: trailing-mean detection + slow epoch means
        // the CG tuner accumulates more SLO misses on a rate ramp.
        let slo = 0.3;
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(100.0, 1.0, 30.0, 21);
        let live = varying_trace(
            &[
                Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: false },
                Phase { lambda: 230.0, cv: 1.0, duration: 20.0, ramp: true },
                Phase { lambda: 230.0, cv: 1.0, duration: 120.0, ramp: false },
            ],
            23,
        );
        // InferLine side.
        let il_plan = crate::planner::plan(&spec, &profiles, &sample, slo).unwrap();
        let st = crate::simulator::service_time(&spec, &profiles, &il_plan.config);
        let inputs = crate::tuner::TunerInputs::from_plan(
            &spec, &profiles, &il_plan.config, &sample, st,
        );
        let mut il_tuner = crate::tuner::Tuner::new(inputs);
        let il = simulate_controlled(
            &spec, &profiles, &il_plan.config, &live, &SimParams::default(), &mut il_tuner,
        );
        // Coarse-grained side.
        let cg = coarse::plan(&spec, &profiles, &sample, slo, CoarseTarget::Mean);
        let mut cg_tuner = AutoScaleTuner::new(cg.unit_throughput, cg.units);
        let cgr = simulate_controlled(
            &spec, &profiles, &cg.config, &live, &SimParams::default(), &mut cg_tuner,
        );
        assert!(
            il.miss_rate(slo) <= cgr.miss_rate(slo) + 1e-9,
            "InferLine {} vs CG {}",
            il.miss_rate(slo),
            cgr.miss_rate(slo)
        );
    }
}
