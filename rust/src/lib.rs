//! # InferLine (reproduction)
//!
//! Provisioning and management of ML prediction pipelines subject to
//! end-to-end tail-latency SLOs at minimum cost, after Crankshaw et al.,
//! *InferLine: ML Prediction Pipeline Provisioning and Management for
//! Tight Latency Objectives* (2018).
//!
//! The library is organised around the paper's two control loops:
//!
//! * **Low-frequency [`planner`]** — combines per-model [`profiler`]
//!   profiles, the discrete-event [`simulator`] (the Estimator) and a
//!   constrained greedy search over (hardware, batch size, replicas) to
//!   find the cost-minimizing configuration meeting a P99 SLO (§4).
//! * **High-frequency [`tuner`]** — network-calculus traffic envelopes
//!   detect arrival-process deviations across timescales and re-scale
//!   individual stages within seconds (§5).
//!
//! [`fleet`] lifts the Planner to many tenant pipelines jointly
//! provisioned against one finite accelerator inventory, with
//! shared-prefix stage deduplication. [`baselines`] implements the
//! paper's comparison points (coarse-grained
//! CG-Mean/CG-Peak planning, the AutoScale reactive tuner, DS2), and
//! [`serving`] is a Clipper-like physical serving plane that executes the
//! real AOT-compiled models through PJRT ([`runtime`]) with centralized
//! batched queues — Python never runs on the request path.

pub mod baselines;
pub mod config;
pub mod experiments;
pub mod fleet;
pub mod hardware;
pub mod planner;
pub mod profiler;
pub mod runtime;
pub mod serving;
pub mod simulator;
pub mod tuner;
pub mod util;
pub mod workload;
