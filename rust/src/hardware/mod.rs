//! Hardware catalog and cost model (paper §6 "Physical Execution
//! Environment").
//!
//! The paper prices resources by decomposing EC2 instances: CPU cost =
//! instance cost / vCPUs (m4.16xlarge: $3.20/hr / 64 = $0.05), GPU cost =
//! (GPU instance - CPU-equivalent instance) / GPUs (p2.8xlarge K80s ≈
//! $0.70/hr each). We add a V100 tier (p3-derived) so the planner has a
//! 3-deep downgrade chain to search, as in the paper's heterogeneous
//! setting.
//!
//! Real K80/V100 silicon is not present on this image; the catalog prices
//! are real but stage *performance* on each tier comes from the profile
//! layer (empirical for CPU via PJRT, analytic for the accelerator tiers —
//! see DESIGN.md §3).

use std::fmt;

/// A hardware tier a model replica can be placed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Hardware {
    /// One vCPU slice of an m4.16xlarge.
    Cpu,
    /// One NVIDIA K80 of a p2.8xlarge.
    GpuK80,
    /// One NVIDIA V100 of a p3.8xlarge.
    GpuV100,
}

impl Hardware {
    /// All tiers, cheapest first.
    pub const ALL: [Hardware; 3] = [Hardware::Cpu, Hardware::GpuK80, Hardware::GpuV100];

    /// $/hour for one device (paper §6 cost decomposition).
    pub fn cost_per_hour(self) -> f64 {
        match self {
            Hardware::Cpu => 0.05,
            Hardware::GpuK80 => 0.70,
            Hardware::GpuV100 => 1.80,
        }
    }

    /// The next cheaper tier (the planner's DowngradeHW step), if any.
    pub fn downgrade(self) -> Option<Hardware> {
        match self {
            Hardware::GpuV100 => Some(Hardware::GpuK80),
            Hardware::GpuK80 => Some(Hardware::Cpu),
            Hardware::Cpu => None,
        }
    }

    /// Stable identifier used in JSON profiles / manifests / CLI flags.
    pub fn id(self) -> &'static str {
        match self {
            Hardware::Cpu => "cpu",
            Hardware::GpuK80 => "gpu-k80",
            Hardware::GpuV100 => "gpu-v100",
        }
    }

    pub fn from_id(id: &str) -> Option<Hardware> {
        Hardware::ALL.iter().copied().find(|h| h.id() == id)
    }
}

impl fmt::Display for Hardware {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_match_paper_decomposition() {
        assert!((Hardware::Cpu.cost_per_hour() - 0.05).abs() < 1e-12);
        assert!((Hardware::GpuK80.cost_per_hour() - 0.70).abs() < 1e-12);
    }

    #[test]
    fn downgrade_chain_reaches_cpu() {
        let mut hw = Hardware::GpuV100;
        let mut chain = vec![hw];
        while let Some(next) = hw.downgrade() {
            hw = next;
            chain.push(hw);
        }
        assert_eq!(chain, vec![Hardware::GpuV100, Hardware::GpuK80, Hardware::Cpu]);
    }

    #[test]
    fn downgrade_strictly_reduces_cost() {
        for hw in Hardware::ALL {
            if let Some(lower) = hw.downgrade() {
                assert!(lower.cost_per_hour() < hw.cost_per_hour());
            }
        }
    }

    #[test]
    fn id_roundtrip() {
        for hw in Hardware::ALL {
            assert_eq!(Hardware::from_id(hw.id()), Some(hw));
        }
        assert_eq!(Hardware::from_id("tpu"), None);
    }
}
