//! Hardware catalog and cost model (paper §6 "Physical Execution
//! Environment").
//!
//! The paper prices resources by decomposing EC2 instances: CPU cost =
//! instance cost / vCPUs (m4.16xlarge: $3.20/hr / 64 = $0.05), GPU cost =
//! (GPU instance - CPU-equivalent instance) / GPUs (p2.8xlarge K80s ≈
//! $0.70/hr each). We add a V100 tier (p3-derived) so the planner has a
//! 3-deep downgrade chain to search, as in the paper's heterogeneous
//! setting.
//!
//! Real K80/V100 silicon is not present on this image; the catalog prices
//! are real but stage *performance* on each tier comes from the profile
//! layer (empirical for CPU via PJRT, analytic for the accelerator tiers —
//! see DESIGN.md §3).
//!
//! # Inventory
//!
//! [`Inventory`] turns the catalog into a *capacity* model: how many
//! devices of each tier the deployment actually owns, and what each one
//! costs per hour. The historical single-pipeline path assumed an
//! unbounded pool — [`Inventory::unbounded()`] (also [`Default`])
//! preserves exactly those semantics, so every pre-fleet call site keeps
//! today's behaviour bit for bit. Semantics used by the planner and the
//! fleet packer:
//!
//! * a tier with count `None` is unbounded, a tier with `Some(n)` owns
//!   exactly `n` devices, and a tier with `Some(0)` is *absent*:
//!   [`Inventory::tiers()`] skips it, which is how the fleet's local
//!   repair excludes a binding tier when re-planning a tenant;
//! * the single-pipeline `Planner` consults only tier *membership*
//!   (`tiers()` / `has()`) — positive finite counts are enforced one
//!   level up by the fleet packer, which tallies device demand across
//!   all tenants and reports `FleetError::Infeasible` naming the
//!   binding tier when demand exceeds capacity;
//! * per-tier `$`/hr defaults to the catalog price; overriding it (e.g.
//!   reserved-instance discounts) affects fleet-level cost accounting
//!   only — the per-pipeline greedy search still optimises catalog cost.

use std::fmt;

/// A hardware tier a model replica can be placed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Hardware {
    /// One vCPU slice of an m4.16xlarge.
    Cpu,
    /// One NVIDIA K80 of a p2.8xlarge.
    GpuK80,
    /// One NVIDIA V100 of a p3.8xlarge.
    GpuV100,
}

impl Hardware {
    /// All tiers, cheapest first.
    pub const ALL: [Hardware; 3] = [Hardware::Cpu, Hardware::GpuK80, Hardware::GpuV100];

    /// $/hour for one device (paper §6 cost decomposition).
    pub fn cost_per_hour(self) -> f64 {
        match self {
            Hardware::Cpu => 0.05,
            Hardware::GpuK80 => 0.70,
            Hardware::GpuV100 => 1.80,
        }
    }

    /// The next cheaper tier (the planner's DowngradeHW step), if any.
    pub fn downgrade(self) -> Option<Hardware> {
        match self {
            Hardware::GpuV100 => Some(Hardware::GpuK80),
            Hardware::GpuK80 => Some(Hardware::Cpu),
            Hardware::Cpu => None,
        }
    }

    /// Stable identifier used in JSON profiles / manifests / CLI flags.
    pub fn id(self) -> &'static str {
        match self {
            Hardware::Cpu => "cpu",
            Hardware::GpuK80 => "gpu-k80",
            Hardware::GpuV100 => "gpu-v100",
        }
    }

    pub fn from_id(id: &str) -> Option<Hardware> {
        Hardware::ALL.iter().copied().find(|h| h.id() == id)
    }

    /// Position of this tier in [`Hardware::ALL`] — the stable index used
    /// by cache keys and fingerprints.
    pub fn index(self) -> usize {
        match self {
            Hardware::Cpu => 0,
            Hardware::GpuK80 => 1,
            Hardware::GpuV100 => 2,
        }
    }
}

impl fmt::Display for Hardware {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// A finite (or unbounded) pool of devices per hardware tier.
///
/// See the module docs for the full semantics. `count == None` means
/// unbounded, `Some(0)` means the tier is absent (excluded from
/// [`Inventory::tiers()`]), and per-tier `$`/hr defaults to the catalog
/// price from [`Hardware::cost_per_hour`].
#[derive(Debug, Clone, PartialEq)]
pub struct Inventory {
    counts: [Option<usize>; 3],
    costs: [f64; 3],
}

impl Inventory {
    /// The historical assumption: every tier available, no capacity
    /// limit, catalog prices. Also the [`Default`].
    pub fn unbounded() -> Self {
        Inventory {
            counts: [None; 3],
            costs: [
                Hardware::Cpu.cost_per_hour(),
                Hardware::GpuK80.cost_per_hour(),
                Hardware::GpuV100.cost_per_hour(),
            ],
        }
    }

    /// A fully bounded pool: exactly `cpu`/`k80`/`v100` devices per tier
    /// (0 removes the tier from the search entirely).
    pub fn bounded(cpu: usize, k80: usize, v100: usize) -> Self {
        Inventory { counts: [Some(cpu), Some(k80), Some(v100)], ..Inventory::unbounded() }
    }

    /// Set one tier's device count (`None` = unbounded, `Some(0)` =
    /// absent). Builder-style.
    pub fn with_count(mut self, hw: Hardware, count: Option<usize>) -> Self {
        self.counts[hw.index()] = count;
        self
    }

    /// Override one tier's `$`/hr (fleet-level accounting only; the
    /// per-pipeline search still prices by the catalog). Builder-style.
    pub fn with_cost_per_hour(mut self, hw: Hardware, cost: f64) -> Self {
        assert!(cost.is_finite() && cost >= 0.0, "tier cost must be finite and non-negative");
        self.costs[hw.index()] = cost;
        self
    }

    /// Device count for a tier: `None` = unbounded.
    pub fn count(&self, hw: Hardware) -> Option<usize> {
        self.counts[hw.index()]
    }

    /// `$`/hr for one device of this tier under this inventory.
    pub fn cost_per_hour(&self, hw: Hardware) -> f64 {
        self.costs[hw.index()]
    }

    /// Whether the tier exists in this inventory at all (count ≠ 0).
    pub fn has(&self, hw: Hardware) -> bool {
        self.counts[hw.index()] != Some(0)
    }

    /// Available tiers, cheapest first — the replacement for iterating
    /// `Hardware::ALL` directly when searching placements.
    pub fn tiers(&self) -> impl Iterator<Item = Hardware> + '_ {
        Hardware::ALL.into_iter().filter(|hw| self.has(*hw))
    }

    /// True when no tier has a finite count (today's pre-fleet
    /// semantics).
    pub fn is_unbounded(&self) -> bool {
        self.counts.iter().all(|c| c.is_none())
    }
}

impl Default for Inventory {
    fn default() -> Self {
        Inventory::unbounded()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_match_paper_decomposition() {
        assert!((Hardware::Cpu.cost_per_hour() - 0.05).abs() < 1e-12);
        assert!((Hardware::GpuK80.cost_per_hour() - 0.70).abs() < 1e-12);
    }

    #[test]
    fn downgrade_chain_reaches_cpu() {
        let mut hw = Hardware::GpuV100;
        let mut chain = vec![hw];
        while let Some(next) = hw.downgrade() {
            hw = next;
            chain.push(hw);
        }
        assert_eq!(chain, vec![Hardware::GpuV100, Hardware::GpuK80, Hardware::Cpu]);
    }

    #[test]
    fn downgrade_strictly_reduces_cost() {
        for hw in Hardware::ALL {
            if let Some(lower) = hw.downgrade() {
                assert!(lower.cost_per_hour() < hw.cost_per_hour());
            }
        }
    }

    #[test]
    fn id_roundtrip() {
        for hw in Hardware::ALL {
            assert_eq!(Hardware::from_id(hw.id()), Some(hw));
        }
        assert_eq!(Hardware::from_id("tpu"), None);
    }

    #[test]
    fn index_matches_all_order() {
        for (i, hw) in Hardware::ALL.into_iter().enumerate() {
            assert_eq!(hw.index(), i);
        }
    }

    #[test]
    fn unbounded_inventory_keeps_catalog_semantics() {
        let inv = Inventory::default();
        assert!(inv.is_unbounded());
        assert_eq!(inv.tiers().collect::<Vec<_>>(), Hardware::ALL.to_vec());
        for hw in Hardware::ALL {
            assert!(inv.has(hw));
            assert_eq!(inv.count(hw), None);
            assert_eq!(inv.cost_per_hour(hw).to_bits(), hw.cost_per_hour().to_bits());
        }
    }

    #[test]
    fn zero_count_tier_is_absent() {
        let inv = Inventory::unbounded().with_count(Hardware::GpuK80, Some(0));
        assert!(!inv.has(Hardware::GpuK80));
        assert_eq!(inv.tiers().collect::<Vec<_>>(), vec![Hardware::Cpu, Hardware::GpuV100]);
        assert!(!inv.is_unbounded());
    }

    #[test]
    fn bounded_counts_and_cost_override() {
        let inv = Inventory::bounded(64, 8, 2).with_cost_per_hour(Hardware::GpuK80, 0.35);
        assert_eq!(inv.count(Hardware::Cpu), Some(64));
        assert_eq!(inv.count(Hardware::GpuK80), Some(8));
        assert_eq!(inv.count(Hardware::GpuV100), Some(2));
        assert!((inv.cost_per_hour(Hardware::GpuK80) - 0.35).abs() < 1e-12);
        // Other tiers keep catalog prices.
        assert_eq!(
            inv.cost_per_hour(Hardware::Cpu).to_bits(),
            Hardware::Cpu.cost_per_hour().to_bits()
        );
    }
}
