//! The high-frequency Tuner (paper §5): network-calculus detection +
//! per-stage re-scaling within seconds.
//!
//! During planning, the Planner hands the Tuner (a) the traffic envelope
//! of the sample trace, (b) each model's single-replica throughput μ_m at
//! its planned batch size, and (c) each model's max-provisioning ratio
//! ρ_m — the slack the Planner determined the model needs to absorb
//! bursts within the SLO. At runtime the Tuner compares the live traffic
//! envelope against the sample envelope across all timescales
//! simultaneously; any exceedance at any window size triggers scale-up to
//! the triggering rate r_max via
//!
//!   k_m = ⌈ r_max · s_m / (μ_m · ρ_m) ⌉
//!
//! Scale-down is conservative: after 15 s of stability it re-provisions
//! for the max trailing 30 s rate (5 s buckets) using the pipeline-wide
//! minimum ρ, floored at the Planner's replica counts — the Tuner
//! returns to the planned configuration but never undercuts it (paper
//! §5 "Scaling Down").

pub mod envelope;

use crate::config::{PipelineConfig, PipelineSpec};
use crate::profiler::ProfileSet;
use crate::simulator::control::{ControlAction, ControlState, Controller};
use crate::workload::Trace;

use envelope::{window_ladder, RateMonitor, TrafficEnvelope};

/// Immutable planning-time inputs to the Tuner (paper §5 "Initialization").
#[derive(Debug, Clone)]
pub struct TunerInputs {
    /// Sample-trace envelope rates per ladder window.
    pub sample_rates: Vec<f64>,
    /// Ladder window sizes (T_s … 60 s).
    pub windows: Vec<f64>,
    /// Per-stage single-replica throughput μ_m at the planned batch size.
    pub mu: Vec<f64>,
    /// Per-stage max-provisioning ratio ρ_m.
    pub rho: Vec<f64>,
    /// Per-stage scale factor s_m.
    pub scale_factor: Vec<f64>,
    /// The Planner's replica counts (the floor the Tuner returns to).
    pub planned_replicas: Vec<usize>,
}

impl TunerInputs {
    /// Compute the Tuner's inputs from a plan (paper §5 Initialization):
    /// ρ_m = (λ · s_m) / (k_m · μ_m) — the planned utilization slack.
    pub fn from_plan(
        spec: &PipelineSpec,
        profiles: &ProfileSet,
        config: &PipelineConfig,
        sample: &Trace,
        service_time: f64,
    ) -> Self {
        let lambda = sample.mean_rate();
        let windows = window_ladder(service_time);
        let env = TrafficEnvelope::from_arrivals(&sample.arrivals, &windows);
        let mut mu = Vec::new();
        let mut rho = Vec::new();
        let mut scale_factor = Vec::new();
        let mut planned_replicas = Vec::new();
        for (stage, c) in spec.stages.iter().zip(&config.stages) {
            let prof = profiles.get(&stage.model).get(c.hw).expect("profile");
            let mu_m = prof.throughput(c.batch);
            let rho_m = (lambda * stage.scale_factor) / (c.replicas as f64 * mu_m);
            mu.push(mu_m);
            // Clamp: a stage with huge headroom (e.g. a cheap CPU stage the
            // planner over-replicated for pennies) would otherwise produce
            // a near-zero ρ; since scale-down divides by the pipeline-wide
            // min ρ, that would freeze the expensive stages at spike-level
            // replication forever. [0.35, 0.95] keeps burst slack while
            // bounding the conservatism.
            rho.push(rho_m.clamp(0.35, 0.95));
            scale_factor.push(stage.scale_factor);
            planned_replicas.push(c.replicas);
        }
        TunerInputs {
            sample_rates: env.rates(),
            windows,
            mu,
            rho,
            scale_factor,
            planned_replicas,
        }
    }
}

/// The InferLine high-frequency Tuner, pluggable into the controlled
/// simulator and the physical serving plane.
pub struct Tuner {
    inputs: TunerInputs,
    monitor: RateMonitor,
    /// Pipeline-wide min ρ (conservative scale-down divisor).
    rho_min: f64,
    /// Time of the last scaling action (for the stabilization delay).
    last_change: f64,
    /// First observed arrival (scale-down requires a warm monitor: acting
    /// on an empty trailing window would tear the pipeline down at t=0).
    first_arrival: Option<f64>,
    /// Seconds to wait after any change before scaling down (paper: 15 s =
    /// 3× the 5 s replica activation time).
    pub downscale_delay: f64,
    /// Trailing span / bucket for the scale-down statistic (30 s / 5 s).
    pub down_span: f64,
    pub down_bucket: f64,
    /// Detection tolerance on envelope exceedance (fractional).
    pub tolerance: f64,
}

impl Tuner {
    pub fn new(inputs: TunerInputs) -> Self {
        let rho_min = inputs.rho.iter().copied().fold(f64::INFINITY, f64::min);
        let monitor = RateMonitor::new(inputs.windows.clone());
        Tuner {
            inputs,
            monitor,
            rho_min,
            last_change: f64::NEG_INFINITY,
            first_arrival: None,
            downscale_delay: 15.0,
            down_span: 30.0,
            down_bucket: 5.0,
            tolerance: 0.02,
        }
    }

    /// Replica target for every stage at arrival rate `r` with
    /// provisioning ratio divisor `rho` (paper §5 k_m formula).
    fn targets(&self, r: f64, rho: &[f64]) -> Vec<usize> {
        self.inputs
            .mu
            .iter()
            .zip(&self.inputs.scale_factor)
            .zip(rho)
            .map(|((&mu_m, &s_m), &rho_m)| {
                ((r * s_m) / (mu_m * rho_m)).ceil().max(1.0) as usize
            })
            .collect()
    }

    /// Detection: the maximum live rate exceeding its sample envelope
    /// rate, if any (paper §5 "Scaling Up").
    fn detect_exceedance(&self, now: f64) -> Option<f64> {
        let live = self.monitor.rates(now);
        let mut r_max: Option<f64> = None;
        for (r, sample) in live.iter().zip(&self.inputs.sample_rates) {
            if *r > sample * (1.0 + self.tolerance) {
                r_max = Some(r_max.map_or(*r, |m: f64| m.max(*r)));
            }
        }
        r_max
    }
}

impl Controller for Tuner {
    fn on_arrival(&mut self, t: f64) {
        self.first_arrival.get_or_insert(t);
        self.monitor.on_arrival(t);
    }

    fn on_tick(&mut self, now: f64, state: &ControlState) -> Vec<ControlAction> {
        let mut actions = Vec::new();
        let mut acted = vec![false; state.provisioned.len()];
        let warm = self
            .first_arrival
            .map_or(false, |t0| now - t0 >= self.down_span);
        if let Some(r_max) = self.detect_exceedance(now) {
            // Scale up to absorb the triggering rate.
            let targets = self.targets(r_max, &self.inputs.rho.clone());
            for (stage, (&target, &current)) in
                targets.iter().zip(&state.provisioned).enumerate()
            {
                if target > current {
                    actions.push(ControlAction::SetReplicas { stage, replicas: target });
                    acted[stage] = true;
                }
            }
        } else if warm && now - self.last_change >= self.downscale_delay {
            // Conservative scale-down toward the trailing-max rate,
            // floored at the Planner's replica counts: the planned
            // configuration is the validated baseline the Tuner returns
            // to, never undercuts (paper §5 — lowering the floor is the
            // Planner's job on its next low-frequency pass).
            let lambda_new = self
                .monitor
                .max_bucket_rate(now, self.down_span, self.down_bucket);
            let rho_p = vec![self.rho_min; self.inputs.mu.len()];
            let targets = self.targets(lambda_new, &rho_p);
            for (stage, (&target, &current)) in
                targets.iter().zip(&state.provisioned).enumerate()
            {
                let floor = self.inputs.planned_replicas[stage].max(1);
                let target = target.max(floor);
                // Removal only when strictly lower.
                if target < current {
                    actions.push(ControlAction::SetReplicas { stage, replicas: target });
                    acted[stage] = true;
                }
            }
        }
        // Failure recovery: a stage under the Planner's floor lost
        // capacity it never chose to give up (replica crashes — scaling
        // actions themselves never undercut the floor), so restore the
        // validated baseline immediately. The envelope detector cannot
        // see this: it reacts to *traffic* exceeding the sample, not to
        // *capacity* falling out from under nominal traffic. Skipping
        // stages already acted on this tick keeps the two branches from
        // issuing contradictory targets; under no-fault serving
        // provisioned counts never fall below the floor, so this branch
        // never fires and fault-free runs are bit-identical.
        for (stage, &current) in state.provisioned.iter().enumerate() {
            let floor = self.inputs.planned_replicas[stage].max(1);
            if !acted[stage] && current < floor {
                actions.push(ControlAction::SetReplicas { stage, replicas: floor });
            }
        }
        if !actions.is_empty() {
            self.last_change = now;
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pipelines;
    use crate::planner::Planner;
    use crate::profiler::analytic::paper_profiles;
    use crate::simulator::{self, control::simulate_controlled, SimParams};
    use crate::workload::{gamma_trace, varying_trace, Phase};

    fn setup(lambda: f64, slo: f64) -> (crate::config::PipelineSpec, crate::profiler::ProfileSet, crate::config::PipelineConfig, TunerInputs) {
        let spec = pipelines::image_processing();
        let profiles = paper_profiles();
        let sample = gamma_trace(lambda, 1.0, 30.0, 21);
        let plan = Planner::new(&spec, &profiles).plan(&sample, slo).unwrap();
        let st = simulator::service_time(&spec, &profiles, &plan.config);
        let inputs = TunerInputs::from_plan(&spec, &profiles, &plan.config, &sample, st);
        (spec, profiles, plan.config, inputs)
    }

    #[test]
    fn inputs_are_self_consistent() {
        let (_spec, _profiles, config, inputs) = setup(100.0, 0.3);
        // Re-deriving targets at the sample λ must not exceed the plan.
        let tuner = Tuner::new(inputs.clone());
        let targets = tuner.targets(100.0, &inputs.rho);
        for (t, c) in targets.iter().zip(&config.stages) {
            assert!(
                *t <= c.replicas + 1,
                "target {t} vs planned {} should roughly match",
                c.replicas
            );
        }
    }

    #[test]
    fn no_false_positive_on_sample_like_traffic() {
        let (spec, profiles, config, inputs) = setup(100.0, 0.3);
        let live = gamma_trace(100.0, 1.0, 120.0, 77); // same distribution
        let mut tuner = Tuner::new(inputs);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut tuner,
        );
        // Total replicas should stay near the planned level: scale-ups, if
        // any, are small and transient.
        let planned: usize = config.stages.iter().map(|s| s.replicas).sum();
        let max_seen = result
            .replica_timeline
            .iter()
            .map(|&(_, n)| n)
            .max()
            .unwrap_or(planned);
        assert!(
            max_seen <= planned + planned / 2 + 1,
            "max {max_seen} vs planned {planned}"
        );
    }

    #[test]
    fn scales_up_on_rate_increase_and_maintains_slo() {
        let slo = 0.3;
        let (spec, profiles, config, inputs) = setup(100.0, slo);
        let live = varying_trace(
            &[
                Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: false },
                Phase { lambda: 220.0, cv: 1.0, duration: 30.0, ramp: true },
                Phase { lambda: 220.0, cv: 1.0, duration: 120.0, ramp: false },
            ],
            31,
        );
        let mut tuner = Tuner::new(inputs);
        let with_tuner = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut tuner,
        );
        let mut null = crate::simulator::control::NullController;
        let without = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut null,
        );
        assert!(
            with_tuner.miss_rate(slo) < 0.05,
            "tuned miss rate {}",
            with_tuner.miss_rate(slo)
        );
        assert!(
            with_tuner.miss_rate(slo) < without.miss_rate(slo),
            "tuner {} should beat static {}",
            with_tuner.miss_rate(slo),
            without.miss_rate(slo)
        );
        // And it must actually have scaled up.
        let planned: usize = config.stages.iter().map(|s| s.replicas).sum();
        let max_seen = with_tuner.replica_timeline.iter().map(|&(_, n)| n).max().unwrap();
        assert!(max_seen > planned, "never scaled up");
    }

    #[test]
    fn detects_burstiness_increase_at_constant_rate() {
        let slo = 0.3;
        let (spec, profiles, config, inputs) = setup(100.0, slo);
        // Same λ, CV jumps 1 -> 4 (the Fig 11 scenario).
        let live = varying_trace(
            &[
                Phase { lambda: 100.0, cv: 1.0, duration: 60.0, ramp: false },
                Phase { lambda: 100.0, cv: 4.0, duration: 120.0, ramp: false },
            ],
            33,
        );
        let mut tuner = Tuner::new(inputs);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut tuner,
        );
        let planned: usize = config.stages.iter().map(|s| s.replicas).sum();
        let max_seen = result.replica_timeline.iter().map(|&(_, n)| n).max().unwrap();
        assert!(max_seen > planned, "burstiness increase not detected");
    }

    #[test]
    fn scale_down_never_undercuts_the_planned_floor() {
        // A long rate *drop*: the trailing-rate targets fall below the
        // planned replica counts, but the Tuner must park at the planned
        // floor rather than tearing the validated baseline down.
        let slo = 0.3;
        let (spec, profiles, config, inputs) = setup(100.0, slo);
        let live = varying_trace(
            &[
                Phase { lambda: 100.0, cv: 1.0, duration: 40.0, ramp: false },
                Phase { lambda: 30.0, cv: 1.0, duration: 160.0, ramp: false },
            ],
            37,
        );
        let mut tuner = Tuner::new(inputs);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut tuner,
        );
        let planned: usize = config.stages.iter().map(|s| s.replicas).sum();
        for &(t, n) in &result.replica_timeline {
            assert!(n >= planned, "t={t}: provisioned {n} under planned floor {planned}");
        }
    }

    #[test]
    fn scales_back_down_after_spike() {
        let slo = 0.3;
        let (spec, profiles, config, inputs) = setup(100.0, slo);
        let live = varying_trace(
            &[
                Phase { lambda: 100.0, cv: 1.0, duration: 40.0, ramp: false },
                Phase { lambda: 250.0, cv: 1.0, duration: 40.0, ramp: false },
                Phase { lambda: 80.0, cv: 1.0, duration: 120.0, ramp: false },
            ],
            35,
        );
        let mut tuner = Tuner::new(inputs);
        let result = simulate_controlled(
            &spec, &profiles, &config, &live, &SimParams::default(), &mut tuner,
        );
        let max_seen = result.replica_timeline.iter().map(|&(_, n)| n).max().unwrap();
        let final_count = result.replica_timeline.last().unwrap().1;
        assert!(final_count < max_seen, "never scaled down: {max_seen} -> {final_count}");
    }
}
