//! Traffic envelopes from network calculus (paper §5, Fig 4).
//!
//! A traffic envelope maps a set of window sizes ΔT_i to the maximum
//! number of queries observed in *any* interval of that width — an
//! arrival-curve characterization that captures burstiness across
//! multiple timescales simultaneously. Window sizes start at the system
//! service time T_s and double up to 60 seconds (paper §5).

use std::collections::VecDeque;

/// Window ladder: T_s, 2·T_s, 4·T_s, … capped at 60 s (inclusive). The
/// ladder always starts at T_s, even when T_s ≥ 60 s (a slow pipeline
/// still needs its service-time rung — the cap only bounds the rungs
/// *above* T_s, so such a pipeline gets the single window [T_s]).
pub fn window_ladder(service_time: f64) -> Vec<f64> {
    let ts = service_time.max(0.010); // floor at 10 ms for sanity
    let mut windows = vec![ts];
    let mut w = ts * 2.0;
    while w < 60.0 {
        windows.push(w);
        w *= 2.0;
    }
    if ts < 60.0 {
        windows.push(60.0);
    }
    windows
}

/// A traffic envelope over a fixed window ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficEnvelope {
    pub windows: Vec<f64>,
    /// Max queries observed in any interval of the matching width.
    pub max_queries: Vec<f64>,
    /// Effective window widths: min(window, trace duration). A 30 s
    /// planning trace cannot say anything about 60 s windows; without the
    /// clamp its 60 s envelope rate would be half the true sustained rate
    /// and the Tuner would see permanent phantom exceedances.
    pub effective: Vec<f64>,
}

impl TrafficEnvelope {
    /// Build the envelope of an arrival trace over the given windows
    /// (two-pointer sliding max per window; O(N) per window).
    pub fn from_arrivals(arrivals: &[f64], windows: &[f64]) -> Self {
        let duration = match (arrivals.first(), arrivals.last()) {
            (Some(a), Some(b)) if b > a => b - a,
            _ => f64::INFINITY,
        };
        let mut max_queries = Vec::with_capacity(windows.len());
        let mut effective = Vec::with_capacity(windows.len());
        for &w in windows {
            let mut best = 0usize;
            let mut lo = 0usize;
            for hi in 0..arrivals.len() {
                while arrivals[hi] - arrivals[lo] > w {
                    lo += 1;
                }
                best = best.max(hi - lo + 1);
            }
            max_queries.push(best as f64);
            effective.push(w.min(duration));
        }
        TrafficEnvelope { windows: windows.to_vec(), max_queries, effective }
    }

    /// Arrival rate bound per window: r_i = q_i / ΔT_i (paper §5), with
    /// ΔT_i clamped to the trace duration.
    pub fn rates(&self) -> Vec<f64> {
        self.effective
            .iter()
            .zip(&self.max_queries)
            .map(|(&w, &q)| q / w)
            .collect()
    }
}

/// Streaming monitor of the live arrival process: maintains the recent
/// arrival timestamps and answers "current max rate per window" queries.
/// This is the Tuner's detection tap (§5 "Scaling Up").
#[derive(Debug, Clone)]
pub struct RateMonitor {
    windows: Vec<f64>,
    max_window: f64,
    buf: VecDeque<f64>,
}

impl RateMonitor {
    pub fn new(windows: Vec<f64>) -> Self {
        let max_window = windows.iter().copied().fold(60.0_f64, f64::max);
        RateMonitor { windows, max_window, buf: VecDeque::new() }
    }

    pub fn on_arrival(&mut self, t: f64) {
        self.buf.push_back(t);
        // Evict anything older than the largest window.
        while let Some(&front) = self.buf.front() {
            if t - front > self.max_window {
                self.buf.pop_front();
            } else {
                break;
            }
        }
    }

    /// Arrival count in the half-open interval `(lo, hi]`.
    pub fn count_between(&self, lo: f64, hi: f64) -> usize {
        let (a, b) = self.buf.as_slices();
        let upto = |s: &[f64], x: f64| s.partition_point(|&t| t <= x);
        (upto(a, hi) + upto(b, hi)).saturating_sub(upto(a, lo) + upto(b, lo))
    }

    /// Observed arrival count in the trailing window ending at `now`.
    pub fn count_in(&self, now: f64, window: f64) -> usize {
        self.count_between(now - window, now)
    }

    /// Current trailing rates for every window of the ladder.
    pub fn rates(&self, now: f64) -> Vec<f64> {
        self.windows
            .iter()
            .map(|&w| self.count_in(now, w) as f64 / w)
            .collect()
    }

    /// Max arrival rate over the trailing `span` seconds measured with
    /// `bucket`-second sub-windows (the Tuner's scale-down statistic:
    /// "max request rate observed over the last 30 seconds, using 5
    /// second windows", §5).
    pub fn max_bucket_rate(&self, now: f64, span: f64, bucket: f64) -> f64 {
        let mut best = 0.0f64;
        let mut end = now;
        while end > now - span + bucket - 1e-9 {
            let cnt = self.count_between(end - bucket, end);
            best = best.max(cnt as f64 / bucket);
            end -= bucket;
        }
        best
    }

    pub fn windows(&self) -> &[f64] {
        &self.windows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gamma_trace;

    #[test]
    fn ladder_doubles_and_caps_at_60() {
        let w = window_ladder(0.25);
        assert!((w[0] - 0.25).abs() < 1e-12);
        for pair in w.windows(2) {
            assert!(pair[1] <= 60.0 + 1e-9);
            assert!(pair[1] > pair[0]);
        }
        assert!((w.last().unwrap() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn envelope_of_uniform_trace() {
        // 10 QPS uniform: any w-second window holds ~10w+1 queries.
        let arrivals: Vec<f64> = (0..600).map(|i| i as f64 * 0.1).collect();
        let env = TrafficEnvelope::from_arrivals(&arrivals, &[1.0, 10.0]);
        assert_eq!(env.max_queries, vec![11.0, 101.0]);
    }

    #[test]
    fn envelope_rates_decrease_with_window_for_bursty() {
        // Burstiness concentrates arrivals: small windows see higher rates.
        let tr = gamma_trace(100.0, 4.0, 120.0, 3);
        let env = TrafficEnvelope::from_arrivals(&tr.arrivals, &[0.5, 60.0]);
        let r = env.rates();
        assert!(r[0] > r[1] * 1.5, "rates {r:?}");
    }

    #[test]
    fn envelope_is_monotone_in_window() {
        let tr = gamma_trace(50.0, 2.0, 60.0, 5);
        let windows = window_ladder(0.2);
        let env = TrafficEnvelope::from_arrivals(&tr.arrivals, &windows);
        for pair in env.max_queries.windows(2) {
            assert!(pair[1] >= pair[0], "counts must grow with window");
        }
    }

    #[test]
    fn monitor_matches_batch_envelope_rates() {
        let tr = gamma_trace(80.0, 1.0, 90.0, 7);
        let windows = vec![1.0, 4.0, 16.0];
        let mut mon = RateMonitor::new(windows.clone());
        for &t in &tr.arrivals {
            mon.on_arrival(t);
        }
        let now = *tr.arrivals.last().unwrap();
        let rates = mon.rates(now);
        // Trailing rates can't exceed the trace envelope's max rates.
        let env = TrafficEnvelope::from_arrivals(&tr.arrivals, &windows);
        for (r, e) in rates.iter().zip(env.rates()) {
            assert!(*r <= e + 1e-9, "trailing {r} > envelope {e}");
            assert!(*r > 0.0);
        }
    }

    #[test]
    fn monitor_evicts_old_arrivals() {
        let mut mon = RateMonitor::new(vec![1.0]);
        for i in 0..100 {
            mon.on_arrival(i as f64 * 0.01); // burst at t≈0..1
        }
        mon.on_arrival(200.0);
        assert_eq!(mon.count_in(200.0, 1.0), 1);
    }

    #[test]
    fn max_bucket_rate_finds_burst() {
        let mut mon = RateMonitor::new(vec![60.0]);
        // 5 qps background for 30 s with a 50-query burst at t=15.
        let mut t = 0.0;
        while t < 30.0 {
            mon.on_arrival(t);
            t += 0.2;
        }
        for i in 0..50 {
            mon.on_arrival(15.0 + i as f64 * 0.001);
        }
        let max_rate = mon.max_bucket_rate(30.0, 30.0, 5.0);
        assert!(max_rate > 12.0, "burst rate {max_rate}");
    }
}
