//! AutoScale-derived real-workload traces (paper §6, Fig 6).
//!
//! The workloads studied in AutoScale [12] report only the average request
//! rate each minute for an hour. The paper re-synthesizes full traces by
//! (1) rescaling the max throughput to 300 QPS and (2) sampling each
//! per-minute rate from a Gamma distribution with CV 1.0 in 30 s segments.
//! We follow the identical recipe over the two published workload shapes:
//!
//!  * **big_spike** — diurnal-ish slow variation with one large sustained
//!    spike mid-trace (Fig 6(a));
//!  * **instant_spike** — a near-instantaneous jump to peak followed by a
//!    decline to a low terminal rate (Fig 6(b)).

use super::Trace;
use crate::util::rng::Rng;

/// Per-minute mean rates (normalized 0..1) for the "big spike" workload:
/// gentle wander, a hard spike around minute 38-44, then recovery.
pub fn big_spike_minutes() -> Vec<f64> {
    let mut m = Vec::with_capacity(60);
    for i in 0..60usize {
        let t = i as f64;
        // Baseline diurnal wander around 0.4 with mild oscillation.
        let mut v = 0.40 + 0.08 * (t / 9.0).sin() + 0.05 * (t / 3.5).cos();
        // The big spike (paper: "when the big spike occurs ...").
        if (38..=44).contains(&i) {
            let peak = 1.0 - 0.03 * (i as f64 - 41.0).abs();
            v = v.max(peak);
        }
        m.push(v.clamp(0.05, 1.0));
    }
    m
}

/// Per-minute mean rates for the "instantaneous spike" workload: low
/// start, step to peak at minute 12, slow decline to a low terminal rate
/// (paper: "the workload drops quickly after 1000 seconds").
pub fn instant_spike_minutes() -> Vec<f64> {
    let mut m = Vec::with_capacity(60);
    for i in 0..60usize {
        let v = if i < 12 {
            0.25 + 0.02 * (i as f64 / 3.0).sin()
        } else if i < 17 {
            1.0 // instantaneous jump to peak, sustained ~5 min
        } else {
            // decline toward a low terminal rate
            (1.0 - 0.06 * (i as f64 - 17.0)).max(0.12)
        };
        m.push(v.clamp(0.05, 1.0));
    }
    m
}

/// Synthesize a trace from per-minute normalized rates, following the
/// paper's recipe: rescale so the max rate is `max_qps` (300 in the
/// paper), then sample 30 s Gamma(CV=1) segments per half-minute.
pub fn synthesize(minutes: &[f64], max_qps: f64, seed: u64) -> Trace {
    assert!(!minutes.is_empty() && max_qps > 0.0);
    let peak = minutes.iter().copied().fold(f64::MIN, f64::max);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t0 = 0.0;
    for &norm in minutes {
        let lambda = (norm / peak * max_qps).max(0.5);
        for _seg in 0..2 {
            // 30 s Gamma CV=1 segment at this minute's rate.
            let end = t0 + 30.0;
            let mut t = t0;
            loop {
                t += rng.interarrival(lambda, 1.0);
                if t > end {
                    break;
                }
                arrivals.push(t);
            }
            t0 = end;
        }
    }
    Trace::new(arrivals)
}

/// The Fig 6(a) workload at the paper's 300 QPS max.
pub fn big_spike_trace(seed: u64) -> Trace {
    synthesize(&big_spike_minutes(), 300.0, seed)
}

/// The Fig 6(b) workload at the paper's 300 QPS max.
pub fn instant_spike_trace(seed: u64) -> Trace {
    synthesize(&instant_spike_minutes(), 300.0, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_hour_long() {
        assert_eq!(big_spike_minutes().len(), 60);
        assert_eq!(instant_spike_minutes().len(), 60);
        let tr = big_spike_trace(1);
        assert!((tr.duration() - 3600.0).abs() < 60.0, "{}", tr.duration());
    }

    #[test]
    fn max_rate_rescaled_to_300() {
        let tr = big_spike_trace(2);
        // Count arrivals in each 30 s bucket; the max bucket should be
        // close to 300 QPS.
        let mut buckets = vec![0usize; 121];
        for &t in &tr.arrivals {
            buckets[(t / 30.0) as usize] += 1;
        }
        let max_rate = *buckets.iter().max().unwrap() as f64 / 30.0;
        assert!((max_rate - 300.0).abs() < 45.0, "max rate {max_rate}");
    }

    #[test]
    fn big_spike_has_a_spike() {
        let m = big_spike_minutes();
        let baseline: f64 = m[..30].iter().sum::<f64>() / 30.0;
        let spike = m[38..=44].iter().copied().fold(f64::MIN, f64::max);
        assert!(spike > 1.8 * baseline, "spike {spike} baseline {baseline}");
    }

    #[test]
    fn instant_spike_jumps_within_one_minute() {
        let m = instant_spike_minutes();
        assert!(m[12] / m[11] > 3.0, "jump {} -> {}", m[11], m[12]);
        // and declines to a low terminal rate
        assert!(m[59] < 0.2);
    }

    #[test]
    fn synthesis_is_deterministic() {
        assert_eq!(big_spike_trace(5), big_spike_trace(5));
        assert_ne!(big_spike_trace(5), big_spike_trace(6));
    }

    #[test]
    fn segment_cv_is_near_one() {
        // Within a constant-rate segment the inter-arrival CV should be ~1.
        let tr = synthesize(&[0.5; 10], 100.0, 9);
        let seg = Trace::new(
            tr.arrivals.iter().copied().filter(|&t| t < 300.0).collect(),
        );
        assert!((seg.cv() - 1.0).abs() < 0.2, "cv {}", seg.cv());
    }
}
