//! Chunked, pull-based arrival sources: the streaming face of the
//! workload layer.
//!
//! Every scenario generator in this crate is seed-deterministic, but
//! until this module they all *materialized* — `Scenario::build`
//! realizes the whole arrival process into one `Vec<f64>`, capping
//! simulation horizons at what fits in memory. [`ArrivalSource`] removes
//! that cap: a source produces arrivals in bounded chunks that the
//! simulator's event loop pulls on demand
//! (`simulator::simulate_streamed`), so a day-long production trace
//! costs O(chunk) memory instead of O(queries).
//!
//! ## Determinism contract
//!
//! **spec + seed ⇒ byte-identical arrival stream, materialized or
//! streamed.** For every scenario node, concatenating the chunks of
//! [`Scenario::source`](super::scenarios::Scenario::source) reproduces
//! [`Scenario::build`](super::scenarios::Scenario::build) bit for bit,
//! for *any* sequence of chunk sizes (including size 1). The streaming
//! sources guarantee this by consuming the scenario's RNG stream in
//! exactly the order the materialized generators do — each leaf below is
//! the incremental form of the corresponding generator loop in
//! [`super::scenarios`], and each operator replicates the materialized
//! operator's RNG-consumption and ordering semantics:
//!
//! * [`SuperposeSource`] merges child streams smallest-timestamp-first,
//!   breaking ties toward the lowest child index — exactly the order a
//!   stable `total_cmp` sort gives the concatenated child traces.
//! * [`SpliceSource`] shifts each child by the last arrival emitted so
//!   far (empty children leave the offset untouched), matching the
//!   `fold(concat)` in `scenarios::splice`.
//! * [`ThinSource`] draws one Bernoulli per *input* arrival whether or
//!   not it survives, like `scenarios::thin`.
//!
//! Three scenario kinds materialize internally and stream from the
//! buffer: `ramp_between` (its crossfade window hangs off the *last*
//! arrival of the `from` trace, which is unknowable before exhausting
//! it), `replay` (bounded by the on-disk file it loads) and `autoscale`
//! (a fixed ~1 h paper workload). They still satisfy the contract —
//! only their memory is O(trace), documented here rather than hidden.
//!
//! The chunk-size invariance means a conformance suite can drive both
//! representations over the whole checked-in scenario grid and assert
//! `Vec<f64>` equality (`rust/tests/streaming_conformance.rs`), which is
//! what keeps the two code paths from drifting.

use crate::util::rng::Rng;

use super::Trace;

/// A pull-based, chunked arrival stream: timestamps in seconds from 0,
/// nondecreasing across the whole stream.
///
/// `next_chunk` appends up to `max` arrivals to `out` and returns how
/// many it appended; `0` means the stream is exhausted (and every later
/// call must also return `0`). Callers own the buffer, so a long-horizon
/// consumer can reuse one allocation for the entire run.
pub trait ArrivalSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize;
}

/// Drain a source to a [`Trace`] by repeated `chunk`-sized pulls — the
/// bridge back to the materialized world, used by the conformance tests
/// and by tooling that wants a concrete trace from a streaming spec.
pub fn drain(src: &mut dyn ArrivalSource, chunk: usize) -> Trace {
    assert!(chunk > 0, "drain chunk size must be > 0");
    let mut arrivals = Vec::new();
    while src.next_chunk(&mut arrivals, chunk) > 0 {}
    Trace::new(arrivals)
}

/// Shared chunk-filling loop: step the closure until the chunk is full
/// or the stream ends.
fn fill(out: &mut Vec<f64>, max: usize, mut step: impl FnMut() -> Option<f64>) -> usize {
    let start = out.len();
    while out.len() - start < max {
        match step() {
            Some(t) => out.push(t),
            None => break,
        }
    }
    out.len() - start
}

/// An already-materialized trace served through the streaming API.
#[derive(Debug, Clone)]
pub struct MaterializedSource {
    arrivals: Vec<f64>,
    pos: usize,
}

impl MaterializedSource {
    pub fn new(trace: Trace) -> Self {
        MaterializedSource { arrivals: trace.arrivals, pos: 0 }
    }
}

impl ArrivalSource for MaterializedSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        let n = max.min(self.arrivals.len() - self.pos);
        out.extend_from_slice(&self.arrivals[self.pos..self.pos + n]);
        self.pos += n;
        n
    }
}

// ---------------------------------------------------------------------------
// Leaf sources: incremental forms of the materialized generators.
// ---------------------------------------------------------------------------

/// Streaming [`super::gamma_trace`]: stationary Gamma renewals at rate
/// λ with the given CV.
pub struct GammaSource {
    rng: Rng,
    lambda: f64,
    cv: f64,
    duration: f64,
    t: f64,
    done: bool,
}

impl GammaSource {
    pub fn new(lambda: f64, cv: f64, duration: f64, seed: u64) -> Self {
        assert!(lambda > 0.0 && cv > 0.0 && duration > 0.0);
        GammaSource { rng: Rng::new(seed), lambda, cv, duration, t: 0.0, done: false }
    }

    fn step(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        self.t += self.rng.interarrival(self.lambda, self.cv);
        if self.t > self.duration {
            self.done = true;
            return None;
        }
        Some(self.t)
    }
}

impl ArrivalSource for GammaSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

/// Streaming [`super::scenarios::rate_curve_trace`]: non-homogeneous
/// Gamma renewals whose instantaneous rate is `rate(t)` evaluated at the
/// current arrival time, floored at the same small positive value.
pub struct RateCurveSource {
    rate: Box<dyn Fn(f64) -> f64>,
    rng: Rng,
    cv: f64,
    duration: f64,
    t: f64,
    done: bool,
}

impl RateCurveSource {
    pub fn new(rate: Box<dyn Fn(f64) -> f64>, cv: f64, duration: f64, seed: u64) -> Self {
        assert!(cv > 0.0 && duration > 0.0);
        RateCurveSource { rate, rng: Rng::new(seed), cv, duration, t: 0.0, done: false }
    }

    fn step(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        let lambda = (self.rate)(self.t).max(1e-3);
        self.t += self.rng.interarrival(lambda, self.cv);
        if self.t > self.duration {
            self.done = true;
            return None;
        }
        Some(self.t)
    }
}

impl ArrivalSource for RateCurveSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

/// Streaming [`super::scenarios::mmpp_trace`]: the same regime state
/// machine, suspended between chunks. Regime boundaries, per-regime
/// Poisson arrivals and the uniform state jump draw from the RNG in
/// exactly the materialized order (the jump is drawn at the end of every
/// regime, including the one that hits `duration`).
pub struct MmppSource {
    rates: Vec<f64>,
    dwell: Vec<f64>,
    duration: f64,
    rng: Rng,
    state: usize,
    /// Start of the next regime (== end of the previous one).
    t: f64,
    /// Candidate arrival time inside the current regime.
    a: f64,
    /// End of the current regime, valid while `in_regime`.
    end: f64,
    in_regime: bool,
    done: bool,
}

impl MmppSource {
    pub fn new(rates: Vec<f64>, dwell: Vec<f64>, duration: f64, seed: u64) -> Self {
        assert!(
            !rates.is_empty() && rates.len() == dwell.len(),
            "mmpp needs matching non-empty rates/dwell"
        );
        assert!(rates.iter().all(|&r| r > 0.0) && dwell.iter().all(|&d| d > 0.0));
        assert!(duration > 0.0);
        MmppSource {
            rates,
            dwell,
            duration,
            rng: Rng::new(seed),
            state: 0,
            t: 0.0,
            a: 0.0,
            end: 0.0,
            in_regime: false,
            done: false,
        }
    }

    fn step(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        loop {
            if !self.in_regime {
                if self.t >= self.duration {
                    self.done = true;
                    return None;
                }
                let sojourn = self.rng.exp(1.0 / self.dwell[self.state]);
                self.end = (self.t + sojourn).min(self.duration);
                self.a = self.t;
                self.in_regime = true;
            }
            self.a += self.rng.exp(self.rates[self.state]);
            if self.a >= self.end {
                self.t = self.end;
                self.in_regime = false;
                if self.rates.len() > 1 {
                    let mut next = self.rng.usize(self.rates.len() - 1);
                    if next >= self.state {
                        next += 1;
                    }
                    self.state = next;
                }
                continue;
            }
            return Some(self.a);
        }
    }
}

impl ArrivalSource for MmppSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

/// Streaming [`super::scenarios::pareto_trace`]: Pareto renewals with
/// shape α > 1 and scale chosen for mean rate λ.
pub struct ParetoSource {
    rng: Rng,
    xm: f64,
    shape: f64,
    duration: f64,
    t: f64,
    done: bool,
}

impl ParetoSource {
    pub fn new(lambda: f64, shape: f64, duration: f64, seed: u64) -> Self {
        assert!(lambda > 0.0 && shape > 1.0 && duration > 0.0);
        let xm = (shape - 1.0) / (shape * lambda);
        ParetoSource { rng: Rng::new(seed), xm, shape, duration, t: 0.0, done: false }
    }

    fn step(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        self.t += self.xm / self.rng.f64_open().powf(1.0 / self.shape);
        if self.t > self.duration {
            self.done = true;
            return None;
        }
        Some(self.t)
    }
}

impl ArrivalSource for ParetoSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

/// Streaming [`super::scenarios::lognormal_trace`]: lognormal renewals
/// with log-σ `sigma` and log-μ chosen for mean rate λ.
pub struct LognormalSource {
    rng: Rng,
    mu: f64,
    sigma: f64,
    duration: f64,
    t: f64,
    done: bool,
}

impl LognormalSource {
    pub fn new(lambda: f64, sigma: f64, duration: f64, seed: u64) -> Self {
        assert!(lambda > 0.0 && sigma > 0.0 && duration > 0.0);
        let mu = -lambda.ln() - sigma * sigma / 2.0;
        LognormalSource { rng: Rng::new(seed), mu, sigma, duration, t: 0.0, done: false }
    }

    fn step(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        self.t += (self.mu + self.sigma * self.rng.normal()).exp();
        if self.t > self.duration {
            self.done = true;
            return None;
        }
        Some(self.t)
    }
}

impl ArrivalSource for LognormalSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

// ---------------------------------------------------------------------------
// Operator sources.
// ---------------------------------------------------------------------------

/// Per-child peek buffer for the operator sources: pulls from the inner
/// source in bounded batches so an operator never forces a child to
/// materialize.
struct Buffered {
    src: Box<dyn ArrivalSource>,
    buf: Vec<f64>,
    pos: usize,
    done: bool,
}

/// Refill batch for operator-internal buffers; bounds operator memory at
/// O(children · REFILL) regardless of stream length.
const REFILL: usize = 1024;

impl Buffered {
    fn new(src: Box<dyn ArrivalSource>) -> Self {
        Buffered { src, buf: Vec::new(), pos: 0, done: false }
    }

    fn peek(&mut self) -> Option<f64> {
        if self.pos == self.buf.len() && !self.done {
            self.buf.clear();
            self.pos = 0;
            if self.src.next_chunk(&mut self.buf, REFILL) == 0 {
                self.done = true;
            }
        }
        self.buf.get(self.pos).copied()
    }

    fn advance(&mut self) {
        self.pos += 1;
    }
}

/// Streaming [`super::scenarios::superpose`]: k-way merge of child
/// streams. Ties go to the lowest child index, which together with
/// per-child FIFO order reproduces the stable `total_cmp` sort of the
/// concatenated child traces byte for byte.
pub struct SuperposeSource {
    children: Vec<Buffered>,
}

impl SuperposeSource {
    pub fn new(children: Vec<Box<dyn ArrivalSource>>) -> Self {
        SuperposeSource { children: children.into_iter().map(Buffered::new).collect() }
    }

    fn step(&mut self) -> Option<f64> {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..self.children.len() {
            if let Some(t) = self.children[i].peek() {
                let better = match best {
                    None => true,
                    Some((_, bt)) => t.total_cmp(&bt).is_lt(),
                };
                if better {
                    best = Some((i, t));
                }
            }
        }
        let (i, t) = best?;
        self.children[i].advance();
        Some(t)
    }
}

impl ArrivalSource for SuperposeSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

/// Streaming [`super::scenarios::splice`]: children played back-to-back,
/// each shifted to start where the stream so far ended. An empty child
/// leaves the offset untouched, exactly like the materialized
/// `fold(concat)` starting from the empty trace.
pub struct SpliceSource {
    children: Vec<Buffered>,
    idx: usize,
    offset: f64,
    /// Last arrival emitted so far (0.0 before the first), the offset
    /// base for the next child.
    last: f64,
}

impl SpliceSource {
    pub fn new(children: Vec<Box<dyn ArrivalSource>>) -> Self {
        SpliceSource {
            children: children.into_iter().map(Buffered::new).collect(),
            idx: 0,
            offset: 0.0,
            last: 0.0,
        }
    }

    fn step(&mut self) -> Option<f64> {
        while self.idx < self.children.len() {
            match self.children[self.idx].peek() {
                Some(t) => {
                    self.children[self.idx].advance();
                    let shifted = t + self.offset;
                    self.last = shifted;
                    return Some(shifted);
                }
                None => {
                    self.offset = self.last;
                    self.idx += 1;
                }
            }
        }
        None
    }
}

impl ArrivalSource for SpliceSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

/// Streaming [`super::scenarios::thin`]: Bernoulli thinning that draws
/// one `rng.bool(p)` per *input* arrival in input order, whether or not
/// the arrival survives — the same RNG consumption as the materialized
/// filter.
pub struct ThinSource {
    inner: Buffered,
    rng: Rng,
    p: f64,
}

impl ThinSource {
    pub fn new(inner: Box<dyn ArrivalSource>, p: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p), "thin probability {p}");
        ThinSource { inner: Buffered::new(inner), rng: Rng::new(seed), p }
    }

    fn step(&mut self) -> Option<f64> {
        loop {
            let t = self.inner.peek()?;
            self.inner.advance();
            if self.rng.bool(self.p) {
                return Some(t);
            }
        }
    }
}

impl ArrivalSource for ThinSource {
    fn next_chunk(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        fill(out, max, || self.step())
    }
}

#[cfg(test)]
mod tests {
    use super::super::scenarios::{
        lognormal_trace, mmpp_trace, pareto_trace, rate_curve_trace, splice, superpose, thin,
    };
    use super::super::{gamma_trace, Trace};
    use super::*;

    fn drain_sizes(mut make: impl FnMut() -> Box<dyn ArrivalSource>, expect: &Trace) {
        for chunk in [1usize, 3, 1024] {
            let got = drain(make().as_mut(), chunk);
            assert_eq!(&got, expect, "chunk size {chunk}");
        }
    }

    #[test]
    fn gamma_source_matches_generator_bit_for_bit() {
        let expect = gamma_trace(80.0, 1.3, 20.0, 7);
        drain_sizes(|| Box::new(GammaSource::new(80.0, 1.3, 20.0, 7)), &expect);
    }

    #[test]
    fn rate_curve_source_matches_generator_bit_for_bit() {
        let curve = |t: f64| 50.0 + 30.0 * (t / 7.0).sin();
        let expect = rate_curve_trace(curve, 1.0, 25.0, 11);
        drain_sizes(
            || Box::new(RateCurveSource::new(Box::new(curve), 1.0, 25.0, 11)),
            &expect,
        );
    }

    #[test]
    fn mmpp_source_matches_generator_bit_for_bit() {
        let rates = vec![20.0, 300.0, 80.0];
        let dwell = vec![5.0, 2.0, 4.0];
        let expect = mmpp_trace(&rates, &dwell, 60.0, 3);
        drain_sizes(
            || Box::new(MmppSource::new(rates.clone(), dwell.clone(), 60.0, 3)),
            &expect,
        );
    }

    #[test]
    fn heavy_tail_sources_match_generators_bit_for_bit() {
        let expect = pareto_trace(100.0, 1.6, 30.0, 9);
        drain_sizes(|| Box::new(ParetoSource::new(100.0, 1.6, 30.0, 9)), &expect);
        let expect = lognormal_trace(100.0, 1.5, 30.0, 9);
        drain_sizes(|| Box::new(LognormalSource::new(100.0, 1.5, 30.0, 9)), &expect);
    }

    #[test]
    fn materialized_source_roundtrips() {
        let tr = gamma_trace(40.0, 1.0, 10.0, 5);
        drain_sizes(|| Box::new(MaterializedSource::new(tr.clone())), &tr);
        // Exhaustion is sticky.
        let mut src = MaterializedSource::new(tr);
        let mut buf = Vec::new();
        while src.next_chunk(&mut buf, 64) > 0 {}
        assert_eq!(src.next_chunk(&mut buf, 64), 0);
    }

    #[test]
    fn superpose_source_matches_operator_bit_for_bit() {
        let a = gamma_trace(50.0, 1.0, 30.0, 1);
        let b = gamma_trace(70.0, 2.0, 30.0, 2);
        let c = pareto_trace(40.0, 1.8, 30.0, 3);
        let expect = superpose(&[a.clone(), b.clone(), c.clone()]);
        drain_sizes(
            || {
                Box::new(SuperposeSource::new(vec![
                    Box::new(MaterializedSource::new(a.clone())),
                    Box::new(MaterializedSource::new(b.clone())),
                    Box::new(MaterializedSource::new(c.clone())),
                ]))
            },
            &expect,
        );
    }

    #[test]
    fn superpose_source_breaks_ties_like_a_stable_sort() {
        // Duplicate timestamps across children: stable sort of the
        // concatenation keeps child-0 copies ahead of child-1 copies.
        let a = Trace::new(vec![1.0, 2.0, 2.0]);
        let b = Trace::new(vec![1.0, 2.0, 3.0]);
        let expect = superpose(&[a.clone(), b.clone()]);
        drain_sizes(
            || {
                Box::new(SuperposeSource::new(vec![
                    Box::new(MaterializedSource::new(a.clone())),
                    Box::new(MaterializedSource::new(b.clone())),
                ]))
            },
            &expect,
        );
    }

    #[test]
    fn splice_source_matches_operator_including_empty_children() {
        let a = gamma_trace(80.0, 1.0, 10.0, 19);
        let empty = Trace::default();
        let b = gamma_trace(20.0, 1.0, 10.0, 23);
        let expect = splice(&[a.clone(), empty.clone(), b.clone()]);
        drain_sizes(
            || {
                Box::new(SpliceSource::new(vec![
                    Box::new(MaterializedSource::new(a.clone())),
                    Box::new(MaterializedSource::new(empty.clone())),
                    Box::new(MaterializedSource::new(b.clone())),
                ]))
            },
            &expect,
        );
    }

    #[test]
    fn thin_source_matches_operator_bit_for_bit() {
        let tr = gamma_trace(100.0, 1.0, 20.0, 13);
        for p in [0.0, 0.5, 1.0] {
            let expect = thin(&tr, p, 17);
            drain_sizes(
                || {
                    Box::new(ThinSource::new(
                        Box::new(MaterializedSource::new(tr.clone())),
                        p,
                        17,
                    ))
                },
                &expect,
            );
        }
    }
}
