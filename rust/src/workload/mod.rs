//! Query arrival workloads (paper §6 "Workload Setup").
//!
//! Synthetic traces sample inter-arrival times from a Gamma distribution
//! with mean 1/λ and coefficient of variation CV; time-varying traces
//! evolve the generating distribution between (λ, CV) set-points over a
//! transition time τ; and the AutoScale-derived traces re-synthesize the
//! real per-minute-rate workloads studied in [12] exactly the way the
//! paper does (rescale max to 300 QPS, 30 s Gamma CV=1 segments).
//!
//! Beyond the paper's processes, [`scenarios`] adds declarative
//! scenario construction — MMPP bursts, diurnal curves, flash crowds,
//! heavy-tailed renewals, file replay, and composition operators — the
//! workload layer the robustness harness stresses the closed loop with.

use crate::util::rng::Rng;
use crate::util::stats;

/// An arrival trace: sorted query arrival timestamps in seconds from 0.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub arrivals: Vec<f64>,
}

impl Trace {
    pub fn new(arrivals: Vec<f64>) -> Self {
        debug_assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "unsorted trace");
        Trace { arrivals }
    }

    /// Checked constructor for externally supplied timestamps (file
    /// replay, user tooling): rejects non-finite and out-of-order
    /// arrivals with the offending index, in release builds too —
    /// [`Trace::new`]'s debug assertion vanishes exactly where replayed
    /// traces are most likely to be malformed.
    pub fn try_new(arrivals: Vec<f64>) -> Result<Trace, String> {
        // Finiteness first: NaN compares false to everything, so a NaN
        // mid-trace would sail through the order scan and the error for
        // mixed-bad inputs would name the wrong failure class/index.
        if let Some(i) = arrivals.iter().position(|t| !t.is_finite()) {
            return Err(format!("arrival {i} is not finite: {}", arrivals[i]));
        }
        for (i, w) in arrivals.windows(2).enumerate() {
            if w[0] > w[1] {
                return Err(format!(
                    "arrivals out of order at index {}: {} > {}",
                    i + 1,
                    w[0],
                    w[1]
                ));
            }
        }
        Ok(Trace { arrivals })
    }

    /// Constructor for generators that produce unordered timestamps
    /// (superposition, crossfades): sorts before wrapping.
    pub fn from_unsorted(mut arrivals: Vec<f64>) -> Trace {
        arrivals.sort_by(f64::total_cmp);
        Trace { arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Trace duration in seconds (0 for < 2 arrivals).
    pub fn duration(&self) -> f64 {
        match (self.arrivals.first(), self.arrivals.last()) {
            (Some(a), Some(b)) => b - a,
            _ => 0.0,
        }
    }

    /// Mean arrival rate (QPS).
    pub fn mean_rate(&self) -> f64 {
        stats::arrival_rate(&self.arrivals)
    }

    /// Coefficient of variation of the inter-arrival process.
    pub fn cv(&self) -> f64 {
        stats::interarrival_cv(&self.arrivals)
    }

    /// Peak rate over a sliding window of `window` seconds (the CG-Peak
    /// planning statistic, paper §6: window set to the SLO). The divisor
    /// is clamped to the trace duration the same way
    /// `TrafficEnvelope::effective` clamps its windows: a 10 s trace
    /// cannot say anything about 60 s windows, and dividing its total
    /// count by the full window would underestimate the statistic 6×.
    pub fn peak_rate(&self, window: f64) -> f64 {
        assert!(window > 0.0);
        let a = &self.arrivals;
        // Below 2 arrivals `mean_rate()` is NaN (no inter-arrival span),
        // which would silently poison CG-Peak planning and every
        // downstream cost/ratio comparison: an empty trace has no load,
        // a single arrival is one query in the best window.
        if a.is_empty() {
            return 0.0;
        }
        if a.len() == 1 {
            return 1.0 / window;
        }
        let mut lo = 0usize;
        let mut best = 0usize;
        for hi in 0..a.len() {
            while a[hi] - a[lo] > window {
                lo += 1;
            }
            best = best.max(hi - lo + 1);
        }
        let duration = self.duration();
        let effective = if duration > 0.0 { window.min(duration) } else { window };
        best as f64 / effective
    }

    /// Split into (head, tail) at a fraction of the *duration* (the paper
    /// uses the first 25% of the trace for planning, the rest for serving).
    /// The tail is re-based to t = 0.
    pub fn split_at_fraction(&self, frac: f64) -> (Trace, Trace) {
        let cut = self.arrivals.first().unwrap_or(&0.0) + self.duration() * frac;
        let idx = self.arrivals.partition_point(|&t| t <= cut);
        let head = Trace::new(self.arrivals[..idx].to_vec());
        let tail: Vec<f64> = self.arrivals[idx..].iter().map(|t| t - cut).collect();
        (head, Trace::new(tail))
    }

    /// Concatenate, shifting `other` to start after `self` ends.
    pub fn concat(&self, other: &Trace) -> Trace {
        let offset = self.arrivals.last().copied().unwrap_or(0.0);
        let mut arrivals = self.arrivals.clone();
        arrivals.extend(other.arrivals.iter().map(|t| t + offset));
        Trace::new(arrivals)
    }

    /// Save as newline-delimited seconds (compact, diffable).
    ///
    /// Timestamps use Rust's shortest-roundtrip `Display` formatting, so
    /// save→load reproduces every `f64` bit-exactly — fixed-precision
    /// `{:.6}` would truncate and break the "replay ⇒ byte-identical
    /// trace" determinism contract for file-backed scenarios.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut out = String::with_capacity(self.arrivals.len() * 12);
        for t in &self.arrivals {
            out.push_str(&format!("{t}\n"));
        }
        std::fs::write(path, out)
    }

    /// Load a saved trace, validating it line by line: a file with
    /// non-numeric, non-finite or unsorted timestamps is rejected with
    /// an error naming the offending line (1-based, blank lines
    /// included in the count) instead of tripping a debug-only
    /// assertion downstream.
    pub fn load(path: &std::path::Path) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let mut arrivals = Vec::new();
        let mut prev: Option<(usize, f64)> = None;
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let lineno = idx + 1;
            let t: f64 = line.parse().map_err(|e| {
                format!("{}: line {lineno}: {e}: {line:?}", path.display())
            })?;
            // parse() accepts "nan"/"inf"; a trace must not.
            if !t.is_finite() {
                return Err(format!(
                    "{}: line {lineno}: arrival is not finite: {line:?}",
                    path.display()
                ));
            }
            if let Some((prev_line, prev_t)) = prev {
                if prev_t > t {
                    return Err(format!(
                        "{}: line {lineno}: arrivals out of order: \
                         {prev_t} (line {prev_line}) > {t}",
                        path.display()
                    ));
                }
            }
            prev = Some((lineno, t));
            arrivals.push(t);
        }
        Ok(Trace::new(arrivals))
    }
}

/// Stationary Gamma-process trace: `duration` seconds at rate λ with the
/// given CV (paper §6). CV = 1 is a Poisson process.
pub fn gamma_trace(lambda: f64, cv: f64, duration: f64, seed: u64) -> Trace {
    assert!(lambda > 0.0 && cv > 0.0 && duration > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity((lambda * duration * 1.1) as usize + 16);
    loop {
        t += rng.interarrival(lambda, cv);
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

/// A workload phase for time-varying generation.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub lambda: f64,
    pub cv: f64,
    /// Seconds this phase lasts (for `Set`) or takes to morph (for `Ramp`).
    pub duration: f64,
    /// If true, λ and CV interpolate linearly from the previous phase over
    /// `duration` (the paper's "transition time" τ); if false they hold.
    pub ramp: bool,
}

/// Time-varying trace: the generating Gamma distribution evolves across
/// phases (paper §6: "we evolve the workload generating function between
/// different Gamma distributions over a specified period of time").
pub fn varying_trace(phases: &[Phase], seed: u64) -> Trace {
    assert!(!phases.is_empty());
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    let mut phase_start = 0.0;
    let (mut prev_lambda, mut prev_cv) = (phases[0].lambda, phases[0].cv);
    for ph in phases {
        let end = phase_start + ph.duration;
        while t < end {
            let (lambda, cv) = if ph.ramp && ph.duration > 0.0 {
                let frac = ((t - phase_start) / ph.duration).clamp(0.0, 1.0);
                (
                    prev_lambda + frac * (ph.lambda - prev_lambda),
                    prev_cv + frac * (ph.cv - prev_cv),
                )
            } else {
                (ph.lambda, ph.cv)
            };
            t += rng.interarrival(lambda, cv);
            if t <= end {
                arrivals.push(t);
            }
        }
        t = t.min(end); // do not leak a long gap into the next phase
        phase_start = end;
        prev_lambda = ph.lambda;
        prev_cv = ph.cv;
    }
    Trace::new(arrivals)
}

pub mod autoscale;
pub mod production;
pub mod scenarios;
pub mod stream;

pub use stream::{ArrivalSource, MaterializedSource};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_trace_matches_requested_stats() {
        let tr = gamma_trace(100.0, 1.0, 120.0, 7);
        assert!((tr.mean_rate() - 100.0).abs() < 5.0, "rate {}", tr.mean_rate());
        assert!((tr.cv() - 1.0).abs() < 0.1, "cv {}", tr.cv());

        let bursty = gamma_trace(100.0, 4.0, 120.0, 7);
        assert!((bursty.cv() - 4.0).abs() < 0.5, "cv {}", bursty.cv());
    }

    #[test]
    fn gamma_trace_is_deterministic_per_seed() {
        assert_eq!(gamma_trace(50.0, 1.0, 10.0, 1), gamma_trace(50.0, 1.0, 10.0, 1));
        assert_ne!(gamma_trace(50.0, 1.0, 10.0, 1), gamma_trace(50.0, 1.0, 10.0, 2));
    }

    #[test]
    fn peak_rate_exceeds_mean_for_bursty() {
        let tr = gamma_trace(100.0, 4.0, 60.0, 3);
        assert!(tr.peak_rate(0.15) > tr.mean_rate() * 1.5);
    }

    #[test]
    fn peak_rate_close_to_mean_for_uniform() {
        // A perfectly regular trace: peak over 1 s windows == mean.
        let tr = Trace::new((1..=600).map(|i| i as f64 * 0.1).collect());
        assert!((tr.peak_rate(1.0) - tr.mean_rate()).abs() / tr.mean_rate() < 0.15);
    }

    #[test]
    fn split_rebases_tail() {
        let tr = gamma_trace(50.0, 1.0, 100.0, 5);
        let (head, tail) = tr.split_at_fraction(0.25);
        assert!(head.len() + tail.len() == tr.len());
        assert!(head.duration() < 30.0);
        assert!(tail.arrivals[0] >= 0.0 && tail.arrivals[0] < 1.0);
    }

    #[test]
    fn varying_trace_ramps_rate() {
        let phases = [
            Phase { lambda: 50.0, cv: 1.0, duration: 60.0, ramp: false },
            Phase { lambda: 200.0, cv: 1.0, duration: 30.0, ramp: true },
            Phase { lambda: 200.0, cv: 1.0, duration: 60.0, ramp: false },
        ];
        let tr = varying_trace(&phases, 11);
        let early: Vec<f64> = tr.arrivals.iter().copied().filter(|&t| t < 50.0).collect();
        let late: Vec<f64> = tr.arrivals.iter().copied().filter(|&t| t > 100.0).collect();
        let early_rate = early.len() as f64 / 50.0;
        let late_rate = late.len() as f64 / 50.0;
        assert!((early_rate - 50.0).abs() < 10.0, "early {early_rate}");
        assert!((late_rate - 200.0).abs() < 25.0, "late {late_rate}");
    }

    #[test]
    fn varying_trace_changes_cv_at_fixed_rate() {
        let phases = [
            Phase { lambda: 100.0, cv: 1.0, duration: 120.0, ramp: false },
            Phase { lambda: 100.0, cv: 4.0, duration: 120.0, ramp: false },
        ];
        let tr = varying_trace(&phases, 13);
        let head = Trace::new(tr.arrivals.iter().copied().filter(|&t| t < 115.0).collect());
        let tail = Trace::new(
            tr.arrivals.iter().copied().filter(|&t| t > 125.0).map(|t| t - 125.0).collect(),
        );
        assert!((head.cv() - 1.0).abs() < 0.3, "head cv {}", head.cv());
        assert!(tail.cv() > 2.0, "tail cv {}", tail.cv());
        assert!((head.mean_rate() - tail.mean_rate()).abs() < 20.0);
    }

    #[test]
    fn trace_file_roundtrip() {
        let tr = gamma_trace(20.0, 1.0, 10.0, 17);
        let dir = std::env::temp_dir().join("inferline-test-traces");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.txt");
        tr.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        // Shortest-roundtrip formatting makes save→load bit-exact.
        assert_eq!(back, tr);
    }

    #[test]
    fn load_rejects_unsorted_file() {
        let dir = std::env::temp_dir().join("inferline-test-traces");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unsorted.txt");
        std::fs::write(&path, "1.0\n3.0\n2.0\n").unwrap();
        let err = Trace::load(&path).unwrap_err();
        assert!(err.contains("out of order"), "{err}");
        assert!(err.contains("line 3"), "{err}");
        std::fs::write(&path, "1.0\nnan\n2.0\n").unwrap();
        let err = Trace::load(&path).unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        std::fs::write(&path, "1.0\n\n2.0\nbogus\n").unwrap();
        let err = Trace::load(&path).unwrap_err();
        // Blank lines are skipped but still counted.
        assert!(err.contains("line 4"), "{err}");
    }

    #[test]
    fn try_new_and_from_unsorted() {
        assert!(Trace::try_new(vec![1.0, 2.0, 3.0]).is_ok());
        assert!(Trace::try_new(vec![2.0, 1.0]).is_err());
        assert!(Trace::try_new(vec![1.0, f64::INFINITY]).is_err());
        assert_eq!(
            Trace::from_unsorted(vec![3.0, 1.0, 2.0]).arrivals,
            vec![1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn try_new_reports_nan_as_non_finite_not_out_of_order() {
        // NaN compares false to everything: before the fix the order
        // scan ran first, silently passed the NaN, and a *later* real
        // order violation was reported instead of the NaN itself.
        let err = Trace::try_new(vec![1.0, f64::NAN, 2.0, 1.5]).unwrap_err();
        assert!(err.contains("not finite"), "{err}");
        assert!(err.contains("arrival 1"), "{err}");
        // A clean out-of-order input still reports the order violation.
        let err = Trace::try_new(vec![1.0, 3.0, 2.0]).unwrap_err();
        assert!(err.contains("out of order"), "{err}");
        assert!(err.contains("index 2"), "{err}");
    }

    #[test]
    fn peak_rate_is_finite_for_degenerate_traces() {
        // Empty: no load, not NaN.
        assert_eq!(Trace::default().peak_rate(0.3), 0.0);
        // Single arrival: one query in the best window.
        let one = Trace::new(vec![5.0]);
        assert_eq!(one.peak_rate(0.5), 2.0);
        assert_eq!(one.peak_rate(2.0), 0.5);
        // Regression shape: the old code delegated to mean_rate(),
        // which is NaN below 2 samples.
        assert!(one.peak_rate(0.3).is_finite());
        assert!(Trace::default().peak_rate(0.3).is_finite());
    }

    #[test]
    fn save_load_roundtrip_preserves_order_and_length() {
        let tr = gamma_trace(120.0, 2.0, 20.0, 31);
        let dir = std::env::temp_dir().join("inferline-test-traces");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.txt");
        tr.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.len(), tr.len());
        assert!(back.arrivals.windows(2).all(|w| w[0] <= w[1]));
        // Exact equality: the save format must roundtrip every bit.
        assert_eq!(back.arrivals, tr.arrivals);
    }

    #[test]
    fn save_roundtrips_awkward_floats_exactly() {
        // Values chosen to break fixed-precision formatting: more than
        // six significant fractional digits, and a subnormal-ish tiny
        // gap between neighbours.
        let tr = Trace::new(vec![
            0.000_000_123_456_789,
            1.0 / 3.0,
            2.0 / 3.0,
            1.0 + f64::EPSILON,
            12_345.678_901_234_567,
        ]);
        let dir = std::env::temp_dir().join("inferline-test-traces");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("awkward.txt");
        tr.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), tr);
    }

    #[test]
    fn concat_shifts() {
        let a = Trace::new(vec![1.0, 2.0]);
        let b = Trace::new(vec![0.5, 1.0]);
        assert_eq!(a.concat(&b).arrivals, vec![1.0, 2.0, 2.5, 3.0]);
    }
}
